//! Engine bench: per-kernel native train-step/eval latency (scalar vs
//! blocked, plus simd when compiled in) and XLA (AOT artifact via PJRT)
//! rows (EXPERIMENTS.md §Perf L2). This quantifies the cost of a single
//! simulated client step — the dominant term of every experiment — and
//! is the acceptance gauge for the kernel subsystem: the `blocked` rows
//! must beat `scalar` on `mlp` at batch 32.
//!
//! The eval rows also cover the zero-alloc evaluation path: after the
//! first chunk the engine's reusable index/batch scratch
//! (`Dataset::gather_batch_into`) makes the steady-state eval loop
//! allocation-free, so these rows time pure compute + gather copies.
//!
//! Flags (after `cargo bench --bench bench_engine --`):
//!   --smoke         seconds-scale sampling (the CI figure-smoke job)
//!   --out-dir DIR   write DIR/BENCH_engine.json (canonical {bench, rows})

use std::sync::Arc;

use quafl::data::{SynthFamily, SynthSpec};
use quafl::engine::{KernelKind, KernelStats, NativeEngine, TrainEngine, XlaEngine};
use quafl::model::ModelSpec;
use quafl::testing::bench::{bench_cfg, write_bench_json, BenchResult};
use quafl::util::cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse_with_bool_flags(&argv, &["smoke"]);
    let smoke = args.bool("smoke");
    let (warmup, secs) = if smoke { (1, 0.05) } else { (3, 1.0) };

    let kernels: &[KernelKind] = if cfg!(feature = "simd") {
        &[KernelKind::Scalar, KernelKind::Blocked, KernelKind::Simd]
    } else {
        &[KernelKind::Scalar, KernelKind::Blocked]
    };

    println!("== bench_engine ==");
    let mut results: Vec<BenchResult> = Vec::new();
    // (model, matching data family): mlp_tiny is the fleet-scaling
    // miniature (16-dim), mlp the paper's MNIST-scale model.
    for (model, family) in [
        ("mlp_tiny", SynthFamily::Tiny),
        ("mlp", SynthFamily::Mnist),
    ] {
        let spec = ModelSpec::by_name(model).unwrap();
        let (train, val) = SynthSpec::family(family, 2048, 1024, 1).generate();
        let idx: Vec<usize> = (0..32).collect();
        let batch = train.gather_batch(&idx);
        let mut params = spec.init_params(3);

        for &kind in kernels {
            let mut native = NativeEngine::with_kernel(
                spec.clone(),
                32,
                kind,
                Arc::new(KernelStats::new()),
            )
            .unwrap();
            results.push(bench_cfg(
                &format!("native train_step {model} [{}]", kind.name()),
                warmup,
                secs,
                Some((32.0, "samples")),
                &mut || {
                    native.train_step(&mut params, &batch, 0.01).unwrap();
                },
            ));
            results.push(bench_cfg(
                &format!("native eval(1024) {model} [{}]", kind.name()),
                warmup,
                secs,
                Some((1024.0, "samples")),
                &mut || {
                    std::hint::black_box(native.evaluate(&params, &val).unwrap());
                },
            ));
        }

        if model == "mlp" && std::path::Path::new("artifacts/meta.json").exists() {
            let mut xla = XlaEngine::new("artifacts", &spec).unwrap();
            results.push(bench_cfg(
                &format!("xla    train_step {model}"),
                warmup,
                secs,
                Some((32.0, "samples")),
                &mut || {
                    xla.train_step(&mut params, &batch, 0.01).unwrap();
                },
            ));
            results.push(bench_cfg(
                &format!("xla    eval(1024) {model}"),
                warmup,
                secs,
                Some((1024.0, "samples")),
                &mut || {
                    std::hint::black_box(xla.evaluate(&params, &val).unwrap());
                },
            ));
        } else if model == "mlp" {
            println!("(artifacts missing — run `make artifacts` for XLA numbers)");
        }
    }

    if let Some(dir) = args.get("out-dir") {
        let path = format!("{dir}/BENCH_engine.json");
        write_bench_json(&path, "engine_step", &results).unwrap();
        println!("wrote {path}");
    }
}

//! Engine bench: XLA (AOT artifact via PJRT) vs native Rust train-step and
//! eval latency (EXPERIMENTS.md §Perf L2). This quantifies the cost of a
//! single simulated client step — the dominant term of every experiment.
//!
//! Flags (after `cargo bench --bench bench_engine --`):
//!   --smoke         seconds-scale sampling (the CI trace-smoke job)
//!   --out-dir DIR   write DIR/BENCH_engine.json (canonical {bench, rows})

use quafl::data::{SynthFamily, SynthSpec};
use quafl::engine::{NativeEngine, TrainEngine, XlaEngine};
use quafl::model::ModelSpec;
use quafl::testing::bench::{bench_cfg, write_bench_json, BenchResult};
use quafl::util::cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse_with_bool_flags(&argv, &["smoke"]);
    let smoke = args.bool("smoke");
    let (warmup, secs) = if smoke { (1, 0.05) } else { (3, 1.0) };

    println!("== bench_engine ==");
    let (train, val) = SynthSpec::family(SynthFamily::Mnist, 2048, 1024, 1).generate();
    let idx: Vec<usize> = (0..32).collect();
    let batch = train.gather_batch(&idx);

    let mut results: Vec<BenchResult> = Vec::new();
    for model in ["mlp", "mlp_deep"] {
        let spec = ModelSpec::by_name(model).unwrap();
        let mut params = spec.init_params(3);

        let mut native = NativeEngine::new(spec.clone(), 32);
        results.push(bench_cfg(
            &format!("native train_step {model}"),
            warmup,
            secs,
            Some((32.0, "samples")),
            &mut || {
                native.train_step(&mut params, &batch, 0.01).unwrap();
            },
        ));
        results.push(bench_cfg(
            &format!("native eval(1024) {model}"),
            warmup,
            secs,
            Some((1024.0, "samples")),
            &mut || {
                std::hint::black_box(native.evaluate(&params, &val).unwrap());
            },
        ));

        if std::path::Path::new("artifacts/meta.json").exists() {
            let mut xla = XlaEngine::new("artifacts", &spec).unwrap();
            results.push(bench_cfg(
                &format!("xla    train_step {model}"),
                warmup,
                secs,
                Some((32.0, "samples")),
                &mut || {
                    xla.train_step(&mut params, &batch, 0.01).unwrap();
                },
            ));
            results.push(bench_cfg(
                &format!("xla    eval(1024) {model}"),
                warmup,
                secs,
                Some((1024.0, "samples")),
                &mut || {
                    std::hint::black_box(xla.evaluate(&params, &val).unwrap());
                },
            ));
        } else {
            println!("(artifacts missing — run `make artifacts` for XLA numbers)");
        }
    }

    if let Some(dir) = args.get("out-dir") {
        let path = format!("{dir}/BENCH_engine.json");
        write_bench_json(&path, "engine_step", &results).unwrap();
        println!("wrote {path}");
    }
}

//! Engine bench: XLA (AOT artifact via PJRT) vs native Rust train-step and
//! eval latency (EXPERIMENTS.md §Perf L2). This quantifies the cost of a
//! single simulated client step — the dominant term of every experiment.

use quafl::data::{SynthFamily, SynthSpec};
use quafl::engine::{NativeEngine, TrainEngine, XlaEngine};
use quafl::model::ModelSpec;
use quafl::testing::bench::bench_units;

fn main() {
    println!("== bench_engine ==");
    let (train, val) = SynthSpec::family(SynthFamily::Mnist, 2048, 1024, 1).generate();
    let idx: Vec<usize> = (0..32).collect();
    let batch = train.gather_batch(&idx);

    for model in ["mlp", "mlp_deep"] {
        let spec = ModelSpec::by_name(model).unwrap();
        let mut params = spec.init_params(3);

        let mut native = NativeEngine::new(spec.clone(), 32);
        bench_units(&format!("native train_step {model}"), 32.0, "samples", || {
            native.train_step(&mut params, &batch, 0.01).unwrap();
        });
        bench_units(&format!("native eval(1024) {model}"), 1024.0, "samples", || {
            std::hint::black_box(native.evaluate(&params, &val).unwrap());
        });

        if std::path::Path::new("artifacts/meta.json").exists() {
            let mut xla = XlaEngine::new("artifacts", &spec).unwrap();
            bench_units(&format!("xla    train_step {model}"), 32.0, "samples", || {
                xla.train_step(&mut params, &batch, 0.01).unwrap();
            });
            bench_units(&format!("xla    eval(1024) {model}"), 1024.0, "samples", || {
                std::hint::black_box(xla.evaluate(&params, &val).unwrap());
            });
        } else {
            println!("(artifacts missing — run `make artifacts` for XLA numbers)");
        }
    }
}

//! End-to-end throughput bench (EXPERIMENTS.md §Perf headline): server
//! rounds/second for the full QuAFL system on both engines, and scaling
//! in n and s. This is the number a deployment would size against.

use quafl::config::ExperimentConfig;
use quafl::coordinator;
use quafl::testing::bench::bench_units;

fn main() {
    println!("== bench_e2e ==");
    let base = ExperimentConfig {
        n: 20,
        s: 5,
        k: 10,
        rounds: 10,
        // Pin one worker so the rows measure serial per-round cost and stay
        // comparable across machines; bench_round owns the workers sweep.
        workers: 1,
        eval_every: 1_000_000,
        train_samples: 2000,
        val_samples: 256,
        ..Default::default()
    };

    bench_units("e2e quafl native (n=20 s=5)", 10.0, "rounds", || {
        std::hint::black_box(coordinator::run(&base).unwrap());
    });

    if std::path::Path::new("artifacts/meta.json").exists() {
        let cfg = ExperimentConfig { use_xla: true, ..base.clone() };
        bench_units("e2e quafl xla    (n=20 s=5)", 10.0, "rounds", || {
            std::hint::black_box(coordinator::run(&cfg).unwrap());
        });
    }

    // Scaling in fleet size (per-round work is s·K steps, not n).
    for (n, s) in [(50usize, 10usize), (100, 10), (300, 30)] {
        let cfg = ExperimentConfig {
            n,
            s,
            rounds: 5,
            train_samples: n * 40,
            ..base.clone()
        };
        bench_units(&format!("e2e quafl native (n={n} s={s})"), 5.0, "rounds", || {
            std::hint::black_box(coordinator::run(&cfg).unwrap());
        });
    }
}

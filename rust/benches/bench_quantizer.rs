//! Quantizer hot-path bench (EXPERIMENTS.md §Perf L3-a).
//!
//! Claim tied to: compression must be negligible next to a local SGD step
//! (a native mlp train step is ~1.5 ms; see bench_engine). Reports
//! encode/decode latency and MB/s for both quantizer families at the real
//! model dims (d = 25,450 for `mlp`, 235,146 for `mlp_deep`).

use quafl::quant::{IdentityQuantizer, LatticeQuantizer, QsgdQuantizer, Quantizer};
use quafl::testing::bench::bench_units;
use quafl::util::rng::Rng;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal() as f32).collect()
}

fn main() {
    println!("== bench_quantizer ==");
    for &d in &[25_450usize, 235_146] {
        let x = randvec(d, 1);
        let key: Vec<f32> = x.iter().map(|v| v + 0.001).collect();
        let bytes = (d * 4) as f64;

        let lat = LatticeQuantizer::new(10, 1e-4);
        let mut seed = 0u64;
        bench_units(&format!("lattice10 encode d={d}"), bytes, "B", || {
            seed += 1;
            std::hint::black_box(lat.encode(&x, seed));
        });
        let msg = lat.encode(&x, 42);
        bench_units(&format!("lattice10 decode d={d}"), bytes, "B", || {
            std::hint::black_box(lat.decode(&msg, &key));
        });

        let qs = QsgdQuantizer::new(10);
        bench_units(&format!("qsgd10    encode d={d}"), bytes, "B", || {
            seed += 1;
            std::hint::black_box(qs.encode(&x, seed));
        });
        let qmsg = qs.encode(&x, 42);
        bench_units(&format!("qsgd10    decode d={d}"), bytes, "B", || {
            std::hint::black_box(qs.decode(&qmsg, &key));
        });

        let id = IdentityQuantizer;
        bench_units(&format!("identity  encode d={d}"), bytes, "B", || {
            std::hint::black_box(id.encode(&x, 0));
        });
    }
}

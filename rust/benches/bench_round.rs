//! Round-orchestration bench (EXPERIMENTS.md §Perf L3-b): one full QuAFL
//! server round vs one FedAvg round, and the L3-only overhead (averaging +
//! quantization + sampling with the engine swapped for a no-op model) —
//! the claim is that the coordinator is NOT the bottleneck: its share of a
//! round must be small next to the client SGD steps.
//!
//! Flags (after `cargo bench --bench bench_round --`):
//!   --smoke         clamp fleet sizes/rounds and shorten sampling (CI)
//!   --out-dir DIR   write DIR/BENCH_round.json (canonical {bench, rows})

use std::sync::Arc;

use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind};
use quafl::coordinator;
use quafl::engine::KernelKind;
use quafl::exec::{ClientTask, EngineFactory, EnginePool};
use quafl::model::params;
use quafl::quant::{LatticeQuantizer, Quantizer};
use quafl::testing::bench::{bench_cfg, write_bench_json, BenchResult};
use quafl::util::cli;
use quafl::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse_with_bool_flags(&argv, &["smoke"]);
    let smoke = args.bool("smoke");
    let (warmup, secs) = if smoke { (1, 0.05) } else { (3, 1.0) };

    println!("== bench_round ==");
    let mut results: Vec<BenchResult> = Vec::new();

    // Full end-to-end rounds (engine included), per algorithm.
    let e2e_rounds = if smoke { 2 } else { 10 };
    for algo in [Algorithm::QuAFL, Algorithm::FedAvg, Algorithm::FedBuff] {
        let cfg = ExperimentConfig {
            algorithm: algo,
            n: 20,
            s: 5,
            k: 10,
            rounds: e2e_rounds,
            workers: 1,
            eval_every: 1_000_000, // never evaluate inside the bench
            train_samples: 2000,
            val_samples: 256,
            ..Default::default()
        };
        results.push(bench_cfg(
            &format!(
                "{} {e2e_rounds} rounds (n=20 s=5 K=10, engine incl)",
                algo.name()
            ),
            warmup,
            secs,
            Some((e2e_rounds as f64, "rounds")),
            &mut || {
                std::hint::black_box(coordinator::run(&cfg).unwrap());
            },
        ));
    }

    // Parallel client-execution scaling (§exec): QuAFL at the paper's
    // large-fleet scale (n=300, s=32) across worker counts. Trajectories
    // are bit-identical across rows; only wall-clock changes. The
    // acceptance target is >= 2x speedup at workers=8 vs workers=1.
    // Smoke keeps the two endpoint rows at a reduced fleet so the
    // artifact still carries a serial-vs-parallel pair.
    let worker_rows: &[usize] = if smoke { &[1, 8] } else { &[1, 2, 4, 8] };
    let (scale_n, scale_s, scale_samples) =
        if smoke { (60, 8, 1200) } else { (300, 32, 6000) };
    for &workers in worker_rows {
        let cfg = ExperimentConfig {
            algorithm: Algorithm::QuAFL,
            n: scale_n,
            s: scale_s,
            k: 10,
            rounds: 2,
            workers,
            eval_every: 1_000_000,
            train_samples: scale_samples,
            val_samples: 256,
            ..Default::default()
        };
        results.push(bench_cfg(
            &format!(
                "quafl scaling n={scale_n} s={scale_s} K=10 workers={workers} (2 rounds)"
            ),
            warmup,
            secs,
            Some((2.0, "rounds")),
            &mut || {
                std::hint::black_box(coordinator::run(&cfg).unwrap());
            },
        ));
    }

    // Fan-out overhead at large s (§exec persistent pool): dispatch s
    // no-op tasks through the pool and measure the pure orchestration
    // cost. With the long-lived workers this is channel send/recv only —
    // the per-round thread-spawn cost the scoped-thread implementation
    // paid at s >= 100 is gone (compare a row against the workers=1
    // serial loop: the gap is the entire fan-out overhead budget).
    for (s, workers) in [(128usize, 1usize), (128, 8), (256, 8)] {
        let mut pool = EnginePool::new(
            EngineFactory::new("mlp", false, "artifacts", 32, KernelKind::default()),
            workers,
        )
        .unwrap();
        // Warm the worker threads/engines outside the timed region.
        let warm: Vec<ClientTask> = (0..s)
            .map(|i| ClientTask {
                client_id: i,
                params: Arc::new(Vec::new()),
                batches: Vec::new(),
                lr: 0.1,
                seed: 0,
            })
            .collect();
        pool.run_local_sgd(warm).unwrap();
        results.push(bench_cfg(
            &format!("fan-out overhead s={s} workers={workers} (no-op tasks)"),
            warmup,
            secs,
            Some((s as f64, "tasks")),
            &mut || {
                let tasks: Vec<ClientTask> = (0..s)
                    .map(|i| ClientTask {
                        client_id: i,
                        params: Arc::new(Vec::new()),
                        batches: Vec::new(),
                        lr: 0.1,
                        seed: 0,
                    })
                    .collect();
                std::hint::black_box(pool.run_local_sgd(tasks).unwrap());
            },
        ));
    }

    // Fleet-store memory (§fleet): peak resident client-model bytes at
    // huge fleet scale, CoW vs the dense O(n·d) footprint the eager
    // layout allocated up front. The CoW peak is O(touched·d) with
    // touched <= s·rounds (+ shared bases), demonstrating the
    // acceptance target: an n=10⁴/s=30 run's resident model bytes are
    // O(s + touched), not O(n). The dense column is analytic (n·d·4) —
    // actually allocating it is exactly what the store avoids. This
    // section is a one-shot measurement, not a timed BenchResult, so it
    // stays console-only and out of BENCH_round.json.
    let fleet_n = if smoke { 1_000 } else { 10_000 };
    for algo in [Algorithm::QuAFL, Algorithm::FedBuff] {
        let n = fleet_n;
        let s = 30;
        let rounds = 3;
        let cfg = ExperimentConfig {
            algorithm: algo,
            n,
            s,
            k: 5,
            rounds,
            workers: 4,
            eval_every: 1_000_000,
            train_samples: n,
            val_samples: 256,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let m = coordinator::run(&cfg).unwrap();
        let d = quafl::model::ModelSpec::by_name(&cfg.model)
            .unwrap()
            .num_params();
        let model_bytes = (d * 4) as u64;
        let dense_bytes = n as u64 * model_bytes;
        let peak = m.peak_model_bytes();
        println!(
            "fleet memory {} n={n} s={s} rounds={rounds}: peak_model_bytes={peak} \
             ({:.2} MB, ~{:.1} models) vs dense {dense_bytes} ({:.0} MB, {n} models) \
             => {:.0}x smaller  [{:.1}s wall]",
            algo.name(),
            peak as f64 / 1e6,
            peak as f64 / model_bytes as f64,
            dense_bytes as f64 / 1e6,
            dense_bytes as f64 / peak.max(1) as f64,
            t0.elapsed().as_secs_f64(),
        );
    }

    // L3-only cost of the QuAFL server update path at model scale:
    // quantize s models, decode s models, weighted-average (engine
    // excluded). Compare against bench_engine's ~per-step cost x s x K.
    let d = 25_450;
    let mut rng = Rng::new(7);
    let x_server: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let clients: Vec<Vec<f32>> = (0..5)
        .map(|_| x_server.iter().map(|v| v + 0.001).collect())
        .collect();
    let q = LatticeQuantizer::new(10, 1e-4);
    let mut seed = 0u64;
    results.push(bench_cfg(
        "quafl L3-only round update (s=5, d=25450)",
        warmup,
        secs,
        None,
        &mut || {
            seed += 1;
            let enc_x = q.encode(&x_server, seed);
            let mut sum = vec![0f32; d];
            for c in &clients {
                let enc_y = q.encode(c, seed ^ 0x99);
                let qy = q.decode(&enc_y, &x_server);
                params::axpy(&mut sum, 1.0, &qy);
                std::hint::black_box(q.decode(&enc_x, c));
            }
            let mut xs = x_server.clone();
            params::scale(&mut xs, 1.0 / 6.0);
            params::axpy(&mut xs, 1.0 / 6.0, &sum);
            std::hint::black_box(xs);
        },
    ));

    // Identity path (fp32) for reference — isolates quantizer cost.
    let qn = QuantizerKind::None;
    let _ = qn;
    results.push(bench_cfg(
        "quafl L3-only round update, fp32 (s=5, d=25450)",
        warmup,
        secs,
        None,
        &mut || {
            let mut sum = vec![0f32; d];
            for c in &clients {
                params::axpy(&mut sum, 1.0, c);
            }
            let mut xs = x_server.clone();
            params::scale(&mut xs, 1.0 / 6.0);
            params::axpy(&mut xs, 1.0 / 6.0, &sum);
            std::hint::black_box(xs);
        },
    ));

    if let Some(dir) = args.get("out-dir") {
        let path = format!("{dir}/BENCH_round.json");
        write_bench_json(&path, "round_orchestration", &results).unwrap();
        println!("wrote {path}");
    }
}

//! End-to-end algorithm behaviour on the native engine (fast, hermetic):
//! every protocol converges on the easy family, the paper's qualitative
//! orderings hold, and the bit accounting matches the quantizer math.

use quafl::config::{
    Algorithm, AveragingMode, ExperimentConfig, QuantizerKind, TimingConfig,
};
use quafl::coordinator;
use quafl::data::PartitionKind;

fn base() -> ExperimentConfig {
    ExperimentConfig {
        n: 10,
        s: 4,
        k: 6,
        rounds: 40,
        eval_every: 40,
        train_samples: 1500,
        val_samples: 512,
        seed: 5,
        ..Default::default()
    }
}

#[test]
fn quafl_converges_iid() {
    let m = coordinator::run(&base()).unwrap();
    assert!(m.final_acc() > 0.9, "acc={}", m.final_acc());
    assert!(m.final_loss() < 0.5, "loss={}", m.final_loss());
}

#[test]
fn fedavg_converges_iid() {
    let cfg = ExperimentConfig {
        algorithm: Algorithm::FedAvg,
        quantizer: QuantizerKind::None,
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.9, "acc={}", m.final_acc());
}

#[test]
fn fedbuff_converges_iid() {
    let cfg = ExperimentConfig {
        algorithm: Algorithm::FedBuff,
        quantizer: QuantizerKind::None,
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.9, "acc={}", m.final_acc());
}

#[test]
fn baseline_converges() {
    let cfg = ExperimentConfig {
        algorithm: Algorithm::Baseline,
        rounds: 400,
        eval_every: 400,
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.9, "acc={}", m.final_acc());
}

#[test]
fn quafl_converges_non_iid_with_slow_clients() {
    // The headline robustness claim: by-class data + 30% slow clients.
    let cfg = ExperimentConfig {
        partition: PartitionKind::ByClass,
        rounds: 80,
        eval_every: 80,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.6, "non-iid acc={}", m.final_acc());
    // Some interactions must show partial/zero progress (asynchrony real).
    assert!(m.mean_observed_steps() < cfg.k as f64);
}

#[test]
fn quafl_wallclock_beats_fedavg_at_same_accuracy() {
    // Figure 3/11's claim: in simulated time, non-blocking QuAFL reaches a
    // given accuracy earlier than synchronous FedAvg when slow clients
    // exist (FedAvg pays max-of-K-steps every round).
    let mut quafl_cfg = ExperimentConfig {
        rounds: 60,
        eval_every: 5,
        ..base()
    };
    quafl_cfg.timing.slow_fraction = 0.3;
    let quafl_m = coordinator::run(&quafl_cfg).unwrap();
    let fedavg_cfg = ExperimentConfig {
        algorithm: Algorithm::FedAvg,
        quantizer: QuantizerKind::None,
        ..quafl_cfg
    };
    let fedavg_m = coordinator::run(&fedavg_cfg).unwrap();
    let target = 0.9;
    let tq = quafl_m.time_to_accuracy(target);
    let tf = fedavg_m.time_to_accuracy(target);
    assert!(tq.is_some(), "quafl never hit {target}");
    if let (Some(tq), Some(tf)) = (tq, tf) {
        assert!(
            tq < tf,
            "quafl time-to-acc {tq} should beat fedavg {tf}"
        );
    }
}

#[test]
fn quantized_quafl_tracks_fp32_within_tolerance() {
    // ≥3x compression without significant loss (Figure 2's claim):
    // lattice b=10 final accuracy within 5 points of fp32.
    let fp = coordinator::run(&ExperimentConfig {
        quantizer: QuantizerKind::None,
        ..base()
    })
    .unwrap();
    let q10 = coordinator::run(&ExperimentConfig {
        quantizer: QuantizerKind::Lattice { bits: 10 },
        ..base()
    })
    .unwrap();
    assert!(
        q10.final_acc() > fp.final_acc() - 0.05,
        "b10 {} vs fp32 {}",
        q10.final_acc(),
        fp.final_acc()
    );
    // And it must actually deliver the paper's >3x compression.
    let ratio = fp.total_bits() as f64 / q10.total_bits() as f64;
    assert!(ratio > 3.0, "compression ratio {ratio}");
}

#[test]
fn bits_accounting_matches_quantizer_math() {
    let cfg = ExperimentConfig {
        rounds: 4,
        eval_every: 4,
        quantizer: QuantizerKind::Lattice { bits: 10 },
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    let d = quafl::quant::lattice::padded_dim(25_450); // mlp wire dim
    let per_msg = (d * 10 + 32 + 64) as u64;
    // Per round: s uplinks + s downlinks.
    let expect = per_msg * (cfg.s as u64) * 2 * cfg.rounds as u64;
    assert_eq!(m.total_bits(), expect);
}

#[test]
fn averaging_ablation_runs_all_modes() {
    for mode in [
        AveragingMode::Both,
        AveragingMode::ServerOnly,
        AveragingMode::ClientOnly,
    ] {
        let cfg = ExperimentConfig { averaging: mode, rounds: 10, ..base() };
        let m = coordinator::run(&cfg).unwrap();
        assert!(m.final_loss().is_finite(), "{mode:?}");
    }
}

#[test]
fn weighted_variant_runs_and_converges() {
    let cfg = ExperimentConfig {
        weighted: true,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.85, "weighted acc={}", m.final_acc());
}

#[test]
fn deterministic_given_seed() {
    let a = coordinator::run(&base()).unwrap();
    let b = coordinator::run(&base()).unwrap();
    assert_eq!(a.final_acc(), b.final_acc());
    assert_eq!(a.total_bits(), b.total_bits());
    let c = coordinator::run(&ExperimentConfig { seed: 6, ..base() }).unwrap();
    assert_ne!(a.final_loss(), c.final_loss());
}

#[test]
fn quantization_degradation_quafl_not_worse_than_fedbuff() {
    // Figure 16's transferable claim: adding quantization costs QuAFL
    // (position-aware lattice) no more accuracy than it costs FedBuff
    // (norm-scaled QSGD on updates), in the non-iid heterogeneous-speed
    // setting. (Absolute cross-algorithm accuracy depends on compute
    // budget; the quantization *interaction* is the invariant.)
    let common = ExperimentConfig {
        partition: PartitionKind::ByClass,
        family: quafl::data::SynthFamily::Hard,
        rounds: 60,
        eval_every: 60,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..base()
    };
    let run = |algo: Algorithm, quant: QuantizerKind| {
        coordinator::run(&ExperimentConfig {
            algorithm: algo,
            quantizer: quant,
            ..common.clone()
        })
        .unwrap()
        .final_acc()
    };
    let q_fp = run(Algorithm::QuAFL, QuantizerKind::None);
    let q_lat = run(Algorithm::QuAFL, QuantizerKind::Lattice { bits: 10 });
    let f_fp = run(Algorithm::FedBuff, QuantizerKind::None);
    let f_qsgd = run(Algorithm::FedBuff, QuantizerKind::Qsgd { bits: 10 });
    let quafl_cost = q_fp - q_lat;
    let fedbuff_cost = f_fp - f_qsgd;
    assert!(
        quafl_cost <= fedbuff_cost + 0.03,
        "quantization cost: quafl {quafl_cost:.4} (fp {q_fp:.3} -> {q_lat:.3}) \
         vs fedbuff {fedbuff_cost:.4} (fp {f_fp:.3} -> {f_qsgd:.3})"
    );
    // Quantized QuAFL must remain close to its fp32 self (lattice works).
    assert!(quafl_cost < 0.05, "lattice cost too high: {quafl_cost}");
}

#[test]
fn potential_stays_bounded_lemma_3_4() {
    // Empirical check of Lemma 3.4's contraction: the potential
    // Φ_t = ‖X_t − μ_t‖² + Σ‖Xⁱ − μ_t‖² must stay bounded over a run —
    // it cannot grow without bound even under non-iid data and slow
    // clients. We check the last-quarter max is not larger than the
    // overall max (no late-run blowup) and that all values are finite.
    let cfg = ExperimentConfig {
        partition: PartitionKind::ByClass,
        rounds: 60,
        eval_every: 60,
        track_potential: true,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert_eq!(m.potential.len(), cfg.rounds);
    assert!(m.potential.iter().all(|p| p.is_finite() && *p >= 0.0));
    let overall_max = m.potential.iter().cloned().fold(0.0, f64::max);
    let tail_max = m.potential[45..].iter().cloned().fold(0.0, f64::max);
    assert!(
        tail_max <= overall_max * 1.01,
        "potential blew up late: tail {tail_max} vs max {overall_max}"
    );
    // And it should be small relative to the model norm scale (~O(1)).
    assert!(overall_max < 100.0, "potential too large: {overall_max}");
}

#[test]
fn failure_injection_truncated_message_panics_not_corrupts() {
    // A truncated payload must fail loudly (BitReader overrun), never
    // silently decode garbage.
    use quafl::quant::{LatticeQuantizer, Quantizer};
    let q = LatticeQuantizer::new(8, 0.01);
    let x = vec![0.5f32; 100];
    let mut msg = q.encode(&x, 1);
    msg.payload.truncate(msg.payload.len() / 2);
    let res = std::panic::catch_unwind(|| q.decode(&msg, &x));
    assert!(res.is_err(), "truncated decode must panic");
}

#[test]
fn failure_injection_wrong_key_dimension_panics() {
    use quafl::quant::{LatticeQuantizer, Quantizer};
    let q = LatticeQuantizer::new(8, 0.01);
    let x = vec![0.5f32; 64];
    let msg = q.encode(&x, 1);
    let bad_key = vec![0.5f32; 32];
    let res = std::panic::catch_unwind(|| q.decode(&msg, &bad_key));
    assert!(res.is_err());
}

#[test]
fn dirichlet_partition_end_to_end() {
    let cfg = ExperimentConfig {
        partition: PartitionKind::Dirichlet(0.3),
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.7, "dirichlet acc={}", m.final_acc());
}

#[test]
fn single_client_degenerates_to_local_sgd() {
    // n = s = 1: QuAFL degenerates to (interrupted) local SGD with
    // averaging weight 1/2 — must still converge.
    let cfg = ExperimentConfig {
        n: 1,
        s: 1,
        rounds: 80,
        eval_every: 80,
        quantizer: QuantizerKind::None,
        ..base()
    };
    let m = coordinator::run(&cfg).unwrap();
    assert!(m.final_acc() > 0.85, "acc={}", m.final_acc());
}

#[test]
fn zero_progress_interactions_are_observed_with_high_poll_rate() {
    // Poll fast (small swt) so slow clients often have H=0 — the paper's
    // robustness scenario.
    let mut cfg = base();
    cfg.timing.swt = 1.0;
    cfg.timing.slow_fraction = 0.5;
    cfg.rounds = 60;
    cfg.eval_every = 60;
    let m = coordinator::run(&cfg).unwrap();
    assert!(
        m.zero_progress_fraction() > 0.05,
        "expected zero-progress interactions, got {}",
        m.zero_progress_fraction()
    );
    // ...and the run must still converge despite them.
    assert!(m.final_acc() > 0.8, "acc={}", m.final_acc());
}

//! Property tests over the quantizers (proptest substitute: the in-crate
//! mini harness `quafl::testing`). These encode Lemma 3.1's guarantees:
//! unbiasedness, bounded error, decodability within the model-distance
//! radius, and exact bit accounting — across randomized dims, scales,
//! bit-widths and seeds.

use quafl::prop_assert;
use quafl::quant::lattice::padded_dim;
use quafl::quant::{
    lattice_gamma_for, IdentityQuantizer, LatticeQuantizer, QsgdQuantizer,
    Quantizer,
};
use quafl::testing::{check, PropConfig};
use quafl::util::rng::Rng;
use quafl::util::stats::{l2_dist, l2_norm};

fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

#[test]
fn prop_lattice_error_bound_self_key() {
    // ‖Q(x) − x‖ ≤ γ·√d′ when decoding against x itself (Lemma 3.1 (2)).
    check(
        "lattice_error_bound",
        PropConfig { cases: 40, max_size: 3000, ..Default::default() },
        |rng, size| {
            let bits = 4 + (rng.gen_range(8)) as u8;
            let gamma = 10f32.powi(rng.gen_range(5) as i32 - 4);
            let q = LatticeQuantizer::new(bits, gamma);
            let x = randvec(rng, size, 1.0);
            let y = q.decode(&q.encode(&x, rng.next_u64()), &x);
            let bound = gamma as f64 * (padded_dim(size) as f64).sqrt();
            let err = l2_dist(&x, &y);
            prop_assert!(
                err <= bound + 1e-6,
                "err {err} > bound {bound} (bits={bits} gamma={gamma} d={size})"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_lattice_decodes_within_radius() {
    // If ‖x − key‖ ≤ dist and γ = lattice_gamma_for(dist, ...), decoding
    // recovers the encoder's grid point: error stays ≤ γ√d′ even though
    // the key differs from x (position-aware decoding).
    check(
        "lattice_radius",
        PropConfig { cases: 30, max_size: 4096, ..Default::default() },
        |rng, size| {
            let size = size.max(8);
            let bits = 6 + (rng.gen_range(7)) as u8;
            let dist = 0.01 + rng.next_f64() * 2.0;
            let gamma = lattice_gamma_for(dist, bits, size);
            let q = LatticeQuantizer::new(bits, gamma);
            let x = randvec(rng, size, 1.0);
            let dir = randvec(rng, size, 1.0);
            let dn = l2_norm(&dir).max(1e-12);
            let key: Vec<f32> = x
                .iter()
                .zip(&dir)
                .map(|(v, d)| v + d * (dist / dn) as f32)
                .collect();
            let y = q.decode(&q.encode(&x, rng.next_u64()), &key);
            let bound = gamma as f64 * (padded_dim(size) as f64).sqrt();
            let err = l2_dist(&x, &y);
            prop_assert!(
                err <= bound * 1.01 + 1e-6,
                "err {err} > {bound} (bits={bits} dist={dist:.3} d={size})"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_lattice_bits_exact() {
    check(
        "lattice_bits",
        PropConfig { cases: 20, max_size: 5000, ..Default::default() },
        |rng, size| {
            let bits = 2 + (rng.gen_range(12)) as u8;
            let q = LatticeQuantizer::new(bits, 0.01);
            let x = randvec(rng, size, 1.0);
            let msg = q.encode(&x, 1);
            let expect = padded_dim(size) * bits as usize + 32 + 64;
            prop_assert!(
                msg.bits == expect,
                "bits {} != {expect} (b={bits}, d={size})",
                msg.bits
            );
            prop_assert!(
                msg.payload.len() * 8 >= msg.bits - 64,
                "payload shorter than bit count"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_qsgd_error_bound() {
    check(
        "qsgd_error",
        PropConfig { cases: 40, max_size: 4000, ..Default::default() },
        |rng, size| {
            let bits = 2 + (rng.gen_range(10)) as u8;
            let q = QsgdQuantizer::new(bits);
            let scale = 10f32.powi(rng.gen_range(5) as i32 - 2);
            let x = randvec(rng, size, scale);
            let y = q.decode(&q.encode(&x, rng.next_u64()), &x);
            let s = ((1u32 << (bits - 1)) - 1) as f64;
            let bound = l2_norm(&x) * (size as f64).sqrt() / s;
            let err = l2_dist(&x, &y);
            prop_assert!(err <= bound + 1e-6, "err {err} > bound {bound}");
            Ok(())
        },
    );
}

#[test]
fn prop_all_quantizers_unbiased_small_dim() {
    // Mean of repeated encodes ≈ x (stochastic rounding unbiasedness).
    check(
        "unbiased",
        PropConfig { cases: 6, max_size: 48, ..Default::default() },
        |rng, size| {
            let size = size.max(4);
            let x = randvec(rng, size, 1.0);
            let qs: Vec<Box<dyn Quantizer>> = vec![
                Box::new(LatticeQuantizer::new(5, 0.1)),
                Box::new(QsgdQuantizer::new(4)),
            ];
            for q in qs {
                let trials = 500u64;
                let mut acc = vec![0f64; size];
                for t in 0..trials {
                    let y = q.decode(&q.encode(&x, rng.next_u64() ^ t), &x);
                    for (a, v) in acc.iter_mut().zip(&y) {
                        *a += *v as f64;
                    }
                }
                let mean: Vec<f32> =
                    acc.iter().map(|a| (*a / trials as f64) as f32).collect();
                let bias = l2_dist(&mean, &x);
                let tol = 0.05 * (size as f64).sqrt().max(1.0)
                    * l2_norm(&x).max(1.0)
                    / (trials as f64).sqrt()
                    * 10.0;
                prop_assert!(
                    bias < tol.max(0.05),
                    "{}: bias {bias} > {tol}",
                    q.name()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_identity_lossless_any_values() {
    check(
        "identity_lossless",
        PropConfig { cases: 20, max_size: 2000, ..Default::default() },
        |rng, size| {
            let q = IdentityQuantizer;
            let x: Vec<f32> = (0..size)
                .map(|_| f32::from_bits(rng.next_u32()))
                .map(|v| if v.is_nan() { 0.0 } else { v })
                .collect();
            let y = q.decode(&q.encode(&x, 0), &x);
            prop_assert!(
                x.iter().zip(&y).all(|(a, b)| a.to_bits() == b.to_bits()),
                "identity not bit-exact"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_lattice_roundtrip_through_rotation_seeds() {
    // Decoding with the wrong seed must NOT give the right answer (the
    // rotation is part of the shared randomness contract).
    check(
        "lattice_seed_contract",
        PropConfig { cases: 10, max_size: 512, ..Default::default() },
        |rng, size| {
            let size = size.max(64);
            let q = LatticeQuantizer::new(8, 0.01);
            let x = randvec(rng, size, 1.0);
            let mut msg = q.encode(&x, 42);
            let good = q.decode(&msg, &x);
            msg.seed = 43; // tamper
            let bad = q.decode(&msg, &x);
            let egood = l2_dist(&good, &x);
            let ebad = l2_dist(&bad, &x);
            prop_assert!(
                ebad > egood * 10.0,
                "wrong-seed decode suspiciously good: {ebad} vs {egood}"
            );
            Ok(())
        },
    );
}

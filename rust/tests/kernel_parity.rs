//! Parity suite for the GEMM kernel subsystem (rust/src/engine/kernel):
//!
//! 1. **Per-op bit-identity.** For every shape in a ragged grid (sizes
//!    that do and don't divide the 4×8 tiles) and randomized inputs
//!    seeded with the zeros/negatives ReLU produces, the blocked kernel's
//!    forward/backward_data/update outputs must equal the scalar
//!    kernel's to the bit.
//! 2. **Engine-level identity.** `train_step` trajectories under scalar
//!    and blocked engines match bit for bit, on the zoo `mlp` and on a
//!    ragged ad-hoc spec.
//! 3. **Whole-run identity.** Full QuAFL/FedAvg/FedBuff runs with
//!    `--engine-kernel blocked` reproduce the scalar runs' metrics
//!    exactly (`assert_identical` — the same notion of "identical
//!    trajectory" every other parity suite uses).
//! 4. **SIMD.** With `--features simd`: approximate parity (relative
//!    error bound — FMA changes rounding, bit-identity is out of scope by
//!    design). Without: the kind parses but refuses to instantiate or
//!    validate.

mod common;

use std::sync::Arc;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig};
use quafl::coordinator;
use quafl::data::{SynthFamily, SynthSpec};
use quafl::engine::kernel::{blocked::BlockedKernel, scalar::ScalarKernel};
use quafl::engine::{KernelKind, KernelStats, MatmulKernel, NativeEngine, TrainEngine};
use quafl::model::ModelSpec;
use quafl::util::rng::Rng;

/// (b, fan_in, fan_out) grid: tile-aligned, sub-tile, and ragged shapes
/// (b % 4, fan_in % 4, fan_out % 8 all exercised as nonzero).
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 3, 5),
    (4, 8, 8),
    (5, 7, 13),
    (8, 16, 10),
    (3, 17, 9),
    (6, 32, 8),
    (9, 5, 24),
    (4, 4, 7),
    (7, 12, 32),
];

/// Random operand in [-1, 1) with exact 0.0 injected at rate ~1/4 and the
/// sign mix ReLU feeds the kernels (zeros from masked activations are the
/// branch-sensitive case — see the contract in engine/kernel docs).
fn operand(rng: &mut Rng, n: usize, zero_rate: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.next_f64() < zero_rate {
                0.0
            } else {
                (rng.uniform(-1.0, 1.0)) as f32
            }
        })
        .collect()
}

#[test]
fn blocked_forward_bit_identical_on_ragged_shapes() {
    let mut rng = Rng::new(0xF0);
    for &(b, fi, fo) in SHAPES {
        let inp = operand(&mut rng, b * fi, 0.25);
        let w = operand(&mut rng, fi * fo, 0.0);
        let bias = operand(&mut rng, fo, 0.0);
        let mut out_s = vec![0f32; b * fo];
        let mut out_b = vec![99f32; b * fo];
        ScalarKernel.forward(&inp, &w, &bias, &mut out_s, b, fi, fo);
        BlockedKernel.forward(&inp, &w, &bias, &mut out_b, b, fi, fo);
        for (i, (x, y)) in out_s.iter().zip(&out_b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "forward ({b},{fi},{fo}) elem {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn blocked_backward_data_bit_identical_on_ragged_shapes() {
    let mut rng = Rng::new(0xF1);
    for &(b, fi, fo) in SHAPES {
        let d = operand(&mut rng, b * fo, 0.0);
        let w = operand(&mut rng, fi * fo, 0.0);
        // act is post-ReLU: non-negative, with masked (0.0) entries.
        let act: Vec<f32> = operand(&mut rng, b * fi, 0.4)
            .into_iter()
            .map(f32::abs)
            .collect();
        let mut dp_s = vec![0f32; b * fi];
        let mut dp_b = vec![99f32; b * fi];
        ScalarKernel.backward_data(&d, &w, &act, &mut dp_s, b, fi, fo);
        BlockedKernel.backward_data(&d, &w, &act, &mut dp_b, b, fi, fo);
        for (i, (x, y)) in dp_s.iter().zip(&dp_b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "backward ({b},{fi},{fo}) elem {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn blocked_update_bit_identical_on_ragged_shapes() {
    let mut rng = Rng::new(0xF2);
    for &(b, fi, fo) in SHAPES {
        let a: Vec<f32> = operand(&mut rng, b * fi, 0.4)
            .into_iter()
            .map(f32::abs)
            .collect();
        let d = operand(&mut rng, b * fo, 0.0);
        let w0 = operand(&mut rng, fi * fo, 0.0);
        let bias0 = operand(&mut rng, fo, 0.0);
        let (mut w_s, mut bias_s) = (w0.clone(), bias0.clone());
        let (mut w_b, mut bias_b) = (w0, bias0);
        ScalarKernel.update(&a, &d, &mut w_s, &mut bias_s, 0.05, b, fi, fo);
        BlockedKernel.update(&a, &d, &mut w_b, &mut bias_b, 0.05, b, fi, fo);
        for (i, (x, y)) in w_s.iter().zip(&w_b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "update W ({b},{fi},{fo}) elem {i}: {x} vs {y}"
            );
        }
        for (i, (x, y)) in bias_s.iter().zip(&bias_b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "update bias ({b},{fi},{fo}) elem {i}: {x} vs {y}"
            );
        }
    }
}

/// Run `steps` SGD steps + one evaluation under the given kernel,
/// returning the final params and eval pair.
fn train_trajectory(
    spec: &ModelSpec,
    kind: KernelKind,
    family: SynthFamily,
    batch: usize,
    steps: usize,
) -> (Vec<f32>, (f64, f64)) {
    let mut engine = NativeEngine::with_kernel(
        spec.clone(),
        batch,
        kind,
        Arc::new(KernelStats::new()),
    )
    .unwrap();
    let (train, _) = SynthSpec::family(family, 256, 32, 17).generate();
    let mut params = spec.init_params(23);
    let mut rng = Rng::new(41);
    for _ in 0..steps {
        let idx: Vec<usize> = (0..batch).map(|_| rng.gen_range(train.len())).collect();
        let b = train.gather_batch(&idx);
        engine.train_step(&mut params, &b, 0.1).unwrap();
    }
    let eval = engine.evaluate(&params, &train).unwrap();
    (params, eval)
}

#[test]
fn engine_trajectories_bit_identical_scalar_vs_blocked() {
    // Zoo mlp (tile-friendly fan-outs) and a ragged ad-hoc spec whose
    // widths hit every remainder path.
    let specs = [
        ModelSpec::by_name("mlp").unwrap(),
        ModelSpec::new("ragged", vec![16, 13, 9, 10]),
    ];
    for spec in &specs {
        let fam = if spec.sizes[0] == 784 {
            SynthFamily::Mnist
        } else {
            SynthFamily::Tiny
        };
        // batch 7: not a multiple of the 4-row tile either.
        let (p_s, e_s) = train_trajectory(spec, KernelKind::Scalar, fam, 7, 25);
        let (p_b, e_b) = train_trajectory(spec, KernelKind::Blocked, fam, 7, 25);
        assert_eq!(p_s, p_b, "{}: params diverged", spec.name);
        assert_eq!(e_s.0.to_bits(), e_b.0.to_bits(), "{}: loss", spec.name);
        assert_eq!(e_s.1.to_bits(), e_b.1.to_bits(), "{}: acc", spec.name);
    }
}

fn run_cfg(algorithm: Algorithm, kernel: KernelKind) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 8,
        s: 3,
        k: 4,
        rounds: 8,
        eval_every: 4,
        train_samples: 256,
        val_samples: 64,
        batch: 16,
        seed: 77,
        workers: 2,
        engine_kernel: kernel,
        ..Default::default()
    }
}

#[test]
fn whole_runs_bit_identical_scalar_vs_blocked() {
    for algorithm in [Algorithm::QuAFL, Algorithm::FedAvg, Algorithm::FedBuff] {
        let scalar = coordinator::run(&run_cfg(algorithm, KernelKind::Scalar))
            .expect("scalar run");
        let blocked = coordinator::run(&run_cfg(algorithm, KernelKind::Blocked))
            .expect("blocked run");
        assert_identical(
            &scalar,
            &blocked,
            &format!("{algorithm:?} scalar vs blocked"),
        );
    }
}

#[cfg(not(feature = "simd"))]
#[test]
fn simd_kind_refused_without_feature() {
    assert!(!KernelKind::Simd.available());
    let err = KernelKind::Simd.instantiate().err().expect("must refuse");
    assert!(err.contains("--features simd"), "err: {err}");
    let cfg = run_cfg(Algorithm::QuAFL, KernelKind::Simd);
    let err = cfg.validate().err().expect("validate must refuse");
    assert!(err.contains("--features simd"), "err: {err}");
}

#[cfg(feature = "simd")]
mod simd_parity {
    use super::*;
    use quafl::engine::kernel::simd::SimdKernel;

    /// FMA reassociates nothing but rounds differently; elementwise
    /// relative error against scalar stays tiny.
    const REL_TOL: f32 = 1e-4;

    fn assert_close(xs: &[f32], ys: &[f32], what: &str) {
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            let denom = x.abs().max(y.abs()).max(1e-6);
            assert!(
                (x - y).abs() / denom <= REL_TOL,
                "{what} elem {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn simd_forward_approximately_matches_scalar() {
        let mut rng = Rng::new(0xA0);
        for &(b, fi, fo) in SHAPES {
            let inp = operand(&mut rng, b * fi, 0.25);
            let w = operand(&mut rng, fi * fo, 0.0);
            let bias = operand(&mut rng, fo, 0.0);
            let mut out_s = vec![0f32; b * fo];
            let mut out_v = vec![0f32; b * fo];
            ScalarKernel.forward(&inp, &w, &bias, &mut out_s, b, fi, fo);
            SimdKernel.forward(&inp, &w, &bias, &mut out_v, b, fi, fo);
            assert_close(&out_s, &out_v, &format!("forward ({b},{fi},{fo})"));
        }
    }

    #[test]
    fn simd_backward_and_update_approximately_match_scalar() {
        let mut rng = Rng::new(0xA1);
        for &(b, fi, fo) in SHAPES {
            let d = operand(&mut rng, b * fo, 0.0);
            let w = operand(&mut rng, fi * fo, 0.0);
            let act: Vec<f32> = operand(&mut rng, b * fi, 0.4)
                .into_iter()
                .map(f32::abs)
                .collect();
            let mut dp_s = vec![0f32; b * fi];
            let mut dp_v = vec![0f32; b * fi];
            ScalarKernel.backward_data(&d, &w, &act, &mut dp_s, b, fi, fo);
            SimdKernel.backward_data(&d, &w, &act, &mut dp_v, b, fi, fo);
            assert_close(&dp_s, &dp_v, &format!("backward ({b},{fi},{fo})"));

            let (mut w_s, mut bias_s) = (w.clone(), operand(&mut rng, fo, 0.0));
            let (mut w_v, mut bias_v) = (w.clone(), bias_s.clone());
            ScalarKernel.update(&act, &d, &mut w_s, &mut bias_s, 0.05, b, fi, fo);
            SimdKernel.update(&act, &d, &mut w_v, &mut bias_v, 0.05, b, fi, fo);
            assert_close(&w_s, &w_v, &format!("update W ({b},{fi},{fo})"));
            assert_close(&bias_s, &bias_v, &format!("update bias ({b},{fi},{fo})"));
        }
    }

    #[test]
    fn simd_training_converges_like_scalar() {
        // Not bit-exact, but the trajectory must be statistically sane:
        // same order of loss after the same steps.
        let spec = ModelSpec::by_name("mlp").unwrap();
        let (_, e_s) =
            train_trajectory(&spec, KernelKind::Scalar, SynthFamily::Mnist, 8, 40);
        let (_, e_v) =
            train_trajectory(&spec, KernelKind::Simd, SynthFamily::Mnist, 8, 40);
        assert!(
            (e_s.0 - e_v.0).abs() < 0.05 * e_s.0.abs() + 0.05,
            "loss diverged: scalar {} vs simd {}",
            e_s.0,
            e_v.0
        );
    }
}

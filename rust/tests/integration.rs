//! Integration over the runtime + AOT artifacts: loads the HLO text the
//! python compile path emitted, executes it via PJRT, and checks the
//! numerics against the native engine's math. Requires `make artifacts`.

use quafl::data::{SynthFamily, SynthSpec};
use quafl::engine::{NativeEngine, TrainEngine, XlaEngine};
use quafl::model::ModelSpec;
use quafl::runtime::Runtime;

const ARTIFACTS: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(ARTIFACTS).join("meta.json").exists()
}

#[test]
fn runtime_loads_meta_and_compiles_every_model() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(ARTIFACTS).unwrap();
    assert_eq!(rt.platform(), "cpu");
    assert!(rt.meta.models.contains_key("mlp"));
    for (name, m) in &rt.meta.models {
        let spec = ModelSpec::by_name(name).unwrap();
        assert_eq!(m.sizes, spec.sizes, "{name}");
        assert_eq!(m.num_params, spec.num_params(), "{name}");
        // Compiling must succeed for both artifacts.
        rt.compile(&m.train_step_file)
            .unwrap_or_else(|e| panic!("{name} train: {e:#}"));
        rt.compile(&m.eval_file)
            .unwrap_or_else(|e| panic!("{name} eval: {e:#}"));
    }
}

#[test]
fn xla_train_step_executes_and_decreases_loss() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp").unwrap();
    let mut engine = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let mut params = spec.init_params(3);
    let (train, _) = SynthSpec::family(SynthFamily::Mnist, 256, 32, 5).generate();
    let idx: Vec<usize> = (0..32).collect();
    let batch = train.gather_batch(&idx);
    let mut losses = Vec::new();
    for _ in 0..5 {
        losses.push(engine.train_step(&mut params, &batch, 0.2).unwrap());
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "losses={losses:?}"
    );
    assert!(params.iter().all(|v| v.is_finite()));
}

#[test]
fn xla_eval_matches_native_eval() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp").unwrap();
    let params = spec.init_params(7);
    let (_, val) = SynthSpec::family(SynthFamily::Mnist, 64, 512, 9).generate();
    let mut xla = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let mut native = NativeEngine::new(spec.clone(), 32);
    let (xl, xa) = xla.evaluate(&params, &val).unwrap();
    let (nl, na) = native.evaluate(&params, &val).unwrap();
    assert!((xl - nl).abs() < 1e-3, "xla loss {xl} vs native {nl}");
    assert!((xa - na).abs() < 1e-3, "xla acc {xa} vs native {na}");
}

#[test]
fn xla_rejects_wrong_batch_size() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp").unwrap();
    let mut engine = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let mut params = spec.init_params(1);
    let (train, _) = SynthSpec::family(SynthFamily::Mnist, 64, 16, 2).generate();
    let idx: Vec<usize> = (0..16).collect();
    let batch = train.gather_batch(&idx);
    assert!(engine.train_step(&mut params, &batch, 0.1).is_err());
}

#[test]
fn fused_train_k_matches_sequential_steps() {
    // The §Perf L2 fused-burst artifact must be numerically identical to
    // h sequential train_step dispatches (same batches).
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp").unwrap();
    let mut engine = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let (train, _) = SynthSpec::family(SynthFamily::Hard, 512, 32, 7).generate();
    let batches: Vec<_> = (0..7)
        .map(|i| {
            let idx: Vec<usize> = (i * 32..(i + 1) * 32).collect();
            train.gather_batch(&idx)
        })
        .collect();
    let init = spec.init_params(9);

    let mut p_seq = init.clone();
    let mut loss_seq = 0.0f32;
    for b in &batches {
        loss_seq += engine.train_step(&mut p_seq, b, 0.05).unwrap();
    }
    let mut p_fused = init.clone();
    let loss_fused = engine.train_steps(&mut p_fused, &batches, 0.05).unwrap();

    assert!(
        (loss_seq - loss_fused).abs() < 1e-3 * (1.0 + loss_seq.abs()),
        "loss {loss_seq} vs fused {loss_fused}"
    );
    let diff = quafl::util::stats::max_abs_diff(&p_seq, &p_fused);
    assert!(diff < 1e-4, "fused/sequential divergence {diff}");
}

#[test]
fn fused_train_k_chunks_bursts_longer_than_k_max() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp").unwrap();
    let mut engine = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let (train, _) = SynthSpec::family(SynthFamily::Mnist, 512, 32, 2).generate();
    // 15 batches > k_max=10: must chunk and still decrease loss.
    let batches: Vec<_> = (0..15)
        .map(|i| {
            let idx: Vec<usize> = (i * 32..(i + 1) * 32).collect();
            train.gather_batch(&idx)
        })
        .collect();
    let mut params = spec.init_params(2);
    let first = engine.train_steps(&mut params, &batches[..1], 0.2).unwrap();
    let _ = engine.train_steps(&mut params, &batches, 0.2).unwrap();
    let last = engine.train_steps(&mut params, &batches[..1], 0.2).unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn eval_handles_non_multiple_dataset_sizes() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // 300 samples with eval batch 256 exercises the wrap-around path.
    let spec = ModelSpec::by_name("mlp").unwrap();
    let params = spec.init_params(4);
    let (_, val) = SynthSpec::family(SynthFamily::Mnist, 32, 300, 3).generate();
    let mut xla = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let (loss, acc) = xla.evaluate(&params, &val).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

//! Lemma 3.4 property test: the paper proves the potential
//! Φ_t = ‖X_t − μ_t‖² + Σᵢ‖Xⁱ − μ_t‖² contracts in expectation
//! (supermartingale-style), which keeps server and client models within a
//! bounded neighborhood — the closeness the lattice quantizer's decoding
//! radius relies on. Here we check the empirical consequence across
//! randomized small QuAFL configs (n, s, K, slow_fraction, seed drawn by
//! the in-crate property harness): Φ_t stays finite, non-negative, small
//! relative to the model scale, and shows no late-run divergence.

use quafl::config::{ExperimentConfig, TimingConfig};
use quafl::coordinator;
use quafl::prop_assert;
use quafl::testing::{check, PropConfig};

#[test]
fn prop_quafl_potential_stays_bounded() {
    check(
        "quafl_potential_bounded",
        PropConfig { cases: 6, max_size: 12, seed: 0x03A4 },
        |rng, size| {
            // size ramps 1..=12 → fleets of 3..=14 clients.
            let n = 2 + size;
            let s = 1 + rng.gen_range(n.min(4));
            let k = 1 + rng.gen_range(6);
            let slow_fraction = rng.next_f64() * 0.6;
            let cfg = ExperimentConfig {
                n,
                s,
                k,
                rounds: 24,
                eval_every: 24,
                train_samples: 512,
                val_samples: 64,
                batch: 16,
                track_potential: true,
                timing: TimingConfig { slow_fraction, ..Default::default() },
                seed: rng.next_u64(),
                ..Default::default()
            };
            let label = format!(
                "n={n} s={s} K={k} slow={slow_fraction:.2} seed={:#x}",
                cfg.seed
            );
            let m = coordinator::run(&cfg).map_err(|e| format!("{label}: {e:#}"))?;

            prop_assert!(
                m.potential.len() == cfg.rounds,
                "{label}: potential series has {} entries, want {}",
                m.potential.len(),
                cfg.rounds
            );
            for (t, &phi) in m.potential.iter().enumerate() {
                prop_assert!(
                    phi.is_finite() && phi >= 0.0,
                    "{label}: Φ_{t} = {phi} not finite/non-negative"
                );
            }
            // Bounded: Φ sums n+1 squared distances of O(η·K)-scale model
            // discrepancies; 100 is a generous model-scale ceiling that a
            // divergent run blows through immediately.
            let overall_max = m.potential.iter().cloned().fold(0.0, f64::max);
            prop_assert!(
                overall_max < 100.0,
                "{label}: potential too large: {overall_max}"
            );
            // No late-run blowup: the last third never exceeds the overall
            // max (contraction keeps the process from drifting upward).
            let tail_start = cfg.rounds - cfg.rounds / 3;
            let tail_max = m.potential[tail_start..]
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            prop_assert!(
                tail_max <= overall_max * 1.01,
                "{label}: potential grew late: tail {tail_max} vs max {overall_max}"
            );
            Ok(())
        },
    );
}

#[test]
fn potential_function_matches_definition_on_tiny_input() {
    // Sanity-pin the Φ implementation itself: one server at 1, one client
    // at 0 (d = 1): μ = 1/2, Φ = (1/2)² + (1/2)² = 1/2.
    let phi = quafl::algorithms::quafl::potential(&[1.0], &[vec![0.0]]);
    assert!((phi - 0.5).abs() < 1e-9, "phi={phi}");
}

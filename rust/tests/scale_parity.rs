//! Naive-parity property suite for the event-driven million-client round
//! engine (`--event-driven`, default on):
//!
//! 1. **Sampler parity.** `Rng::sample_distinct_sparse` — the O(k)
//!    sparse Fisher–Yates behind event-mode uniform draws — must equal
//!    the dense `sample_distinct` bit for bit, result and residual RNG
//!    stream alike, across shapes from k=0 to k=n.
//! 2. **Availability parity.** The event-driven queue + Fenwick up-set
//!    must answer *exactly* like the legacy per-client walk — same
//!    `is_up`, same `next_up` bits, same reachable sets, same sampled
//!    client streams and residual server RNG — for every availability
//!    kind (always / churn / duty) under randomized non-decreasing
//!    query-time sequences with interleaved operation types.
//! 3. **Policy parity.** All four selection policies, fed one shared
//!    tracker history, must pick identical clients (and leave identical
//!    residual RNG state) whether their view is backed by the legacy or
//!    the event-driven availability, for every availability kind.
//! 4. **End-to-end parity.** Whole coordinator runs — QuAFL, FedBuff,
//!    FedAvg under churn and duty cycles, plus QuAFL under every
//!    selection policy — must produce bitwise-identical metrics with
//!    `--event-driven` on and off.
//! 5. **Tracker aggregate parity.** The incrementally maintained
//!    Gini/max/mean-staleness aggregates must stay bitwise equal to the
//!    retained full-scan oracles under arbitrary interleavings of
//!    `record_participation` / `note_snapshot` / `advance_round`.
//!
//! (The Fenwick tree's own prefix-sum/select/sampling properties are
//! unit-tested in rust/src/util/fenwick.rs.)

mod common;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, TimingConfig};
use quafl::coordinator;
use quafl::net::{
    AvailabilityKind, ClientAvailability, NetProfile, NetworkConfig,
};
use quafl::select::{
    ParticipationTracker, SelectionKind, SelectionPolicy, SelectionView,
};
use quafl::util::rng::Rng;

fn kinds() -> Vec<AvailabilityKind> {
    vec![
        AvailabilityKind::Always,
        AvailabilityKind::Churn { mean_up: 12.0, mean_down: 5.0 },
        AvailabilityKind::Churn { mean_up: 2.0, mean_down: 9.0 },
        AvailabilityKind::DutyCycle { period: 7.0, on_fraction: 0.35 },
        AvailabilityKind::DutyCycle { period: 3.0, on_fraction: 0.9 },
        AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 1.0 },
    ]
}

#[test]
fn sparse_fisher_yates_equals_dense_bitwise() {
    for seed in [1u64, 5, 99, 12345] {
        for (n, k) in [
            (1usize, 0usize),
            (1, 1),
            (7, 3),
            (30, 30),
            (100, 1),
            (503, 41),
            (10_000, 64),
        ] {
            let mut dense = Rng::new(seed);
            let mut sparse = Rng::new(seed);
            assert_eq!(
                dense.sample_distinct(n, k),
                sparse.sample_distinct_sparse(n, k),
                "n={n} k={k} seed={seed}"
            );
            // The residual streams must coincide too: callers keep
            // drawing from the same RNG afterwards.
            assert_eq!(dense.next_u64(), sparse.next_u64(), "residual");
        }
    }
}

/// Drive a legacy/event twin pair through an identical randomized op
/// sequence at non-decreasing times and demand bitwise agreement.
#[test]
fn event_driven_availability_is_bit_identical_to_legacy() {
    let n = 40;
    let s = 7;
    for kind in kinds() {
        for seed in [3u64, 21, 77] {
            let mut legacy = ClientAvailability::new(kind.clone(), n, seed);
            let mut event =
                ClientAvailability::with_mode(kind.clone(), n, seed, true);
            assert!(!legacy.is_event_driven());
            assert!(event.is_event_driven());
            let mut server_a = Rng::new(seed ^ 0xABCD);
            let mut server_b = Rng::new(seed ^ 0xABCD);
            let mut driver = Rng::new(seed.wrapping_mul(31) + 7);
            let mut t = 0.0f64;
            for step in 0..300 {
                t += driver.uniform(0.0, 2.5);
                let what = format!("{} seed={seed} step={step} t={t}", kind.name());
                match driver.gen_range(4) {
                    0 => {
                        let i = driver.gen_range(n);
                        assert_eq!(
                            legacy.is_up(i, t),
                            event.is_up(i, t),
                            "is_up({i}) {what}"
                        );
                    }
                    1 => {
                        let i = driver.gen_range(n);
                        assert_eq!(
                            legacy.next_up(i, t).to_bits(),
                            event.next_up(i, t).to_bits(),
                            "next_up({i}) {what}"
                        );
                    }
                    2 => {
                        assert_eq!(
                            legacy.reachable(n, t),
                            event.reachable(n, t),
                            "reachable {what}"
                        );
                    }
                    _ => {
                        assert_eq!(
                            legacy.sample(&mut server_a, n, s, t),
                            event.sample(&mut server_b, n, s, t),
                            "sample {what}"
                        );
                    }
                }
            }
            // Both server streams must end in the same state: the event
            // path consumed exactly the legacy draw sequence.
            assert_eq!(
                server_a.next_u64(),
                server_b.next_u64(),
                "{}: residual server stream",
                kind.name()
            );
        }
    }
}

#[test]
fn every_policy_picks_identically_over_both_modes() {
    let n = 30;
    let s = 5;
    let policies = [
        SelectionKind::Uniform,
        SelectionKind::StalenessAware { cap: 3 },
        SelectionKind::Fairness,
        SelectionKind::LossPoc { candidates: Some(12) },
    ];
    for kind in kinds() {
        for pk in &policies {
            let mut legacy = ClientAvailability::new(kind.clone(), n, 17);
            let mut event =
                ClientAvailability::with_mode(kind.clone(), n, 17, true);
            let mut pol_a = pk.build(s);
            let mut pol_b = pk.build(s);
            let mut rng_a = Rng::new(4242);
            let mut rng_b = Rng::new(4242);
            // One shared history: the policies must diverge only if the
            // availability answers diverge.
            let mut tracker = ParticipationTracker::new(n);
            let mut driver = Rng::new(99);
            let mut t = 0.0f64;
            for step in 0..120 {
                t += driver.uniform(0.1, 2.0);
                let picked_a = {
                    let mut view = SelectionView {
                        now: t,
                        n,
                        availability: &mut legacy,
                        tracker: &tracker,
                    };
                    pol_a.select(&mut view, &mut rng_a, s)
                };
                let picked_b = {
                    let mut view = SelectionView {
                        now: t,
                        n,
                        availability: &mut event,
                        tracker: &tracker,
                    };
                    pol_b.select(&mut view, &mut rng_b, s)
                };
                assert_eq!(
                    picked_a,
                    picked_b,
                    "{}/{} step {step} t={t}",
                    kind.name(),
                    pol_a.name()
                );
                tracker.advance_round();
                for &i in &picked_a {
                    tracker.record_participation(i, t);
                    tracker.note_snapshot(i);
                    tracker.note_loss(i, 1.0 / (1.0 + i as f64));
                }
            }
            assert_eq!(
                rng_a.next_u64(),
                rng_b.next_u64(),
                "{}/{}: residual policy RNG",
                kind.name(),
                pol_b.name()
            );
        }
    }
}

fn e2e_base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 24,
        s: 6,
        k: 3,
        rounds: 6,
        eval_every: 3,
        train_samples: 512,
        val_samples: 64,
        batch: 16,
        seed: 23,
        workers: 2,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

fn gated_net(kind: AvailabilityKind) -> NetworkConfig {
    NetworkConfig {
        profile: NetProfile::preset("mobile").expect("preset"),
        availability: kind,
        ..Default::default()
    }
}

#[test]
fn whole_runs_are_bit_identical_across_modes() {
    let gates = [
        AvailabilityKind::Churn { mean_up: 60.0, mean_down: 30.0 },
        AvailabilityKind::DutyCycle { period: 40.0, on_fraction: 0.5 },
    ];
    for algorithm in [Algorithm::QuAFL, Algorithm::FedBuff, Algorithm::FedAvg] {
        for gate in &gates {
            let mk = |event_driven: bool| ExperimentConfig {
                net: gated_net(gate.clone()),
                event_driven,
                ..e2e_base(algorithm)
            };
            let on = coordinator::run(&mk(true)).expect("event-driven run");
            let off = coordinator::run(&mk(false)).expect("legacy run");
            assert!(!on.points.is_empty());
            assert_identical(
                &on,
                &off,
                &format!("{}/{}", algorithm.name(), gate.name()),
            );
        }
    }
}

#[test]
fn whole_runs_are_bit_identical_across_modes_for_every_policy() {
    let policies = [
        SelectionKind::Uniform,
        SelectionKind::StalenessAware { cap: 4 },
        SelectionKind::Fairness,
        SelectionKind::LossPoc { candidates: None },
    ];
    for select in policies {
        let mk = |event_driven: bool| ExperimentConfig {
            net: gated_net(AvailabilityKind::Churn {
                mean_up: 60.0,
                mean_down: 30.0,
            }),
            select: select.clone(),
            event_driven,
            ..e2e_base(Algorithm::QuAFL)
        };
        let on = coordinator::run(&mk(true)).expect("event-driven run");
        let off = coordinator::run(&mk(false)).expect("legacy run");
        assert!(!on.points.is_empty());
        assert_identical(&on, &off, select.name());
    }
}

#[test]
fn default_config_runs_event_driven_and_reproduces_legacy() {
    // The toggle defaults ON; an untouched config must still reproduce
    // the legacy (pre-event-queue) trajectory bit for bit — the Always
    // kind's sparse draw is stream-identical to the dense one.
    let cfg = e2e_base(Algorithm::QuAFL);
    assert!(cfg.event_driven);
    let on = coordinator::run(&cfg).expect("default run");
    let off = coordinator::run(&ExperimentConfig {
        event_driven: false,
        ..e2e_base(Algorithm::QuAFL)
    })
    .expect("legacy run");
    assert_identical(&on, &off, "default/always");
}

#[test]
fn tracker_incremental_aggregates_match_scan_oracles() {
    for seed in [7u64, 1234, 999_983] {
        let mut driver = Rng::new(seed);
        let n = 1 + driver.gen_range(50);
        let mut t = ParticipationTracker::new(n);
        for step in 0..3000 {
            match driver.gen_range(5) {
                0 | 1 => {
                    t.record_participation(driver.gen_range(n), step as f64)
                }
                2 => t.advance_round(),
                _ => t.note_snapshot(driver.gen_range(n)),
            }
            assert_eq!(
                t.participation_gini().to_bits(),
                t.participation_gini_scan().to_bits(),
                "gini at step {step} (seed {seed}, n {n})"
            );
            assert_eq!(
                t.max_staleness(),
                t.max_staleness_scan(),
                "max staleness at step {step} (seed {seed}, n {n})"
            );
            assert_eq!(
                t.mean_staleness().to_bits(),
                t.mean_staleness_scan().to_bits(),
                "mean staleness at step {step} (seed {seed}, n {n})"
            );
        }
    }
}

//! Parity suite for the L3-telemetry layer (rust/src/telemetry): the
//! tentpole guarantee is that armed telemetry is **bit-free** — a traced
//! run with the metric registry armed must reproduce the untraced
//! trajectory bit for bit, for every algorithm — and that the
//! incremental O(touched·d) Φ_t probe agrees with the retained dense
//! O(n·d) oracle within floating-point fold tolerance (the two
//! accumulate in different orders/precisions, so bitwise equality is
//! not the contract there — trajectory identity is).

mod common;

use std::collections::BTreeSet;
use std::path::PathBuf;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, TimingConfig};
use quafl::coordinator;
use quafl::metrics::RunMetrics;
use quafl::telemetry::health;
use quafl::telemetry::sketch::QuantileSketch;
use quafl::util::json::{self, Json};

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 10,
        s: 4,
        k: 4,
        rounds: 6,
        eval_every: 2,
        workers: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 23,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

fn tmp_trace(tag: &str) -> (PathBuf, String) {
    let path = std::env::temp_dir().join(format!(
        "quafl_telemetry_parity_{tag}_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let s = path.to_str().unwrap().to_string();
    (path, s)
}

/// Run `cfg` untraced (registry disarmed — no sink) and traced (armed,
/// `telemetry` at its default true); assert bit-identical metrics and
/// return the traced run's parsed event stream.
fn run_pair(cfg: ExperimentConfig, tag: &str) -> (RunMetrics, Vec<Json>) {
    let off = coordinator::run(&cfg).expect("untraced run");
    assert!(!off.points.is_empty(), "no eval points — vacuous parity");
    let (path, path_s) = tmp_trace(tag);
    let armed = coordinator::run(&ExperimentConfig {
        trace: Some(path_s),
        ..cfg.clone()
    })
    .expect("traced run");
    assert_identical(
        &off,
        &armed,
        &format!("{} telemetry off vs armed", cfg.algorithm.name()),
    );
    let text = std::fs::read_to_string(&path).expect("trace file written");
    let events = json::parse_lines(&text).expect("trace lines parse");
    let _ = std::fs::remove_file(&path);
    (armed, events)
}

fn metric_names(events: &[Json]) -> BTreeSet<String> {
    events
        .iter()
        .filter(|e| e.get("kind").and_then(|k| k.as_str()) == Some("metric"))
        .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
        .map(|s| s.to_string())
        .collect()
}

#[test]
fn quafl_armed_telemetry_is_bit_free_and_emits_the_catalog() {
    let cfg = ExperimentConfig {
        track_potential: true,
        ..base(Algorithm::QuAFL)
    };
    let (metrics, events) = run_pair(cfg, "quafl");
    assert!(!metrics.potential.is_empty(), "Φ_t series recorded");
    let names = metric_names(&events);
    for want in [
        "phi",
        "discrepancy",
        "select_chi2",
        "gini",
        "qerr_p50",
        "qerr_p95",
        "qerr_n",
        "client_loss_p50",
        "client_loss_rmean",
        "delay_p50",
    ] {
        assert!(names.contains(want), "missing metric {want:?} in {names:?}");
    }
    // The flushed phi gauge must equal the recorded Φ_t series values
    // exactly — both read the same probe.
    let phi_events: Vec<f64> = events
        .iter()
        .filter(|e| {
            e.get("kind").and_then(|k| k.as_str()) == Some("metric")
                && e.get("name").and_then(|n| n.as_str()) == Some("phi")
        })
        .map(|e| e.get("value").and_then(|v| v.as_f64()).unwrap())
        .collect();
    assert_eq!(phi_events.len(), metrics.potential.len());
    for (i, (a, b)) in phi_events.iter().zip(&metrics.potential).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "phi gauge vs series at {i}");
    }
}

#[test]
fn fedavg_armed_telemetry_is_bit_free() {
    let (_, events) = run_pair(base(Algorithm::FedAvg), "fedavg");
    let names = metric_names(&events);
    for want in ["select_chi2", "gini", "client_loss_p50", "delay_p50"] {
        assert!(names.contains(want), "missing metric {want:?} in {names:?}");
    }
    // FedAvg is uncompressed and probe-less.
    assert!(!names.contains("qerr_p50"), "no quantizer in fedavg");
    assert!(!names.contains("phi"), "no Φ_t probe in fedavg");
}

#[test]
fn fedbuff_armed_telemetry_is_bit_free_with_probe_and_staleness() {
    let (_, events) = run_pair(base(Algorithm::FedBuff), "fedbuff");
    let names = metric_names(&events);
    for want in [
        "phi",
        "discrepancy",
        "staleness_p50",
        "qerr_p50",
        "client_loss_p50",
        "delay_p50",
    ] {
        assert!(names.contains(want), "missing metric {want:?} in {names:?}");
    }
}

#[test]
fn baseline_armed_telemetry_is_bit_free() {
    let (_, events) = run_pair(base(Algorithm::Baseline), "baseline");
    let names = metric_names(&events);
    assert!(names.contains("client_loss_p50"), "{names:?}");
}

#[test]
fn telemetry_opt_out_suppresses_metric_events_and_stays_bit_free() {
    let cfg = base(Algorithm::QuAFL);
    let off = coordinator::run(&cfg).expect("untraced run");
    let (path, path_s) = tmp_trace("opt_out");
    let traced = coordinator::run(&ExperimentConfig {
        trace: Some(path_s),
        telemetry: false,
        ..cfg
    })
    .expect("traced run with --telemetry false");
    assert_identical(&off, &traced, "quafl telemetry opt-out");
    let text = std::fs::read_to_string(&path).expect("trace file written");
    let events = json::parse_lines(&text).expect("trace lines parse");
    assert!(!events.is_empty(), "tracing itself still on");
    assert!(
        metric_names(&events).is_empty(),
        "--telemetry false must suppress every metric event"
    );
    let _ = std::fs::remove_file(&path);
}

/// Satellite 1: `--track-potential` defaults to the incremental probe;
/// `--dense-potential` keeps the O(n·d) folds. The two runs must have
/// bit-identical *trajectories* (the probe reads, never writes), and
/// Φ_t series that agree within fp-fold tolerance: the dense fold
/// averages in f32 client order while the probe keeps f64 centered
/// sums, so the documented contract is relative agreement (1e-3 here,
/// same order as rust/src/telemetry/probe.rs's property tests), not
/// bitwise equality.
#[test]
fn incremental_phi_agrees_with_dense_oracle() {
    for algorithm in [Algorithm::QuAFL] {
        let cfg = ExperimentConfig {
            track_potential: true,
            ..base(algorithm)
        };
        let inc = coordinator::run(&cfg).expect("incremental run");
        let dense = coordinator::run(&ExperimentConfig {
            dense_potential: true,
            ..cfg
        })
        .expect("dense run");
        assert_eq!(inc.potential.len(), dense.potential.len());
        assert!(!inc.potential.is_empty(), "vacuous Φ_t comparison");
        // Trajectory identity: swap in the dense potential series and
        // demand everything else bitwise equal.
        let mut inc_swapped = inc.clone();
        inc_swapped.potential = dense.potential.clone();
        assert_identical(
            &inc_swapped,
            &dense,
            &format!("{} incremental vs dense trajectory", algorithm.name()),
        );
        for (i, (a, b)) in
            inc.potential.iter().zip(&dense.potential).enumerate()
        {
            let tol = 1e-6 + 1e-3 * a.abs().max(b.abs());
            assert!(
                (a - b).abs() <= tol,
                "{}: Φ[{i}] probe {a} vs dense {b} (tol {tol})",
                algorithm.name()
            );
            assert!(b.is_finite() && *b >= 0.0, "dense Φ sane");
        }
    }
}

/// Satellite 3 (public-API face): the streaming quantile sketch obeys
/// its documented rank-error bound `depth·n/k` on adversarial streams.
#[test]
fn sketch_rank_error_bound_holds_through_public_api() {
    let k = 64;
    let n = 4096;
    let streams: Vec<Vec<f64>> = vec![
        (0..n).map(|i| i as f64).collect(),
        (0..n).map(|i| (n - i) as f64).collect(),
        (0..n).map(|i| (i % 17) as f64).collect(),
    ];
    for (si, stream) in streams.iter().enumerate() {
        let mut sk = QuantileSketch::with_k(k, 0xBEEF + si as u64);
        for &v in stream {
            sk.update(v);
        }
        let mut sorted = stream.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let bound = sk.depth() as f64 * n as f64 / k as f64 + 1.0;
        for q in [0.1, 0.5, 0.9, 0.99] {
            let est = sk.quantile(q);
            let target = (q * (n - 1) as f64).round();
            let rank = sorted.iter().filter(|&&v| v < est).count() as f64;
            assert!(
                (rank - target).abs() <= bound,
                "stream {si} q={q}: rank {rank} vs target {target} \
                 (bound {bound})"
            );
        }
    }
}

/// End-to-end health-report: aggregate a real traced run's stream and
/// write the canonical BENCH_health.json.
#[test]
fn health_report_aggregates_a_real_run() {
    let cfg = ExperimentConfig {
        track_potential: true,
        ..base(Algorithm::QuAFL)
    };
    let (path, path_s) = tmp_trace("health");
    let metrics = coordinator::run(&ExperimentConfig {
        trace: Some(path_s),
        ..cfg.clone()
    })
    .expect("traced run");
    let text = std::fs::read_to_string(&path).expect("trace file written");
    let events = json::parse_lines(&text).expect("trace lines parse");
    let _ = std::fs::remove_file(&path);

    let r = health::aggregate(&events);
    assert!(r.metric_points > 0, "metric events aggregated");
    assert_eq!(r.runs, vec!["QuAFL".to_string()]);
    let phi = r.series.get("phi").expect("phi series");
    assert_eq!(phi.points.len(), metrics.potential.len());
    assert_eq!(
        phi.last().to_bits(),
        metrics.potential.last().unwrap().to_bits(),
        "health-report reproduces the recorded Φ_t tail"
    );
    let rendered = r.render();
    assert!(rendered.contains("convergence"), "{rendered}");
    assert!(rendered.contains("phi"), "{rendered}");

    let dir = std::env::temp_dir().join(format!(
        "quafl_health_report_test_{}",
        std::process::id()
    ));
    let out_dir = dir.to_str().unwrap().to_string();
    let bench_path = r.write_bench(&out_dir).expect("write BENCH_health.json");
    let doc =
        json::parse(&std::fs::read_to_string(&bench_path).unwrap()).unwrap();
    assert_eq!(
        doc.get("bench").and_then(|v| v.as_str()),
        Some("fleet_health")
    );
    let rows = doc.get("rows").and_then(|v| v.as_arr()).unwrap();
    assert!(
        rows.iter().any(|row| {
            row.get("name").and_then(|n| n.as_str()) == Some("phi")
        }),
        "BENCH_health.json carries the phi series row"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

//! Parity + property suite for the pluggable client-selection subsystem
//! (rust/src/select):
//!
//! 1. **Stream parity.** The default `Uniform` policy must consume the
//!    exact RNG stream `ClientAvailability::sample` consumed before the
//!    subsystem existed — per draw *and* in residual stream state — for
//!    every availability kind (always / churn / duty).
//! 2. **Schedule parity.** A `--select uniform` run's recorded selection
//!    schedule (times + ids, `track_selection`) must reproduce a
//!    from-scratch reimplementation of the *pre-subsystem* sampling loop
//!    (raw `availability.sample`, twin clocks, twin transport priced from
//!    dim-deterministic encoded sizes) bit for bit, for QuAFL and FedAvg,
//!    on a priced network under churn. FedBuff and the baseline have no
//!    sampling step; their uniform path consumes no selection RNG at all,
//!    pinned by replay identity under churn.
//! 3. **Policy properties.** Fairness meets its min-participation quota
//!    (round-robin under full availability; exact argmin under churn),
//!    StalenessAware respects its hard cap (over-cap reachable clients
//!    are mandatory), and the policies genuinely diverge — different
//!    schedules, lower participation Gini for fairness, FedBuff
//!    admission rejections under a tight staleness cap.

mod common;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, TimingConfig};
use quafl::coordinator;
use quafl::model::ModelSpec;
use quafl::net::{
    AvailabilityKind, ClientAvailability, NetProfile, NetworkConfig,
};
use quafl::select::{
    Fairness, ParticipationTracker, SelectionKind, SelectionPolicy,
    SelectionView, StalenessAware,
};
use quafl::sim::build_clocks;
use quafl::util::rng::{derive_seed, Rng};

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 16,
        s: 4,
        k: 4,
        rounds: 10,
        eval_every: 5,
        train_samples: 512,
        val_samples: 64,
        batch: 16,
        seed: 31,
        workers: 2,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        track_selection: true,
        ..Default::default()
    }
}

/// Mobile-profile transport + heavy churn (~10% stationary availability,
/// the regime net_parity.rs already relies on to force short rounds):
/// the richest scheduling path — priced exchanges, reachability gating,
/// short and empty rounds.
fn churny_mobile() -> NetworkConfig {
    NetworkConfig {
        profile: NetProfile::preset("mobile").expect("preset"),
        availability: AvailabilityKind::Churn { mean_up: 10.0, mean_down: 90.0 },
        ..Default::default()
    }
}

#[test]
fn uniform_matches_raw_sample_stream_for_all_availability_kinds() {
    let n = 24;
    let s = 6;
    for kind in [
        AvailabilityKind::Always,
        AvailabilityKind::Churn { mean_up: 30.0, mean_down: 10.0 },
        AvailabilityKind::DutyCycle { period: 50.0, on_fraction: 0.4 },
    ] {
        let mut av = ClientAvailability::new(kind.clone(), n, 5);
        let mut av_raw = ClientAvailability::new(kind.clone(), n, 5);
        let tracker = ParticipationTracker::new(n);
        let mut rng = Rng::new(99);
        let mut rng_raw = Rng::new(99);
        let mut policy = quafl::select::Uniform;
        for step in 0..60 {
            let t = step as f64 * 3.3;
            let mut view = SelectionView {
                now: t,
                n,
                availability: &mut av,
                tracker: &tracker,
            };
            let picked = policy.select(&mut view, &mut rng, s);
            let expect = av_raw.sample(&mut rng_raw, n, s, t);
            assert_eq!(picked, expect, "{} t={t}", kind.name());
        }
        // Residual streams bit-identical: the policy consumed exactly
        // the raw path's randomness, no more, no less.
        assert_eq!(rng.next_u64(), rng_raw.next_u64(), "{}", kind.name());
    }
}

#[test]
fn quafl_uniform_schedule_matches_pre_subsystem_reference() {
    // Reimplement the pre-subsystem QuAFL sampling loop from scratch —
    // raw `availability.sample` on twin processes, clock advancement in
    // sampled order, exchanges priced from the dim-deterministic encoded
    // sizes — and demand the recorded schedule matches bit for bit.
    let cfg = ExperimentConfig { net: churny_mobile(), ..base(Algorithm::QuAFL) };
    let m = coordinator::run(&cfg).expect("quafl run");
    assert_eq!(m.selections.len(), cfg.rounds, "one selection per round");

    let mut rng = Rng::new(derive_seed(cfg.seed, 0x5E1EC7));
    let mut availability =
        cfg.net.build_availability(cfg.n, derive_seed(cfg.seed, 0x4E71));
    let mut clocks =
        build_clocks(cfg.n, &cfg.timing, derive_seed(cfg.seed, 0xC10C));
    let rates: Vec<f64> = clocks.iter().map(|c| c.rate()).collect();
    let transport =
        cfg.net.build_transport(cfg.n, derive_seed(cfg.seed, 0x4E70), &rates);
    let d = ModelSpec::by_name(&cfg.model).unwrap().num_params();
    let quantizer = coordinator::build_quantizer(&cfg, d);
    // Both directions carry the quantizer's encoding, whose wire size is
    // a deterministic function of d (property-tested in net_parity.rs).
    let msg_bits = quantizer.encoded_bits(d) as u64;

    let mut now = 0f64;
    let mut short_rounds = 0u64;
    for t in 0..cfg.rounds {
        now += cfg.timing.swt;
        let sampled = availability.sample(&mut rng, cfg.n, cfg.s, now);
        let (rec_t, rec_ids) = &m.selections[t];
        assert_eq!(rec_t.to_bits(), now.to_bits(), "round {t}: time");
        assert_eq!(rec_ids, &sampled, "round {t}: ids");
        if sampled.len() < cfg.s {
            short_rounds += 1;
        }
        if sampled.is_empty() {
            now += cfg.timing.sit;
            continue;
        }
        // Pre-pass: realize partial progress in sampled order.
        for &i in &sampled {
            let _ = clocks[i].steps_completed(now, cfg.k);
        }
        // Reduction: price the overlapping exchanges, restart clocks.
        let mut round_comm = 0f64;
        for &i in &sampled {
            let down_t = transport.downlink_time(i, msg_bits);
            let up_t = transport.uplink_time(i, msg_bits);
            round_comm = round_comm.max(down_t + up_t);
            clocks[i].restart(now + cfg.timing.sit + down_t);
        }
        now += cfg.timing.sit + round_comm;
    }
    assert_eq!(m.short_rounds, short_rounds, "short-round accounting");
    // The churn must have actually gated something, or this proved little.
    assert!(short_rounds > 0, "churn never produced a short round");
}

#[test]
fn fedavg_uniform_schedule_matches_pre_subsystem_reference() {
    let cfg = ExperimentConfig {
        quantizer: quafl::config::QuantizerKind::None,
        net: churny_mobile(),
        ..base(Algorithm::FedAvg)
    };
    let m = coordinator::run(&cfg).expect("fedavg run");
    assert_eq!(m.selections.len(), cfg.rounds);

    let mut rng = Rng::new(derive_seed(cfg.seed, 0x5E1EC7));
    let mut availability =
        cfg.net.build_availability(cfg.n, derive_seed(cfg.seed, 0x4E71));
    let mut clocks =
        build_clocks(cfg.n, &cfg.timing, derive_seed(cfg.seed, 0xC10C));
    let rates: Vec<f64> = clocks.iter().map(|c| c.rate()).collect();
    let transport =
        cfg.net.build_transport(cfg.n, derive_seed(cfg.seed, 0x4E70), &rates);
    let d = ModelSpec::by_name(&cfg.model).unwrap().num_params();
    let model_bits = (d * 32) as u64;

    let mut now = 0f64;
    for t in 0..cfg.rounds {
        let sampled = availability.sample(&mut rng, cfg.n, cfg.s, now);
        let (rec_t, rec_ids) = &m.selections[t];
        assert_eq!(rec_t.to_bits(), now.to_bits(), "round {t}: time");
        assert_eq!(rec_ids, &sampled, "round {t}: ids");
        if sampled.is_empty() {
            now += cfg.timing.sit;
            continue;
        }
        let mut round_end = now;
        for &i in &sampled {
            let down_t = transport.downlink_time(i, model_bits);
            let up_t = transport.uplink_time(i, model_bits);
            clocks[i].restart(now + down_t);
            let finish = clocks[i].finish_time_for(cfg.k) + up_t;
            round_end = round_end.max(finish);
        }
        now = round_end + cfg.timing.sit;
    }
}

#[test]
fn uniform_replays_identically_under_churn_for_all_algorithms() {
    // FedBuff and the baseline have no sampling step — uniform is the
    // admit-everything no-RNG path — so replay identity under churn pins
    // the whole-trajectory invariance the subsystem promises; QuAFL and
    // FedAvg ride along on top of their reference-schedule proofs.
    for algorithm in [
        Algorithm::QuAFL,
        Algorithm::FedAvg,
        Algorithm::FedBuff,
        Algorithm::Baseline,
    ] {
        let cfg = ExperimentConfig {
            net: churny_mobile(),
            track_selection: false,
            ..base(algorithm)
        };
        let a = coordinator::run(&cfg).expect("run a");
        let b = coordinator::run(&cfg).expect("run b");
        assert!(!a.points.is_empty());
        assert_identical(&a, &b, algorithm.name());
        assert_eq!(a.rejected_interactions, 0, "{}", algorithm.name());
        // An explicit `--select uniform` is the same configuration as
        // the default (the enum default), hence the same trajectory.
        let explicit = coordinator::run(&ExperimentConfig {
            select: SelectionKind::Uniform,
            ..cfg
        })
        .expect("explicit uniform");
        assert_identical(&a, &explicit, algorithm.name());
    }
}

#[test]
fn uniform_participation_metrics_populate() {
    // n=16 clients over 10·s=40 participations cannot split evenly, so
    // the Gini is strictly positive; staleness is ≥ 1 for everyone at
    // the post-round eval boundary.
    let m = coordinator::run(&base(Algorithm::QuAFL)).expect("run");
    assert!(m.participation_gini() > 0.0);
    assert!(m.staleness_max() >= 1);
    assert!(m.staleness_mean() >= 1.0);
}

/// Drive a policy directly against a seeded availability process and a
/// live tracker, checking `check(round, reachable, picked, tracker)`
/// before each round's bookkeeping is recorded.
fn drive_policy(
    policy: &mut dyn SelectionPolicy,
    kind: AvailabilityKind,
    n: usize,
    s: usize,
    rounds: usize,
    mut check: impl FnMut(usize, &[usize], &[usize], &ParticipationTracker),
) {
    let mut av = ClientAvailability::new(kind.clone(), n, 13);
    let mut twin = ClientAvailability::new(kind, n, 13);
    let mut tracker = ParticipationTracker::new(n);
    let mut rng = Rng::new(41);
    for round in 0..rounds {
        let t = round as f64 * 10.0;
        let reachable: Vec<usize> =
            (0..n).filter(|&i| twin.is_up(i, t)).collect();
        let picked = {
            let mut view = SelectionView {
                now: t,
                n,
                availability: &mut av,
                tracker: &tracker,
            };
            policy.select(&mut view, &mut rng, s)
        };
        // Shared contract: distinct, reachable, at most s — and all of
        // the reachable when at most s of them exist.
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), picked.len(), "round {round}: distinct");
        assert!(picked.len() <= s, "round {round}: too many");
        for &i in &picked {
            assert!(reachable.contains(&i), "round {round}: {i} unreachable");
        }
        if reachable.len() <= s {
            assert_eq!(picked, reachable, "round {round}: short round");
        }
        check(round, &reachable, &picked, &tracker);
        for &i in &picked {
            tracker.record_participation(i, t);
            tracker.note_snapshot(i);
        }
        tracker.advance_round();
    }
}

#[test]
fn fairness_is_round_robin_under_full_availability() {
    let (n, s) = (10, 3);
    let mut policy = Fairness;
    drive_policy(
        &mut policy,
        AvailabilityKind::Always,
        n,
        s,
        50,
        |round, _reachable, _picked, tracker| {
            // Always picking the least-served keeps the spread within 1.
            let counts: Vec<u64> = (0..n).map(|i| tracker.count(i)).collect();
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(max - min <= 1, "round {round}: counts {counts:?}");
        },
    );
}

#[test]
fn fairness_meets_quota_under_churn() {
    let (n, s) = (12, 3);
    let mut policy = Fairness;
    let mut full_rounds = 0;
    drive_policy(
        &mut policy,
        AvailabilityKind::Churn { mean_up: 40.0, mean_down: 20.0 },
        n,
        s,
        60,
        |round, reachable, picked, tracker| {
            if reachable.len() <= s {
                return;
            }
            full_rounds += 1;
            // Exact argmin: no unselected reachable client is strictly
            // less served than a selected one.
            let max_picked =
                picked.iter().map(|&i| tracker.count(i)).max().unwrap();
            let min_unpicked = reachable
                .iter()
                .filter(|i| !picked.contains(i))
                .map(|&i| tracker.count(i))
                .min()
                .unwrap();
            assert!(
                max_picked <= min_unpicked,
                "round {round}: picked count {max_picked} over \
                 unpicked min {min_unpicked}"
            );
        },
    );
    assert!(full_rounds > 0, "churn always gated below s");
}

#[test]
fn staleness_cap_mandates_overdue_clients() {
    let (n, s) = (12, 3);
    let cap = 3u64;
    let mut policy = StalenessAware::new(cap);
    let mut binding_rounds = 0;
    drive_policy(
        &mut policy,
        AvailabilityKind::Churn { mean_up: 20.0, mean_down: 20.0 },
        n,
        s,
        60,
        |round, reachable, picked, tracker| {
            if reachable.len() <= s {
                return;
            }
            let over: Vec<usize> = reachable
                .iter()
                .copied()
                .filter(|&i| tracker.staleness(i) >= cap)
                .collect();
            let picked_over =
                picked.iter().filter(|i| over.contains(i)).count();
            // The cap is hard: over-cap reachable clients are selected
            // before anyone else, up to the s slots available.
            assert_eq!(
                picked_over,
                over.len().min(s),
                "round {round}: over-cap {over:?}, picked {picked:?}"
            );
            if !over.is_empty() {
                binding_rounds += 1;
            }
        },
    );
    assert!(binding_rounds > 0, "cap never bound — property untested");
}

#[test]
fn policies_diverge_and_fairness_flattens_participation() {
    let mk = |select: SelectionKind| ExperimentConfig {
        rounds: 40,
        eval_every: 20,
        net: NetworkConfig {
            availability: AvailabilityKind::Churn {
                mean_up: 100.0,
                mean_down: 30.0,
            },
            ..Default::default()
        },
        select,
        ..base(Algorithm::QuAFL)
    };
    let uniform = coordinator::run(&mk(SelectionKind::Uniform)).unwrap();
    let fairness = coordinator::run(&mk(SelectionKind::Fairness)).unwrap();
    let staleness =
        coordinator::run(&mk(SelectionKind::StalenessAware { cap: 6 })).unwrap();
    let poc = coordinator::run(&mk(SelectionKind::LossPoc { candidates: None }))
        .unwrap();

    // The four schedules must genuinely differ.
    let traces: std::collections::BTreeSet<String> =
        [&uniform, &fairness, &staleness, &poc]
            .iter()
            .map(|m| format!("{:?}", m.selections))
            .collect();
    assert_eq!(traces.len(), 4, "some policies selected identically");

    // Fairness explicitly equalizes participation: its Gini must come in
    // below uniform sampling's.
    assert!(
        fairness.participation_gini() < uniform.participation_gini(),
        "fairness gini {} not below uniform {}",
        fairness.participation_gini(),
        uniform.participation_gini()
    );
    // All four converged to something finite.
    for m in [&uniform, &fairness, &staleness, &poc] {
        assert!(m.final_loss().is_finite());
    }
}

#[test]
fn fedbuff_staleness_cap_rejects_stale_pushes_uniform_never_does() {
    let mk = |select: SelectionKind| ExperimentConfig {
        n: 16,
        fedbuff_buffer: 2,
        k: 3,
        rounds: 30,
        eval_every: 15,
        timing: TimingConfig { slow_fraction: 0.5, ..Default::default() },
        select,
        track_selection: false,
        ..base(Algorithm::FedBuff)
    };
    let uniform = coordinator::run(&mk(SelectionKind::Uniform)).unwrap();
    assert_eq!(uniform.rejected_interactions, 0);
    // Buffer 2 over 16 free-running clients: ~8 aggregations pass between
    // a client's pull and its push, so a cap of 1 must reject plenty —
    // while rejected clients re-pull fresh snapshots, so the run still
    // completes its 30 aggregations.
    let capped =
        coordinator::run(&mk(SelectionKind::StalenessAware { cap: 1 })).unwrap();
    assert!(
        capped.rejected_interactions > 0,
        "tight staleness cap never rejected an arrival"
    );
    assert!(capped.final_loss().is_finite());
    // Rejections are visible in the interaction accounting: the rejected
    // arrivals' compute still happened.
    assert!(capped.total_interactions > uniform.total_interactions);
}

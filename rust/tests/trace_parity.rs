//! Parity + schema suite for the tracing subsystem (rust/src/trace): the
//! tentpole guarantee is that observability is **bit-free** — enabling a
//! JSONL sink must not perturb a single RNG draw or trajectory value.
//! Every algorithm's RunMetrics must be bit-identical with tracing off
//! vs. armed, the emitted JSONL must round-trip through the in-crate
//! parser against the schema in docs/TRACE_SCHEMA.md, and the
//! `trace-report` aggregation must see the expected spans / counters /
//! samples from a real run.

mod common;

use std::path::PathBuf;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, TimingConfig};
use quafl::coordinator;
use quafl::metrics::RunMetrics;
use quafl::trace::report;
use quafl::util::json::{self, Json};

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 10,
        s: 4,
        k: 4,
        rounds: 6,
        eval_every: 2,
        workers: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 23,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

fn tmp_trace(tag: &str) -> (PathBuf, String) {
    let path = std::env::temp_dir().join(format!(
        "quafl_trace_parity_{tag}_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let s = path.to_str().unwrap().to_string();
    (path, s)
}

/// Run `cfg` untraced and traced-to-JSONL; assert bit-identical metrics
/// and return (traced metrics, parsed event stream).
fn run_both(cfg: ExperimentConfig, tag: &str) -> (RunMetrics, Vec<Json>) {
    let off = coordinator::run(&cfg).expect("untraced run");
    assert!(
        !off.points.is_empty(),
        "run produced no eval points — vacuous parity"
    );
    let (path, path_s) = tmp_trace(tag);
    let traced = coordinator::run(&ExperimentConfig {
        trace: Some(path_s.clone()),
        ..cfg.clone()
    })
    .expect("traced run");
    assert_identical(
        &off,
        &traced,
        &format!("{} trace off vs jsonl", cfg.algorithm.name()),
    );
    let text = std::fs::read_to_string(&path).expect("trace file written");
    let events = json::parse_lines(&text).expect("trace lines parse");
    assert!(!events.is_empty(), "armed tracer emitted nothing");
    let _ = std::fs::remove_file(&path);
    (traced, events)
}

/// Schema check per docs/TRACE_SCHEMA.md: every line has a known kind
/// and that kind's required fields.
fn check_schema(events: &[Json], what: &str) {
    for e in events {
        let kind = e
            .get("kind")
            .and_then(|v| v.as_str())
            .unwrap_or_else(|| panic!("{what}: event without kind: {e:?}"));
        match kind {
            "meta" => {
                assert!(e.get("algorithm").is_some(), "{what}: meta.algorithm");
                assert!(e.get("seed").is_some(), "{what}: meta.seed");
            }
            "span" => {
                for f in ["phase", "round", "wall_ns", "sim_dt", "sim_now"] {
                    assert!(e.get(f).is_some(), "{what}: span.{f} missing: {e:?}");
                }
                assert!(
                    e.get("wall_ns").unwrap().as_f64().unwrap() >= 0.0,
                    "{what}: negative wall_ns"
                );
            }
            "counter" => {
                for f in ["name", "round", "value", "sim_now"] {
                    assert!(e.get(f).is_some(), "{what}: counter.{f} missing");
                }
            }
            "sample" => {
                for f in ["name", "round", "value"] {
                    assert!(e.get(f).is_some(), "{what}: sample.{f} missing");
                }
            }
            "metric" => {
                // L3-telemetry flush stream (default-on when traced).
                for f in ["name", "round", "value", "sim_now"] {
                    assert!(e.get(f).is_some(), "{what}: metric.{f} missing");
                }
            }
            "log" => {
                assert!(e.get("msg").is_some(), "{what}: log.msg missing");
            }
            other => panic!("{what}: unknown event kind {other:?}"),
        }
    }
}

#[test]
fn quafl_bit_identical_and_schema_valid() {
    let (_, events) = run_both(base(Algorithm::QuAFL), "quafl");
    check_schema(&events, "quafl");
    let r = report::aggregate(&events);
    assert_eq!(r.unknown, 0, "no unknown kinds from our own writer");
    assert!(!r.meta.is_empty(), "meta header present");
    // Phases QuAFL must traverse every round.
    for phase in ["select", "quantize", "local_sgd", "reduce", "round"] {
        let agg = r
            .spans
            .get(phase)
            .unwrap_or_else(|| panic!("missing span phase {phase:?}"));
        assert!(agg.count > 0, "{phase}: zero spans");
    }
    // eval_every=2 over 6 rounds -> eval spans exist.
    assert!(r.spans.get("eval").is_some(), "eval spans");
    // "round" spans advance the simulated clock.
    assert!(
        r.spans["round"].sim_dt_total > 0.0,
        "round spans carry sim time"
    );
    for c in [
        "pool_busy_ns",
        "events_drained",
        "event_queue_depth",
        "fenwick_ops",
        "cow_materializations",
        "bits_up",
        "bits_down",
        "steps_total",
    ] {
        assert!(r.counters.get(c).is_some(), "missing counter {c:?}");
    }
    // Counters are cumulative: last poll sees the full-run bit tally.
    assert!(r.counters["bits_up"].last > 0.0, "bits_up accumulated");
    assert!(
        !r.samples.get("delay").map(Vec::is_empty).unwrap_or(true),
        "per-interaction delay samples"
    );
}

#[test]
fn fedavg_bit_identical_and_phases() {
    let (_, events) = run_both(base(Algorithm::FedAvg), "fedavg");
    check_schema(&events, "fedavg");
    let r = report::aggregate(&events);
    // FedAvg broadcasts the server model; QuAFL's quantize phase is absent.
    assert!(r.spans.get("broadcast").is_some(), "broadcast spans");
    assert!(r.spans.get("quantize").is_none(), "no quantize in fedavg");
    assert!(r.spans.get("round").is_some());
}

#[test]
fn fedbuff_bit_identical_with_staleness_samples() {
    let (_, events) = run_both(base(Algorithm::FedBuff), "fedbuff");
    check_schema(&events, "fedbuff");
    let r = report::aggregate(&events);
    assert!(
        !r.samples.get("staleness").map(Vec::is_empty).unwrap_or(true),
        "fedbuff emits per-admission staleness samples"
    );
    assert!(r.spans.get("round").is_some());
}

#[test]
fn trace_level_off_emits_no_structured_events() {
    // A sink armed below Info severity must stay silent AND stay bit-free.
    let cfg = base(Algorithm::QuAFL);
    let off = coordinator::run(&cfg).expect("untraced run");
    let (path, path_s) = tmp_trace("level_off");
    let traced = coordinator::run(&ExperimentConfig {
        trace: Some(path_s),
        trace_level: quafl::trace::Level::Off,
        ..cfg
    })
    .expect("level-off run");
    assert_identical(&off, &traced, "quafl trace level=off");
    let text = std::fs::read_to_string(&path).unwrap_or_default();
    assert!(
        text.trim().is_empty(),
        "level=off trace file should be empty, got {} bytes",
        text.len()
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn report_aggregates_and_writes_bench_phase_json() {
    let (_, events) = run_both(
        ExperimentConfig { rounds: 4, ..base(Algorithm::QuAFL) },
        "report",
    );
    let r = report::aggregate(&events);
    let rendered = r.render();
    assert!(rendered.contains("round"), "breakdown lists the round phase");
    assert!(rendered.contains("local_sgd"));

    let dir = std::env::temp_dir().join(format!(
        "quafl_trace_report_test_{}",
        std::process::id()
    ));
    let out_dir = dir.to_str().unwrap().to_string();
    let path = r.write_bench(&out_dir).expect("write BENCH_phase.json");
    let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(
        doc.get("bench").and_then(|v| v.as_str()),
        Some("phase_breakdown")
    );
    let rows = doc.get("rows").and_then(|v| v.as_arr()).unwrap();
    assert!(!rows.is_empty(), "phase rows present");
    let phases: Vec<&str> = rows
        .iter()
        .filter_map(|row| row.get("phase").and_then(|v| v.as_str()))
        .collect();
    assert!(phases.contains(&"round"), "rows include the round phase");
    let _ = std::fs::remove_dir_all(&dir);
}

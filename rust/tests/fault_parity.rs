//! Parity + recovery suite for the fault-injection subsystem
//! (rust/src/fault): the tentpole guarantee is that **chaos off is
//! free** — with every fault rate at zero no engine is built and each
//! algorithm runs its untouched legacy loop, so trajectories are
//! bit-identical to a build that never heard of faults. Armed runs must
//! be seeded-deterministic (same seed ⇒ same crashes, drops, retries,
//! evictions, counters), visibly different from clean runs, and able to
//! finish under the aggressive all-faults profile via deadline + quorum
//! degradation. See docs/FAULTS.md for the model semantics.

mod common;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::fault::FaultConfig;
use quafl::net::{NetProfile, NetworkConfig};
use quafl::util::json;

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 10,
        s: 4,
        k: 4,
        rounds: 8,
        eval_every: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 37,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        net: NetworkConfig {
            profile: NetProfile::preset("mobile").expect("preset"),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn quantizer_for(algorithm: Algorithm) -> QuantizerKind {
    match algorithm {
        Algorithm::QuAFL => QuantizerKind::Lattice { bits: 10 },
        Algorithm::FedBuff => QuantizerKind::Qsgd { bits: 8 },
        _ => QuantizerKind::None,
    }
}

const ALL: [Algorithm; 4] = [
    Algorithm::QuAFL,
    Algorithm::FedAvg,
    Algorithm::FedBuff,
    Algorithm::Baseline,
];

/// Aggressive all-faults profile: every model armed at once, plus the
/// deadline/quorum recovery path.
fn chaos() -> FaultConfig {
    FaultConfig {
        crash: 0.5,
        drop: 0.4,
        corrupt: 0.2,
        straggle: 0.3,
        straggle_mult: 4.0,
        round_deadline: 60.0,
        quorum: 2,
        ..Default::default()
    }
}

#[test]
fn recovery_knobs_alone_never_arm_the_engine() {
    // Retry/backoff/quorum tuning without any fault *rate* must not
    // build an engine: the trajectory stays bit-identical to the pure
    // default config for every algorithm (counters included — the
    // extended assert_identical compares FaultCounters too).
    for algorithm in ALL {
        let cfg = ExperimentConfig {
            quantizer: quantizer_for(algorithm),
            ..base(algorithm)
        };
        let tuned = ExperimentConfig {
            fault: FaultConfig {
                max_retries: 7,
                backoff_base: 9.0,
                quorum: 3,
                ..Default::default()
            },
            ..cfg.clone()
        };
        assert!(!tuned.fault.enabled());
        let a = coordinator::run(&cfg).expect("default run");
        let b = coordinator::run(&tuned).expect("tuned-but-disarmed run");
        assert!(!a.points.is_empty(), "vacuous parity");
        assert_identical(
            &a,
            &b,
            &format!("{} recovery knobs disarmed", algorithm.name()),
        );
        assert_eq!(a.fault, Default::default(), "clean run counted faults");
    }
}

#[test]
fn armed_runs_are_seed_deterministic() {
    for algorithm in [Algorithm::QuAFL, Algorithm::FedAvg, Algorithm::FedBuff]
    {
        let cfg = ExperimentConfig {
            quantizer: quantizer_for(algorithm),
            fault: chaos(),
            ..base(algorithm)
        };
        let a = coordinator::run(&cfg).expect("armed run A");
        let b = coordinator::run(&cfg).expect("armed run B");
        assert!(!a.points.is_empty(), "vacuous parity");
        assert_identical(
            &a,
            &b,
            &format!("{} armed same-seed replay", algorithm.name()),
        );
    }
}

#[test]
fn armed_chaos_actually_perturbs_the_run() {
    // Non-vacuity: the same seed with chaos armed must produce a
    // *different* trajectory and nonzero recovery counters — otherwise
    // every parity assertion above proves nothing.
    for algorithm in [Algorithm::QuAFL, Algorithm::FedAvg, Algorithm::FedBuff]
    {
        let clean_cfg = ExperimentConfig {
            quantizer: quantizer_for(algorithm),
            ..base(algorithm)
        };
        let clean = coordinator::run(&clean_cfg).expect("clean run");
        let armed = coordinator::run(&ExperimentConfig {
            fault: chaos(),
            ..clean_cfg
        })
        .expect("armed run");
        let c = &armed.fault;
        assert!(c.crashes > 0, "{}: no crashes", algorithm.name());
        assert!(
            c.drops_up + c.drops_down > 0,
            "{}: no drops",
            algorithm.name()
        );
        assert!(c.retries > 0, "{}: no retries", algorithm.name());
        assert!(
            c.wasted_compute_time > 0.0,
            "{}: wasted compute unpriced",
            algorithm.name()
        );
        let diverged = clean.points.len() != armed.points.len()
            || clean
                .points
                .iter()
                .zip(&armed.points)
                .any(|(p, q)| {
                    p.sim_time.to_bits() != q.sim_time.to_bits()
                        || p.total_client_steps != q.total_client_steps
                });
        assert!(diverged, "{}: chaos was a no-op", algorithm.name());
    }
}

#[test]
fn aggressive_chaos_completes_and_evicts() {
    // The graceful-degradation acceptance scenario: every fault model at
    // once, deadline + 2-of-s quorum, repeated crashers evicted — and
    // the run still terminates with eval points and sane accounting.
    let cfg = ExperimentConfig {
        fault: chaos(),
        rounds: 12,
        ..base(Algorithm::QuAFL)
    };
    let m = coordinator::run(&cfg).expect("chaos run must complete");
    assert!(!m.points.is_empty());
    let c = &m.fault;
    assert!(c.crashes >= 2, "crash rate 0.5 produced {} crashes", c.crashes);
    assert!(c.evictions > 0, "repeat crashers were never evicted");
    assert!(c.retries > 0, "drops never retried");
    assert!(c.wasted_bits > 0, "failed uplinks cost no bits");
    // The CSV waste columns mirror the counters' story.
    let last = m.points.last().unwrap();
    assert!(last.wasted_compute_time > 0.0);
    assert!(last.wasted_up_bits > 0);
}

#[test]
fn fault_counters_flow_into_trace_and_health_report() {
    let path = std::env::temp_dir().join(format!(
        "quafl_fault_parity_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let cfg = ExperimentConfig {
        fault: chaos(),
        trace: Some(path.to_str().unwrap().to_string()),
        ..base(Algorithm::QuAFL)
    };
    let m = coordinator::run(&cfg).expect("traced chaos run");
    let text = std::fs::read_to_string(&path).expect("trace file written");
    let events = json::parse_lines(&text).expect("trace lines parse");
    let _ = std::fs::remove_file(&path);

    // The meta header labels the fault plan.
    let meta_faults = events.iter().find_map(|e| {
        (e.get("kind").and_then(|k| k.as_str()) == Some("meta"))
            .then(|| e.get("faults").and_then(|v| v.as_str()))
            .flatten()
    });
    assert_eq!(meta_faults, Some(cfg.fault.label().as_str()));
    assert_ne!(meta_faults, Some("off"));

    // Cumulative fault_* counter events exist, and the last value of the
    // retries series matches the run totals.
    let last_counter = |name: &str| -> Option<f64> {
        events
            .iter()
            .filter(|e| {
                e.get("kind").and_then(|k| k.as_str()) == Some("counter")
                    && e.get("name").and_then(|n| n.as_str()) == Some(name)
            })
            .filter_map(|e| e.get("value").and_then(|v| v.as_f64()))
            .next_back()
    };
    assert_eq!(last_counter("fault_retries"), Some(m.fault.retries as f64));
    assert_eq!(last_counter("fault_crashes"), Some(m.fault.crashes as f64));
    assert!(last_counter("fault_drops_up").unwrap_or(0.0) >= 0.0);

    // And health-report folds the family into its dashboard.
    let report = quafl::telemetry::health::aggregate(&events);
    assert!(report.series.contains_key("fault_retries"));
    let rendered = report.render();
    assert!(rendered.contains("faults"), "{rendered}");
    assert!(rendered.contains("fault_retries"), "{rendered}");
}

#[test]
fn per_model_isolation_only_trips_its_own_counters() {
    let run = |fault: FaultConfig| {
        coordinator::run(&ExperimentConfig {
            fault,
            ..base(Algorithm::QuAFL)
        })
        .expect("isolated-fault run")
        .fault
    };
    let crash_only = run(FaultConfig { crash: 0.4, ..Default::default() });
    assert!(crash_only.crashes > 0);
    assert_eq!(crash_only.drops_up + crash_only.drops_down, 0);
    assert_eq!(crash_only.corruptions, 0);

    let drop_only = run(FaultConfig { drop: 0.4, ..Default::default() });
    assert!(drop_only.drops_up + drop_only.drops_down > 0);
    assert_eq!(drop_only.crashes, 0);
    assert_eq!(drop_only.corruptions, 0);

    let corrupt_only =
        run(FaultConfig { corrupt: 0.5, ..Default::default() });
    assert!(corrupt_only.corruptions > 0);
    assert_eq!(corrupt_only.crashes, 0);
    assert_eq!(corrupt_only.drops_up + corrupt_only.drops_down, 0);
}

#[test]
fn deadline_quorum_combos_validate_correctly() {
    // Quorum above the per-round sample size can never be met.
    let too_big = ExperimentConfig {
        fault: FaultConfig {
            drop: 0.1,
            round_deadline: 30.0,
            quorum: 9,
            ..Default::default()
        },
        ..base(Algorithm::QuAFL)
    };
    assert!(too_big.validate().is_err());
    // A deadline on the zero-cost ideal transport with no time-inflating
    // fault is dead config.
    let idle_deadline = ExperimentConfig {
        net: NetworkConfig::default(),
        fault: FaultConfig { round_deadline: 30.0, ..Default::default() },
        ..base(Algorithm::QuAFL)
    };
    assert!(idle_deadline.validate().is_err());
    // The same deadline priced by a straggler multiplier is fine.
    let with_straggle = ExperimentConfig {
        net: NetworkConfig::default(),
        fault: FaultConfig {
            round_deadline: 30.0,
            straggle: 0.2,
            straggle_mult: 8.0,
            ..Default::default()
        },
        ..base(Algorithm::QuAFL)
    };
    assert!(with_straggle.validate().is_ok());
}

//! Parity + property suite for the simulated transport & availability
//! subsystem (rust/src/net):
//!
//! 1. The default `Ideal` profile must be a **bit-exact no-op**: a config
//!    that never names the network must produce the same trajectory as an
//!    explicit infinite-bandwidth/zero-latency custom profile, and a
//!    priced network must change *only* the time axis (identical losses,
//!    bits and round structure) when availability stays `Always`.
//! 2. Transport-reported bits equal the quantizer encoder's actual output
//!    length for QSGD / lattice / identity (the property FedBuff's event
//!    scheduling relies on).
//! 3. Seeded churn replays identically across runs (run-level; the
//!    worker-count invariance lives in parallel_parity.rs).
//! 4. Under a skewed-bandwidth profile the sim-time ordering between
//!    compressed QuAFL and the uncompressed baseline flips — the scenario
//!    axis the subsystem exists to open.

mod common;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::metrics::RunMetrics;
use quafl::net::{
    AvailabilityKind, ClientAvailability, Dist, NetProfile, NetworkConfig,
};
use quafl::quant::{
    IdentityQuantizer, LatticeQuantizer, QsgdQuantizer, Quantizer,
};
use quafl::util::rng::Rng;

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 10,
        s: 4,
        k: 4,
        rounds: 6,
        eval_every: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 11,
        workers: 2,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

/// An explicitly-materialized network that prices everything at zero:
/// infinite bandwidth, zero latency, always-on clients. Must be
/// indistinguishable from the `Ideal` fast path.
fn explicit_free_net() -> NetworkConfig {
    NetworkConfig {
        profile: NetProfile::Custom {
            up_bw: Dist::Const(f64::INFINITY),
            down_bw: Dist::Const(f64::INFINITY),
            latency: Dist::Const(0.0),
        },
        ..Default::default()
    }
}

#[test]
fn ideal_equals_explicit_free_network_all_algorithms() {
    for algorithm in [
        Algorithm::QuAFL,
        Algorithm::FedAvg,
        Algorithm::FedBuff,
        Algorithm::Baseline,
    ] {
        let ideal = coordinator::run(&base(algorithm)).expect("ideal run");
        let free = coordinator::run(&ExperimentConfig {
            net: explicit_free_net(),
            ..base(algorithm)
        })
        .expect("free-net run");
        assert!(!ideal.points.is_empty());
        assert_identical(&ideal, &free, algorithm.name());
        // And the free network charged nothing.
        assert_eq!(ideal.total_comm_time(), 0.0);
        assert_eq!(free.total_comm_time(), 0.0);
        assert_eq!(ideal.short_rounds, 0);
    }
}

#[test]
fn priced_network_slows_time_but_not_traffic_for_quafl() {
    // With Always availability the sampling stream and per-message wire
    // sizes are independent of link speeds (sizes are dim-deterministic),
    // so the exact bit tallies must match the free network's while the
    // time axis stretches. (Client *step* progress legitimately differs:
    // slower rounds give the Exp(λ) clocks more wall-time per round.)
    let ideal = coordinator::run(&base(Algorithm::QuAFL)).unwrap();
    let slow = coordinator::run(&ExperimentConfig {
        net: NetworkConfig {
            profile: NetProfile::Custom {
                up_bw: Dist::Const(1e5),
                down_bw: Dist::Const(4e5),
                latency: Dist::Const(0.1),
            },
            ..Default::default()
        },
        ..base(Algorithm::QuAFL)
    })
    .unwrap();
    assert_eq!(ideal.points.len(), slow.points.len());
    for (p, q) in ideal.points.iter().zip(&slow.points) {
        assert_eq!(p.round, q.round);
        assert_eq!(p.bits_up, q.bits_up, "identical traffic");
        assert_eq!(p.bits_down, q.bits_down);
        if p.round > 0 {
            assert!(
                q.sim_time > p.sim_time,
                "round {}: priced time {} must exceed free time {}",
                p.round,
                q.sim_time,
                p.sim_time
            );
            assert!(q.comm_up_time > 0.0 && q.comm_down_time > 0.0);
        }
    }
    assert_eq!(slow.short_rounds, 0, "Always availability: no short rounds");
}

#[test]
fn transport_bits_match_encoder_output_for_all_quantizers() {
    // The bits the transport prices (Quantizer::encoded_bits) must equal
    // the encoder's actual wire size, for every scheme and for dims around
    // padding boundaries.
    let quantizers: Vec<Box<dyn Quantizer>> = vec![
        Box::new(IdentityQuantizer),
        Box::new(QsgdQuantizer::new(8)),
        Box::new(QsgdQuantizer::new(14)),
        Box::new(LatticeQuantizer::new(10, 0.01)),
        Box::new(LatticeQuantizer::new(4, 0.05)),
    ];
    let mut rng = Rng::new(3);
    for dim in [1usize, 7, 64, 100, 1023, 1024, 1025, 4096, 5000] {
        let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        for q in &quantizers {
            let msg = q.encode(&x, 42 + dim as u64);
            assert_eq!(
                msg.bits,
                q.encoded_bits(dim),
                "{} dim={dim}: encoder produced {} bits, analytic says {}",
                q.name(),
                msg.bits,
                q.encoded_bits(dim)
            );
        }
    }
}

#[test]
fn churn_run_replays_identically() {
    let cfg = ExperimentConfig {
        net: NetworkConfig {
            profile: NetProfile::preset("mobile").expect("preset"),
            availability: AvailabilityKind::Churn {
                mean_up: 10.0,
                mean_down: 90.0,
            },
            ..Default::default()
        },
        rounds: 20,
        ..base(Algorithm::QuAFL)
    };
    let a = coordinator::run(&cfg).unwrap();
    let b = coordinator::run(&cfg).unwrap();
    assert_identical(&a, &b, "churn replay");
    // Heavy churn must actually bite: some rounds run under-strength.
    assert!(a.short_rounds > 0, "expected short rounds under heavy churn");
}

#[test]
fn churn_process_replay_is_independent_of_query_granularity() {
    // The lazy walk materializes transitions from the same seeded stream
    // no matter how often it is polled.
    let kind = AvailabilityKind::Churn { mean_up: 25.0, mean_down: 10.0 };
    let mut coarse = ClientAvailability::new(kind.clone(), 6, 77);
    let mut fine = ClientAvailability::new(kind, 6, 77);
    // Fine polls at 0.5; coarse only at multiples of 5.0.
    for step in 0..400 {
        let t = step as f64 * 0.5;
        let f = (0..6).map(|i| fine.is_up(i, t)).collect::<Vec<_>>();
        if step % 10 == 0 {
            let c = (0..6).map(|i| coarse.is_up(i, t)).collect::<Vec<_>>();
            assert_eq!(f, c, "t={t}");
        }
    }
}

#[test]
fn bandwidth_skew_flips_sim_time_ordering() {
    // The acceptance scenario: compressed QuAFL vs the uncompressed
    // protocol. On an ideal network the uncompressed QuAFL run finishes
    // the same rounds in the same simulated time; on a constrained uplink
    // the compressed run finishes first, by roughly the compression ratio.
    let slow_net = NetworkConfig {
        profile: NetProfile::Custom {
            up_bw: Dist::Const(5e4),
            down_bw: Dist::Const(2e5),
            latency: Dist::Const(0.1),
        },
        ..Default::default()
    };
    let lattice = ExperimentConfig {
        quantizer: QuantizerKind::Lattice { bits: 10 },
        ..base(Algorithm::QuAFL)
    };
    let fp32 = ExperimentConfig {
        quantizer: QuantizerKind::None,
        ..base(Algorithm::QuAFL)
    };
    let t_end = |m: &RunMetrics| m.points.last().unwrap().sim_time;

    let ideal_lattice = coordinator::run(&lattice).unwrap();
    let ideal_fp32 = coordinator::run(&fp32).unwrap();
    assert_eq!(
        t_end(&ideal_lattice).to_bits(),
        t_end(&ideal_fp32).to_bits(),
        "free network: identical round schedule regardless of payload"
    );

    let slow_lattice = coordinator::run(&ExperimentConfig {
        net: slow_net.clone(),
        ..lattice
    })
    .unwrap();
    let slow_fp32 =
        coordinator::run(&ExperimentConfig { net: slow_net, ..fp32 }).unwrap();
    assert!(
        t_end(&slow_lattice) < t_end(&slow_fp32),
        "constrained uplink: compressed {} should beat uncompressed {}",
        t_end(&slow_lattice),
        t_end(&slow_fp32)
    );
    // The gap must reflect the >2.5x wire-size difference, not noise.
    let comm_ratio =
        slow_fp32.total_comm_time() / slow_lattice.total_comm_time();
    assert!(comm_ratio > 2.0, "comm-time ratio {comm_ratio}");
}

#[test]
fn broadcast_downlink_prices_one_transmission_per_round() {
    // FedAvg on constant symmetric links: unicast pricing charges s
    // payloads per round, `--broadcast-downlink` exactly one — and since
    // every link is identical, the per-client receive times (hence the
    // clocks, models, and the whole time axis) are bit-identical; only
    // the downlink accounting shrinks by a factor of s.
    let cfg = ExperimentConfig {
        quantizer: QuantizerKind::None,
        net: NetworkConfig {
            profile: NetProfile::Custom {
                up_bw: Dist::Const(1e5),
                down_bw: Dist::Const(1e5),
                latency: Dist::Const(0.1),
            },
            ..Default::default()
        },
        ..base(Algorithm::FedAvg)
    };
    let unicast = coordinator::run(&cfg).unwrap();
    let broadcast = coordinator::run(&ExperimentConfig {
        broadcast_downlink: true,
        ..cfg.clone()
    })
    .unwrap();
    assert_eq!(unicast.points.len(), broadcast.points.len());
    assert_eq!(unicast.short_rounds, 0, "Always availability: full rounds");
    let s = cfg.s as u64;
    for (p, q) in unicast.points.iter().zip(&broadcast.points) {
        assert_eq!(p.round, q.round);
        assert_eq!(p.bits_up, q.bits_up, "uplink traffic unchanged");
        assert_eq!(
            p.bits_down,
            q.bits_down * s,
            "round {}: broadcast pays one payload where unicast pays s",
            p.round
        );
        assert_eq!(
            p.sim_time.to_bits(),
            q.sim_time.to_bits(),
            "identical links: same receive times, same time axis"
        );
        assert_eq!(p.val_loss.to_bits(), q.val_loss.to_bits());
        if p.round > 0 {
            assert!(
                q.comm_down_time < p.comm_down_time,
                "round {}: shared medium must charge less downlink time",
                p.round
            );
        }
    }
}

#[test]
fn compute_corr_reshuffles_links_but_not_traffic() {
    // The copula changes *which client* gets which link, so the time
    // axis moves — but wire sizes are dim-deterministic, so the exact
    // bit tallies cannot.
    let net = |rho: f64| NetworkConfig {
        profile: NetProfile::Custom {
            up_bw: Dist::LogNormal { median: 1e5, sigma: 0.8 },
            down_bw: Dist::LogNormal { median: 4e5, sigma: 0.8 },
            latency: Dist::Const(0.1),
        },
        compute_corr: rho,
        ..Default::default()
    };
    let independent = coordinator::run(&ExperimentConfig {
        net: net(0.0),
        ..base(Algorithm::QuAFL)
    })
    .unwrap();
    let correlated = coordinator::run(&ExperimentConfig {
        net: net(0.9),
        ..base(Algorithm::QuAFL)
    })
    .unwrap();
    assert_eq!(independent.points.len(), correlated.points.len());
    let mut time_differs = false;
    for (p, q) in independent.points.iter().zip(&correlated.points) {
        assert_eq!(p.bits_up, q.bits_up, "round {}: traffic", p.round);
        assert_eq!(p.bits_down, q.bits_down);
        if p.sim_time.to_bits() != q.sim_time.to_bits() {
            time_differs = true;
        }
    }
    assert!(time_differs, "rho=0.9 left the time axis untouched");
}

#[test]
fn duty_cycle_gates_sampling_end_to_end() {
    let m = coordinator::run(&ExperimentConfig {
        net: NetworkConfig {
            profile: NetProfile::Ideal,
            availability: AvailabilityKind::DutyCycle {
                period: 40.0,
                on_fraction: 0.25,
            },
            ..Default::default()
        },
        rounds: 12,
        ..base(Algorithm::QuAFL)
    })
    .unwrap();
    // With only ~25% of 10 clients reachable at any instant, most rounds
    // cannot fill s=4.
    assert!(m.short_rounds > 0, "duty cycle never produced a short round");
    assert!(m.final_loss().is_finite());
}

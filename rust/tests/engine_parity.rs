//! Engine parity: the XLA artifact path (Pallas kernels → JAX → HLO →
//! PJRT) and the native Rust engine implement the *same* training math.
//! Same params + same batches ⇒ near-identical losses and parameters,
//! step for step. This is the strongest cross-layer correctness signal in
//! the repo: it transitively checks the Pallas kernels, the hand-written
//! custom_vjp backward, the AOT lowering, the HLO text round-trip, the
//! PJRT marshaling, and the native implementation against each other.

use quafl::data::{SynthFamily, SynthSpec};
use quafl::engine::{NativeEngine, TrainEngine, XlaEngine};
use quafl::model::ModelSpec;
use quafl::util::stats::{l2_norm, max_abs_diff};

const ARTIFACTS: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(ARTIFACTS).join("meta.json").exists()
}

#[test]
fn step_for_step_parity_mlp() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp").unwrap();
    let mut xla = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let mut native = NativeEngine::new(spec.clone(), 32);
    let mut p_xla = spec.init_params(11);
    let mut p_native = p_xla.clone();
    let (train, _) = SynthSpec::family(SynthFamily::Hard, 512, 32, 21).generate();

    let mut rng = quafl::util::rng::Rng::new(33);
    for step in 0..10 {
        let idx: Vec<usize> = (0..32).map(|_| rng.gen_range(train.len())).collect();
        let batch = train.gather_batch(&idx);
        let lx = xla.train_step(&mut p_xla, &batch, 0.1).unwrap();
        let ln = native.train_step(&mut p_native, &batch, 0.1).unwrap();
        assert!(
            (lx - ln).abs() < 1e-3 * (1.0 + ln.abs()),
            "step {step}: xla loss {lx} vs native {ln}"
        );
        let scale = l2_norm(&p_native).max(1.0) as f32;
        let diff = max_abs_diff(&p_xla, &p_native);
        assert!(
            diff < 2e-4 * scale,
            "step {step}: param divergence {diff} (scale {scale})"
        );
    }
}

#[test]
fn parity_holds_for_deep_model() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let spec = ModelSpec::by_name("mlp_deep").unwrap();
    let mut xla = XlaEngine::new(ARTIFACTS, &spec).unwrap();
    let mut native = NativeEngine::new(spec.clone(), 32);
    let mut p_xla = spec.init_params(5);
    let mut p_native = p_xla.clone();
    let (train, _) = SynthSpec::family(SynthFamily::Mnist, 256, 32, 8).generate();
    let idx: Vec<usize> = (0..32).collect();
    let batch = train.gather_batch(&idx);
    for step in 0..3 {
        let lx = xla.train_step(&mut p_xla, &batch, 0.05).unwrap();
        let ln = native.train_step(&mut p_native, &batch, 0.05).unwrap();
        assert!(
            (lx - ln).abs() < 2e-3 * (1.0 + ln.abs()),
            "step {step}: {lx} vs {ln}"
        );
    }
    let diff = max_abs_diff(&p_xla, &p_native);
    assert!(diff < 1e-3, "deep model divergence {diff}");
}

#[test]
fn full_quafl_run_agrees_across_engines() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Same config/seed through the whole coordinator: final accuracy from
    // the two engines must agree closely (trajectories are identical
    // modulo float accumulation order).
    use quafl::config::ExperimentConfig;
    let mut cfg = ExperimentConfig {
        n: 6,
        s: 2,
        k: 3,
        rounds: 8,
        eval_every: 8,
        train_samples: 512,
        val_samples: 256,
        seed: 77,
        ..Default::default()
    };
    cfg.use_xla = false;
    let native = quafl::coordinator::run(&cfg).unwrap();
    cfg.use_xla = true;
    let xla = quafl::coordinator::run(&cfg).unwrap();
    let (a, b) = (native.final_acc(), xla.final_acc());
    assert!(
        (a - b).abs() < 0.05,
        "native acc {a} vs xla acc {b}"
    );
    let (la, lb) = (native.final_loss(), xla.final_loss());
    assert!(
        (la - lb).abs() < 0.05 * (1.0 + la.abs()),
        "native loss {la} vs xla loss {lb}"
    );
}

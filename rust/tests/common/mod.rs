//! Shared helpers for the integration-test binaries (not itself a test
//! target — Cargo treats `tests/common/` as a plain module directory).

use quafl::metrics::RunMetrics;

/// Bitwise comparison of two runs: every eval-point field (f64s compared
/// by bit pattern — these are determinism tests, tolerances would defeat
/// their purpose), the interaction counters, and the potential series.
/// The single definition keeps the parallel-parity and net-parity suites
/// asserting the same notion of "identical trajectory".
pub fn assert_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{what}: eval point count");
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.round, q.round, "{what}: round");
        assert_eq!(
            p.sim_time.to_bits(),
            q.sim_time.to_bits(),
            "{what}: sim_time at round {}",
            p.round
        );
        assert_eq!(
            p.total_client_steps, q.total_client_steps,
            "{what}: steps at round {}",
            p.round
        );
        assert_eq!(p.bits_up, q.bits_up, "{what}: bits_up at round {}", p.round);
        assert_eq!(
            p.bits_down, q.bits_down,
            "{what}: bits_down at round {}",
            p.round
        );
        assert_eq!(
            p.comm_up_time.to_bits(),
            q.comm_up_time.to_bits(),
            "{what}: comm_up_time at round {}",
            p.round
        );
        assert_eq!(
            p.comm_down_time.to_bits(),
            q.comm_down_time.to_bits(),
            "{what}: comm_down_time at round {}",
            p.round
        );
        assert_eq!(
            p.val_loss.to_bits(),
            q.val_loss.to_bits(),
            "{what}: val_loss at round {} ({} vs {})",
            p.round,
            p.val_loss,
            q.val_loss
        );
        assert_eq!(
            p.val_acc.to_bits(),
            q.val_acc.to_bits(),
            "{what}: val_acc at round {}",
            p.round
        );
        assert_eq!(
            p.train_loss.to_bits(),
            q.train_loss.to_bits(),
            "{what}: train_loss at round {}",
            p.round
        );
        assert_eq!(
            p.participation_gini.to_bits(),
            q.participation_gini.to_bits(),
            "{what}: participation_gini at round {}",
            p.round
        );
        assert_eq!(
            p.staleness_max, q.staleness_max,
            "{what}: staleness_max at round {}",
            p.round
        );
        assert_eq!(
            p.staleness_mean.to_bits(),
            q.staleness_mean.to_bits(),
            "{what}: staleness_mean at round {}",
            p.round
        );
        assert_eq!(
            p.wasted_up_bits, q.wasted_up_bits,
            "{what}: wasted_up_bits at round {}",
            p.round
        );
        assert_eq!(
            p.wasted_compute_time.to_bits(),
            q.wasted_compute_time.to_bits(),
            "{what}: wasted_compute_time at round {}",
            p.round
        );
    }
    assert_eq!(a.fault, b.fault, "{what}: fault counters");
    assert_eq!(a.total_interactions, b.total_interactions, "{what}");
    assert_eq!(
        a.zero_progress_interactions, b.zero_progress_interactions,
        "{what}"
    );
    assert_eq!(a.sum_observed_steps, b.sum_observed_steps, "{what}");
    assert_eq!(a.short_rounds, b.short_rounds, "{what}: short_rounds");
    assert_eq!(
        a.rejected_interactions, b.rejected_interactions,
        "{what}: rejected_interactions"
    );
    assert_eq!(a.potential.len(), b.potential.len(), "{what}: potential len");
    for (i, (x, y)) in a.potential.iter().zip(&b.potential).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: potential[{i}]");
    }
}

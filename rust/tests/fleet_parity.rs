//! Parity + property suite for the copy-on-write fleet store
//! (rust/src/fleet):
//!
//! 1. Under random touch/read patterns, [`ClientModelStore`] must
//!    materialize dense state identical to a reference `Vec<Vec<f32>>`,
//!    while never holding more distinct allocations than touched
//!    clients + the shared base.
//! 2. End-to-end QuAFL and FedBuff trajectories must be **bit-identical**
//!    between the CoW store and the eager `--dense-fleet` reference
//!    layout — every eval field, the bit tallies, and the potential
//!    series (which folds the store's dense view).
//! 3. A huge-fleet run (n=2000, s=8) must allocate ≪ n full models:
//!    `peak_model_bytes` stays O(s·rounds·d), not O(n·d).

mod common;

use std::sync::Arc;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::fleet::ClientModelStore;
use quafl::prop_assert;
use quafl::testing::{check, PropConfig};

#[test]
fn prop_store_matches_dense_reference_under_random_ops() {
    check(
        "fleet_store_cow_vs_reference",
        PropConfig { cases: 20, max_size: 24, seed: 0xF1EE7 },
        |rng, size| {
            let n = 2 + size;
            let d = 1 + rng.gen_range(6);
            let base: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
            let mut store = ClientModelStore::new(n, base.clone());
            let mut reference: Vec<Vec<f32>> = vec![base; n];
            let mut touched = std::collections::BTreeSet::new();
            for _ in 0..80 {
                match rng.gen_range(3) {
                    0 => {
                        // Diverge: client i gets its own fresh model.
                        let i = rng.gen_range(n);
                        let v: Vec<f32> =
                            (0..d).map(|_| rng.next_f32()).collect();
                        store.set(i, v.clone());
                        reference[i] = v;
                        touched.insert(i);
                    }
                    1 => {
                        // Alias: client i points at client j's snapshot
                        // (the FedBuff pull pattern).
                        let i = rng.gen_range(n);
                        let j = rng.gen_range(n);
                        let snap = store.snapshot(j);
                        store.set_shared(i, snap);
                        reference[i] = reference[j].clone();
                        touched.insert(i);
                    }
                    _ => {
                        // Read: a single client's view must match.
                        let i = rng.gen_range(n);
                        prop_assert!(
                            store.get(i) == reference[i].as_slice(),
                            "read mismatch at client {i}"
                        );
                    }
                }
            }
            // The dense view walks clients in order and must equal the
            // reference exactly (same floats, same order).
            let dense: Vec<&[f32]> = store.iter_dense().collect();
            prop_assert!(dense.len() == n, "dense view length {}", dense.len());
            for (i, r) in reference.iter().enumerate() {
                prop_assert!(
                    dense[i] == r.as_slice(),
                    "dense view mismatch at client {i}"
                );
            }
            // CoW bound: distinct allocations never exceed touched + base.
            prop_assert!(
                store.resident_models() <= touched.len() + 1,
                "resident {} > touched {} + 1",
                store.resident_models(),
                touched.len()
            );
            prop_assert!(
                store.peak_models() >= store.resident_models(),
                "peak below resident"
            );
            Ok(())
        },
    );
}

#[test]
fn untouched_store_is_one_allocation_dense_store_is_n() {
    let cow = ClientModelStore::new(500, vec![0.25; 16]);
    assert_eq!(cow.resident_models(), 1);
    assert_eq!(cow.peak_models(), 1);
    let dense = ClientModelStore::new_dense(500, vec![0.25; 16]);
    assert_eq!(dense.resident_models(), 500);
}

#[test]
fn snapshots_are_immutable_across_divergence() {
    let mut store = ClientModelStore::new(3, vec![1.0, 2.0]);
    let snap: Arc<Vec<f32>> = store.snapshot(1);
    store.set(1, vec![9.0, 9.0]);
    assert_eq!(snap.as_slice(), &[1.0, 2.0]);
    assert_eq!(store.get(1), &[9.0, 9.0]);
    assert_eq!(store.get(0), &[1.0, 2.0]);
}

/// Parameter dimension d of the config's model (no hardcoded constants —
/// the bounds below must track the model zoo).
fn model_dim(cfg: &ExperimentConfig) -> usize {
    quafl::model::ModelSpec::by_name(&cfg.model)
        .unwrap()
        .num_params()
}

fn e2e_base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 12,
        s: 4,
        k: 4,
        rounds: 6,
        eval_every: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 23,
        workers: 2,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

fn dense_vs_cow(cfg: ExperimentConfig, what: &str) {
    let cow = coordinator::run(&cfg).expect("cow run");
    assert!(!cow.points.is_empty(), "{what}: no eval points");
    let dense =
        coordinator::run(&ExperimentConfig { dense_fleet: true, ..cfg })
            .expect("dense run");
    assert_identical(&cow, &dense, what);
    // The one legitimate difference: the dense layout is resident-heavier
    // (n allocations up front vs the shared base + diverged clients).
    assert!(
        dense.peak_model_bytes() >= cow.peak_model_bytes(),
        "{what}: dense peak {} below cow peak {}",
        dense.peak_model_bytes(),
        cow.peak_model_bytes()
    );
}

#[test]
fn quafl_cow_matches_dense_bitwise() {
    // track_potential stresses the dense-view fold every round.
    dense_vs_cow(
        ExperimentConfig {
            track_potential: true,
            ..e2e_base(Algorithm::QuAFL)
        },
        "quafl dense-vs-cow",
    );
}

#[test]
fn quafl_weighted_cow_matches_dense_bitwise() {
    dense_vs_cow(
        ExperimentConfig {
            weighted: true,
            track_potential: true,
            ..e2e_base(Algorithm::QuAFL)
        },
        "quafl weighted dense-vs-cow",
    );
}

#[test]
fn fedbuff_cow_matches_dense_bitwise() {
    dense_vs_cow(
        ExperimentConfig {
            quantizer: QuantizerKind::Qsgd { bits: 8 },
            ..e2e_base(Algorithm::FedBuff)
        },
        "fedbuff dense-vs-cow",
    );
}

#[test]
fn fedbuff_uncompressed_cow_matches_dense_bitwise() {
    dense_vs_cow(
        ExperimentConfig {
            quantizer: QuantizerKind::None,
            ..e2e_base(Algorithm::FedBuff)
        },
        "fedbuff fp32 dense-vs-cow",
    );
}

#[test]
fn price_init_broadcast_default_off_is_bit_exact_and_on_charges_bits() {
    // Default off: the flag's existence must not perturb anything (the
    // config is identical, but pin the accounting explicitly).
    let cfg = e2e_base(Algorithm::QuAFL);
    let off = coordinator::run(&cfg).unwrap();
    let on = coordinator::run(&ExperimentConfig {
        price_init_broadcast: true,
        ..cfg.clone()
    })
    .unwrap();
    // Under the Ideal transport the broadcast costs 0.0 time and leaves
    // the clocks untouched, so the trajectory matches except for the
    // extra n full-precision downlinks in the tally.
    let d_bits = (model_dim(&cfg) * 32) as u64;
    let extra = cfg.n as u64 * d_bits;
    assert_eq!(off.points.len(), on.points.len());
    for (p, q) in off.points.iter().zip(&on.points) {
        assert_eq!(p.bits_down + extra, q.bits_down, "round {}", p.round);
        assert_eq!(p.bits_up, q.bits_up);
        assert_eq!(p.sim_time.to_bits(), q.sim_time.to_bits());
        assert_eq!(p.val_loss.to_bits(), q.val_loss.to_bits());
    }
}

#[test]
fn huge_fleet_run_allocates_far_fewer_than_n_models() {
    let base = ExperimentConfig {
        n: 2000,
        s: 8,
        k: 2,
        rounds: 5,
        eval_every: 5,
        train_samples: 2000,
        val_samples: 64,
        batch: 16,
        quantizer: QuantizerKind::None,
        ..Default::default()
    };
    let model_bytes = (model_dim(&base) * 4) as u64;
    let dense_bytes = base.n as u64 * model_bytes;
    for algorithm in [Algorithm::QuAFL, Algorithm::FedBuff] {
        let m = coordinator::run(&ExperimentConfig {
            algorithm,
            ..base.clone()
        })
        .unwrap();
        let peak = m.peak_model_bytes();
        assert!(peak > 0, "{algorithm:?}: peak never recorded");
        // At most s clients diverge per QuAFL round (Z arrivals per
        // FedBuff aggregation) + shared bases/snapshots + transient
        // set() overlap: a generous O(s·rounds) bound, far below n.
        let bound = (base.s * base.rounds + 10) as u64 * model_bytes;
        assert!(
            peak <= bound,
            "{algorithm:?}: peak {peak} exceeds O(touched) bound {bound}"
        );
        assert!(
            peak * 20 <= dense_bytes,
            "{algorithm:?}: peak {peak} not ≪ dense {dense_bytes}"
        );
    }
}

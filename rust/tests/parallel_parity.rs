//! Determinism/parity suite for the parallel client-execution subsystem
//! (rust/src/exec): for every algorithm, the trajectory must be
//! **bit-identical** for any worker count — same eval points (losses,
//! accuracies, simulated times), same bit accounting, same step counts,
//! same potential series. `workers = 1` is exactly the serial path, so
//! equality against it proves the fan-out + in-order reduction changes
//! nothing but wall-clock.

use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::data::PartitionKind;
use quafl::metrics::RunMetrics;

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 10,
        s: 4,
        k: 4,
        rounds: 6,
        eval_every: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 11,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

/// Bitwise comparison of two runs (f64s compared by bit pattern — this is
/// a determinism test, tolerances would defeat its purpose).
fn assert_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{what}: eval point count");
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.round, q.round, "{what}: round");
        assert_eq!(
            p.sim_time.to_bits(),
            q.sim_time.to_bits(),
            "{what}: sim_time at round {}",
            p.round
        );
        assert_eq!(
            p.total_client_steps, q.total_client_steps,
            "{what}: steps at round {}",
            p.round
        );
        assert_eq!(p.bits_up, q.bits_up, "{what}: bits_up at round {}", p.round);
        assert_eq!(
            p.bits_down, q.bits_down,
            "{what}: bits_down at round {}",
            p.round
        );
        assert_eq!(
            p.val_loss.to_bits(),
            q.val_loss.to_bits(),
            "{what}: val_loss at round {} ({} vs {})",
            p.round,
            p.val_loss,
            q.val_loss
        );
        assert_eq!(
            p.val_acc.to_bits(),
            q.val_acc.to_bits(),
            "{what}: val_acc at round {}",
            p.round
        );
        assert_eq!(
            p.train_loss.to_bits(),
            q.train_loss.to_bits(),
            "{what}: train_loss at round {}",
            p.round
        );
    }
    assert_eq!(a.total_interactions, b.total_interactions, "{what}");
    assert_eq!(
        a.zero_progress_interactions, b.zero_progress_interactions,
        "{what}"
    );
    assert_eq!(a.sum_observed_steps, b.sum_observed_steps, "{what}");
    assert_eq!(a.potential.len(), b.potential.len(), "{what}: potential len");
    for (i, (x, y)) in a.potential.iter().zip(&b.potential).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: potential[{i}]");
    }
}

fn parity_for(cfg: ExperimentConfig) {
    let serial = coordinator::run(&ExperimentConfig { workers: 1, ..cfg.clone() })
        .expect("serial run");
    assert!(
        !serial.points.is_empty(),
        "run produced no eval points — vacuous parity"
    );
    for workers in [2usize, 8] {
        let par = coordinator::run(&ExperimentConfig { workers, ..cfg.clone() })
            .expect("parallel run");
        assert_identical(
            &serial,
            &par,
            &format!("{} workers={workers}", cfg.algorithm.name()),
        );
    }
}

#[test]
fn quafl_parity_across_worker_counts() {
    parity_for(base(Algorithm::QuAFL));
}

#[test]
fn quafl_parity_weighted_non_iid_with_potential() {
    // Stress the richer code paths: speed weighting (η_i blending in the
    // workers), by-class shards, and the Φ_t series.
    parity_for(ExperimentConfig {
        weighted: true,
        partition: PartitionKind::ByClass,
        track_potential: true,
        ..base(Algorithm::QuAFL)
    });
}

#[test]
fn fedavg_parity_across_worker_counts() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::None,
        ..base(Algorithm::FedAvg)
    });
}

#[test]
fn fedbuff_parity_across_worker_counts() {
    // QSGD path: per-message compression seeds are assigned in event
    // order, so the compressed deltas must also be bit-identical.
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::Qsgd { bits: 8 },
        ..base(Algorithm::FedBuff)
    });
}

#[test]
fn fedbuff_parity_uncompressed() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::None,
        ..base(Algorithm::FedBuff)
    });
}

#[test]
fn baseline_parity_across_worker_counts() {
    parity_for(ExperimentConfig {
        rounds: 12,
        eval_every: 4,
        ..base(Algorithm::Baseline)
    });
}

#[test]
fn workers_knob_leaves_config_validation_unaffected() {
    for workers in [0usize, 1, 3, 64] {
        let cfg = ExperimentConfig { workers, ..base(Algorithm::QuAFL) };
        assert!(cfg.validate().is_ok(), "workers={workers}");
    }
}

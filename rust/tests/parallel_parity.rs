//! Determinism/parity suite for the parallel client-execution subsystem
//! (rust/src/exec): for every algorithm, the trajectory must be
//! **bit-identical** for any worker count — same eval points (losses,
//! accuracies, simulated times), same bit accounting, same step counts,
//! same potential series. `workers = 1` is exactly the serial path, so
//! equality against it proves the fan-out + in-order reduction changes
//! nothing but wall-clock.

mod common;

use common::assert_identical;
use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind, TimingConfig};
use quafl::coordinator;
use quafl::data::PartitionKind;
use quafl::net::{AvailabilityKind, NetProfile, NetworkConfig};

fn base(algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        algorithm,
        n: 10,
        s: 4,
        k: 4,
        rounds: 6,
        eval_every: 2,
        train_samples: 512,
        val_samples: 128,
        batch: 16,
        seed: 11,
        timing: TimingConfig { slow_fraction: 0.3, ..Default::default() },
        ..Default::default()
    }
}

fn parity_for(cfg: ExperimentConfig) {
    let serial = coordinator::run(&ExperimentConfig { workers: 1, ..cfg.clone() })
        .expect("serial run");
    assert!(
        !serial.points.is_empty(),
        "run produced no eval points — vacuous parity"
    );
    for workers in [2usize, 8] {
        let par = coordinator::run(&ExperimentConfig { workers, ..cfg.clone() })
            .expect("parallel run");
        assert_identical(
            &serial,
            &par,
            &format!("{} workers={workers}", cfg.algorithm.name()),
        );
    }
}

#[test]
fn quafl_parity_across_worker_counts() {
    parity_for(base(Algorithm::QuAFL));
}

#[test]
fn quafl_parity_weighted_non_iid_with_potential() {
    // Stress the richer code paths: speed weighting (η_i blending in the
    // workers), by-class shards, and the Φ_t series.
    parity_for(ExperimentConfig {
        weighted: true,
        partition: PartitionKind::ByClass,
        track_potential: true,
        ..base(Algorithm::QuAFL)
    });
}

#[test]
fn fedavg_parity_across_worker_counts() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::None,
        ..base(Algorithm::FedAvg)
    });
}

#[test]
fn fedbuff_parity_across_worker_counts() {
    // QSGD path: per-message compression seeds are assigned in event
    // order, so the compressed deltas must also be bit-identical.
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::Qsgd { bits: 8 },
        ..base(Algorithm::FedBuff)
    });
}

#[test]
fn fedbuff_parity_uncompressed() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::None,
        ..base(Algorithm::FedBuff)
    });
}

#[test]
fn baseline_parity_across_worker_counts() {
    parity_for(ExperimentConfig {
        rounds: 12,
        eval_every: 4,
        ..base(Algorithm::Baseline)
    });
}

/// A non-trivial network profile: priced transport + churn availability.
fn lossy_net() -> NetworkConfig {
    NetworkConfig {
        profile: NetProfile::preset("mobile").expect("preset"),
        availability: AvailabilityKind::Churn { mean_up: 60.0, mean_down: 30.0 },
        ..Default::default()
    }
}

#[test]
fn quafl_parity_under_transport_and_churn() {
    // The net subsystem runs entirely in the serial pre-pass/reduction, so
    // a seeded churn + bandwidth profile must replay bit-identically
    // across worker counts too.
    parity_for(ExperimentConfig {
        net: lossy_net(),
        rounds: 10,
        ..base(Algorithm::QuAFL)
    });
}

#[test]
fn fedavg_parity_under_transport_and_churn() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::None,
        net: lossy_net(),
        ..base(Algorithm::FedAvg)
    });
}

#[test]
fn fedbuff_parity_under_transport_and_churn() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::Qsgd { bits: 8 },
        net: lossy_net(),
        ..base(Algorithm::FedBuff)
    });
}

/// The full chaos profile: all four fault models plus deadline/quorum
/// recovery ([`quafl::fault`]). Fault draws come from stateless
/// per-(round, client) RNG leaves and every fault decision runs in the
/// serial pre-pass / reduction, so a faulted trajectory — including the
/// recovery counters, which [`assert_identical`] also compares — must
/// replay bit-identically across worker counts.
fn chaos_plan() -> quafl::fault::FaultConfig {
    quafl::fault::FaultConfig {
        crash: 0.1,
        drop: 0.2,
        corrupt: 0.1,
        straggle: 0.3,
        straggle_mult: 4.0,
        round_deadline: 60.0,
        quorum: 2,
        ..Default::default()
    }
}

#[test]
fn quafl_parity_under_chaos() {
    parity_for(ExperimentConfig {
        fault: chaos_plan(),
        net: lossy_net(),
        rounds: 10,
        ..base(Algorithm::QuAFL)
    });
}

#[test]
fn fedavg_parity_under_chaos() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::None,
        fault: chaos_plan(),
        net: lossy_net(),
        ..base(Algorithm::FedAvg)
    });
}

#[test]
fn fedbuff_parity_under_chaos() {
    parity_for(ExperimentConfig {
        quantizer: QuantizerKind::Qsgd { bits: 8 },
        fault: chaos_plan(),
        net: lossy_net(),
        ..base(Algorithm::FedBuff)
    });
}

#[test]
fn workers_knob_leaves_config_validation_unaffected() {
    for workers in [0usize, 1, 3, 64] {
        let cfg = ExperimentConfig { workers, ..base(Algorithm::QuAFL) };
        assert!(cfg.validate().is_ok(), "workers={workers}");
    }
}

//! Discrete-event timing simulation (paper Appendix A.2).
//!
//! Each client's local-step durations are i.i.d. Exp(λ) draws: λ = 1/2 for
//! fast clients (mean 2) and λ = 1/8 for slow clients (mean 8); a
//! configurable fraction of clients is slow. The server's clock advances
//! by `sit` per round plus `swt` between rounds.
//!
//! The key query the algorithms make is: *given that I last synchronized
//! at time t0, how many local steps (≤ K) have I completed by time t1?*
//! `ClientClock::steps_completed` answers it by materializing the step
//! process lazily — draws are consumed only as simulated time passes, so
//! the process is consistent across queries (memoryless arrivals are NOT
//! redrawn; the next step's remaining time is preserved, which makes the
//! process exactly a renewal process interrupted at interaction times).

use crate::config::TimingConfig;
use crate::util::rng::Rng;

/// One client's compute-time process.
#[derive(Clone, Debug)]
pub struct ClientClock {
    pub slow: bool,
    lambda: f64,
    rng: Rng,
    /// absolute time at which the client's *current* step will finish
    next_finish: f64,
    /// absolute time the client (re)started its local computation
    epoch: f64,
    /// steps completed since `epoch`
    done_since_epoch: usize,
}

impl ClientClock {
    pub fn new(slow: bool, timing: &TimingConfig, rng: Rng) -> Self {
        let lambda = if slow { timing.slow_lambda } else { timing.fast_lambda };
        let mut c = ClientClock {
            slow,
            lambda,
            rng,
            next_finish: 0.0,
            epoch: 0.0,
            done_since_epoch: 0,
        };
        c.next_finish = c.draw();
        c
    }

    fn draw(&mut self) -> f64 {
        self.rng.exponential(self.lambda)
    }

    /// Expected steps per unit time × interval — analytic helper for H_i
    /// estimation (E[steps in Δt] = λΔt for an unclamped renewal process).
    pub fn rate(&self) -> f64 {
        self.lambda
    }

    /// Advance the process to absolute time `now` and return how many
    /// steps completed since the last restart, capped at `k`. Does not
    /// restart the process.
    pub fn steps_completed(&mut self, now: f64, k: usize) -> usize {
        while self.done_since_epoch < k && self.next_finish <= now {
            self.done_since_epoch += 1;
            let d = self.draw();
            self.next_finish += d;
        }
        self.done_since_epoch
    }

    /// Restart local computation at absolute time `now` (the client just
    /// finished a server interaction and begins K fresh steps). The
    /// in-flight step is abandoned and a fresh one starts — matching the
    /// algorithm, where the client begins steps on the *new* model.
    pub fn restart(&mut self, now: f64) {
        self.epoch = now;
        self.done_since_epoch = 0;
        let d = self.draw();
        self.next_finish = now + d;
    }

    /// Absolute time at which the client will have finished `k` steps from
    /// its current epoch (used by the synchronous FedAvg round and by
    /// FedBuff's completion events). Advances the process.
    pub fn finish_time_for(&mut self, k: usize) -> f64 {
        while self.done_since_epoch < k {
            self.done_since_epoch += 1;
            if self.done_since_epoch < k {
                let d = self.draw();
                self.next_finish += d;
            }
        }
        self.next_finish
    }
}

/// Build the fleet of client clocks: the first ⌈slow_fraction·n⌉ client
/// ids are slow (deterministic given n; which *data shard* those ids hold
/// is already randomized by partitioning).
pub fn build_clocks(n: usize, timing: &TimingConfig, seed: u64) -> Vec<ClientClock> {
    let n_slow = (timing.slow_fraction * n as f64).round() as usize;
    (0..n)
        .map(|i| {
            let rng = Rng::new(crate::util::rng::derive_seed(seed, 0x5EED_0000 + i as u64));
            ClientClock::new(i < n_slow, timing, rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing() -> TimingConfig {
        TimingConfig::default()
    }

    #[test]
    fn steps_monotone_in_time_and_capped() {
        let t = timing();
        let mut c = ClientClock::new(false, &t, Rng::new(1));
        let s1 = c.steps_completed(10.0, 100);
        let s2 = c.steps_completed(20.0, 100);
        assert!(s2 >= s1);
        let s3 = c.steps_completed(1e9, 7);
        assert_eq!(s3, 7, "cap at K");
    }

    #[test]
    fn fast_mean_rate_is_half_per_unit() {
        // fast lambda = 1/2 => mean step time 2 => ~50 steps in 100 units.
        let t = timing();
        let mut total = 0usize;
        let trials = 200;
        for seed in 0..trials {
            let mut c = ClientClock::new(false, &t, Rng::new(seed));
            total += c.steps_completed(100.0, 10_000);
        }
        let mean = total as f64 / trials as f64;
        assert!((mean - 50.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn slow_clients_are_4x_slower() {
        let t = timing();
        let (mut fast_total, mut slow_total) = (0usize, 0usize);
        for seed in 0..200 {
            let mut f = ClientClock::new(false, &t, Rng::new(seed));
            let mut s = ClientClock::new(true, &t, Rng::new(seed + 1000));
            fast_total += f.steps_completed(200.0, 100_000);
            slow_total += s.steps_completed(200.0, 100_000);
        }
        let ratio = fast_total as f64 / slow_total as f64;
        assert!((ratio - 4.0).abs() < 0.4, "ratio={ratio}");
    }

    #[test]
    fn restart_resets_progress() {
        let t = timing();
        let mut c = ClientClock::new(false, &t, Rng::new(3));
        let _ = c.steps_completed(50.0, 1000);
        c.restart(50.0);
        assert_eq!(c.steps_completed(50.0, 1000), 0);
        assert!(c.steps_completed(51.0, 1000) <= 2);
    }

    #[test]
    fn zero_steps_possible_right_after_restart() {
        // The paper stresses H_i = 0 interactions (27% for slow clients in
        // Fig 1's setup). Immediately-after-restart queries must see 0.
        let t = timing();
        let mut c = ClientClock::new(true, &t, Rng::new(4));
        c.restart(10.0);
        assert_eq!(c.steps_completed(10.0, 10), 0);
    }

    #[test]
    fn finish_time_consistent_with_steps() {
        let t = timing();
        let mut a = ClientClock::new(false, &t, Rng::new(5));
        let mut b = ClientClock::new(false, &t, Rng::new(5));
        let mut c = ClientClock::new(false, &t, Rng::new(5));
        let ft = a.finish_time_for(10);
        // Sibling clocks (same seed) must count exactly 10 steps at that
        // instant, and 9 an instant before (fresh clock — the step count
        // is monotone within one clock, so the past can't be re-queried).
        assert_eq!(b.steps_completed(ft, 100), 10);
        assert_eq!(c.steps_completed(ft - 1e-9, 100), 9);
    }

    #[test]
    fn build_clocks_slow_fraction() {
        let mut t = timing();
        t.slow_fraction = 0.3;
        let clocks = build_clocks(100, &t, 7);
        assert_eq!(clocks.iter().filter(|c| c.slow).count(), 30);
        assert_eq!(clocks.len(), 100);
    }

    #[test]
    fn probability_of_zero_progress_slow_clients() {
        // Reproduce the paper's observation: with swt=10, slow clients
        // (mean step 8) show a sizeable P[H=0] when polled one interval
        // after restart. P[Exp(1/8) > 10] = e^{-10/8} ≈ 0.287.
        let t = timing();
        let trials = 2000;
        let mut zeros = 0;
        for seed in 0..trials {
            let mut c = ClientClock::new(true, &t, Rng::new(seed));
            c.restart(0.0);
            if c.steps_completed(10.0, 100) == 0 {
                zeros += 1;
            }
        }
        let p = zeros as f64 / trials as f64;
        assert!((p - 0.287).abs() < 0.04, "P[H=0]={p}");
    }
}

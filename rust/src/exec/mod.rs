//! Parallel client-execution subsystem: the per-round fan-out layer every
//! algorithm runs its sampled clients through.
//!
//! The paper's headline experiments simulate up to 300 heterogeneous
//! clients per round; executing each sampled client's local SGD serially
//! makes wall-clock scale linearly with `s`. This module fans the per-
//! client work out across an [`EnginePool`] — one [`TrainEngine`] instance
//! per worker thread, built by an [`EngineFactory`] — while keeping
//! trajectories **bit-identical to the serial path for any worker count**.
//! Three invariants make that hold:
//!
//! 1. *Serial pre-pass*: everything that consumes shared or ordered
//!    randomness (client sampling, clock advancement, per-client batch
//!    draws from the shard RNG streams) happens before the fan-out, in
//!    sampled order, and is snapshotted into [`ClientTask`]s.
//! 2. *Pure workers*: a worker's output depends only on its task and on
//!    round-constant shared state (e.g. the server model a quantizer
//!    decodes against) — engines are deterministic given (params, batches,
//!    lr), and each client's state is touched by exactly one task.
//! 3. *Ordered reduction*: [`EnginePool::map`] returns results in task
//!    order, so the caller's floating-point accumulation order is exactly
//!    the serial loop's.
//!
//! Workers are **long-lived threads fed over channels** (each builds its
//! engine once, in-thread, on spawn): a fan-out dispatches one contiguous
//! chunk of tasks per worker and runs chunk 0 on the caller's thread with
//! the primary engine, so per-round spawn overhead is gone — measured by
//! the `fan-out overhead` rows in `benches/bench_round.rs` at s >= 128.
//!
//! [`EnginePool::evaluate_sharded`] reuses the same machinery to shard
//! evaluation: the dataset splits at eval-chunk boundaries, each worker
//! returns per-chunk partial sums ([`TrainEngine::evaluate_span`]), and
//! the fold walks the chunks in global order — bit-identical to a
//! single-engine `evaluate` for every worker count.
//!
//! The worker count comes from `ExperimentConfig::workers` (`--workers`;
//! 0 = available parallelism). `rust/tests/parallel_parity.rs` asserts the
//! bit-identity for workers ∈ {1, 2, 8} on all four algorithms, and
//! `benches/bench_round.rs` measures the scaling at n=300/s=32.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::data::{Batch, Dataset, Shard};
use crate::engine::{build_engine, KernelKind, KernelStats, TrainEngine};
use crate::model::ModelSpec;

/// Recipe for building one worker's engine. Cloneable and cheap; the
/// expensive part (XLA artifact compilation, scratch allocation) happens in
/// [`EngineFactory::build`], once per pool worker.
///
/// Cloning shares the [`KernelStats`] tally, so every engine built from
/// this factory — the pool's primary and all its workers — adds its
/// flop/byte counts to the same counters ([`EngineFactory::kernel_stats`]).
#[derive(Clone, Debug)]
pub struct EngineFactory {
    pub model: String,
    pub use_xla: bool,
    pub artifacts_dir: String,
    pub batch: usize,
    pub kernel: KernelKind,
    stats: Arc<KernelStats>,
}

impl EngineFactory {
    pub fn new(
        model: &str,
        use_xla: bool,
        artifacts_dir: &str,
        batch: usize,
        kernel: KernelKind,
    ) -> Self {
        EngineFactory {
            model: model.to_string(),
            use_xla,
            artifacts_dir: artifacts_dir.to_string(),
            batch,
            kernel,
            stats: Arc::new(KernelStats::new()),
        }
    }

    pub fn build(&self) -> Result<Box<dyn TrainEngine>> {
        build_engine(
            &self.model,
            self.use_xla,
            &self.artifacts_dir,
            self.batch,
            self.kernel,
            Arc::clone(&self.stats),
        )
    }

    /// The shared flop/byte tally across every engine this factory (and
    /// its clones) built.
    pub fn kernel_stats(&self) -> &KernelStats {
        &self.stats
    }
}

/// One sampled client's unit of work: local SGD from `params` over the
/// pre-drawn `batches` at rate `lr`. Batches are materialized in the
/// serial pre-pass so the per-client RNG streams advance in sampled order
/// regardless of how tasks are scheduled across workers.
pub struct ClientTask {
    pub client_id: usize,
    /// starting model X^i — an immutable shared snapshot (an `Arc` clone
    /// of a [`crate::fleet::ClientModelStore`] entry, or of a per-round
    /// broadcast). The worker that needs a mutable copy deep-copies once:
    /// that clone is the fan-out's single materialization point, so
    /// queuing s tasks costs s pointers, not s models.
    pub params: Arc<Vec<f32>>,
    /// one batch per local step, in step order (`len() == h`)
    pub batches: Vec<Batch>,
    pub lr: f32,
    /// per-task randomness stream, precomputed by the algorithm in event
    /// order (e.g. FedBuff's per-message compression seed); 0 if unused
    pub seed: u64,
}

impl ClientTask {
    /// Snapshot a task: draw `h` batches from the client's shard (this
    /// advances the shard's RNG exactly as the serial path would).
    pub fn gather(
        client_id: usize,
        params: Arc<Vec<f32>>,
        shard: &mut Shard,
        data: &Dataset,
        batch_size: usize,
        h: usize,
        lr: f32,
    ) -> Self {
        let batches = (0..h)
            .map(|_| data.gather_batch(&shard.sample_batch(batch_size)))
            .collect();
        ClientTask { client_id, params, batches, lr, seed: 0 }
    }

    /// Local steps this task performs.
    pub fn steps(&self) -> usize {
        self.batches.len()
    }
}

/// Result of the plain local-SGD map ([`EnginePool::run_local_sgd`]).
pub struct ClientResult {
    pub client_id: usize,
    /// model after `steps` local SGD steps
    pub params: Vec<f32>,
    /// summed training loss over the steps (diagnostics)
    pub loss: f32,
    pub steps: usize,
}

/// A job shipped to a long-lived worker thread. The `'static` bound is
/// erased borrow lifetime — see the SAFETY note in [`EnginePool::map`].
type Job = Box<dyn FnOnce(&mut dyn TrainEngine) + Send + 'static>;

/// Erase a job's borrow lifetime so it can cross the worker channel.
///
/// # Safety
/// The caller must not return (or otherwise release the borrows the job
/// captures) until the job has either run to completion or been dropped —
/// [`EnginePool::map`] guarantees this by draining one result (or a
/// disconnect) per dispatched job before returning, with a [`DrainGuard`]
/// covering the unwinding path.
unsafe fn erase_job_lifetime<'a>(
    job: Box<dyn FnOnce(&mut dyn TrainEngine) + Send + 'a>,
) -> Job {
    std::mem::transmute(job)
}

/// Unwind guard for the erased borrows in [`EnginePool::map`]: dispatched
/// jobs hold references into the caller's frame, so that frame must not
/// be torn down — not even by a panic — until every dispatched job has
/// either sent its result or dropped its sender. `drop` closes the
/// guard's own sender first so a dead worker's lost job surfaces as a
/// disconnect instead of a hang.
struct DrainGuard<R> {
    rx: mpsc::Receiver<(usize, Vec<Result<R>>)>,
    tx: Option<mpsc::Sender<(usize, Vec<Result<R>>)>>,
    outstanding: usize,
}

impl<R> Drop for DrainGuard<R> {
    fn drop(&mut self) {
        self.tx.take();
        while self.outstanding > 0 {
            match self.rx.recv() {
                Ok(_) => self.outstanding -= 1,
                // All senders gone: every job finished or was destroyed
                // with its dead worker.
                Err(_) => break,
            }
        }
    }
}

/// One long-lived worker: a channel feeding jobs to a thread that owns a
/// private engine (built in-thread on spawn).
struct Worker {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<JoinHandle<()>>,
}

/// A pool of per-worker training engines plus the deterministic fan-out
/// primitive. The primary engine lives on the caller's thread (serial
/// work, evaluation, chunk 0 of every fan-out); up to `workers - 1`
/// persistent worker threads are spawned lazily on first parallel use and
/// reused across rounds.
pub struct EnginePool {
    factory: EngineFactory,
    primary: Box<dyn TrainEngine>,
    workers: usize,
    pool: Vec<Worker>,
    /// passive observability counter: cumulative nanoseconds any engine
    /// (primary or worker) spent executing fan-out chunks. Shared with
    /// the worker closures; [`crate::trace`] polls it at round
    /// boundaries. Busy vs. the enclosing span's wall time is the
    /// worker-utilization signal.
    busy_ns: Arc<AtomicU64>,
}

impl EnginePool {
    /// `workers == 0` resolves to the machine's available parallelism.
    pub fn new(factory: EngineFactory, workers: usize) -> Result<Self> {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        let primary = factory.build()?;
        Ok(EnginePool {
            factory,
            primary,
            workers,
            pool: Vec::new(),
            busy_ns: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Cumulative engine-busy nanoseconds across every fan-out so far
    /// (the trace layer's `pool_busy_ns` counter).
    pub fn busy_ns(&self) -> u64 {
        self.busy_ns.load(Ordering::Relaxed)
    }

    /// Cumulative (flops, bytes) across every engine the pool built —
    /// primary and workers share one [`KernelStats`] via the factory.
    /// Polled by the trace layer as `kernel_flops`/`kernel_bytes`.
    pub fn kernel_stats(&self) -> (u64, u64) {
        let s = self.factory.kernel_stats();
        (s.flops(), s.bytes())
    }

    /// Resolved worker count (>= 1, including the caller's thread).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The primary engine — used for evaluation and any serial work.
    pub fn primary(&mut self) -> &mut dyn TrainEngine {
        self.primary.as_mut()
    }

    pub fn spec(&self) -> &ModelSpec {
        self.primary.spec()
    }

    pub fn train_batch(&self) -> usize {
        self.primary.train_batch()
    }

    /// Spawn persistent workers up to `k` of them. Each builds its engine
    /// in-thread (construction cost paid once per worker, not per round);
    /// a build failure ends the thread and surfaces as a dead-worker error
    /// on the fan-out that tried to use it.
    fn ensure_workers(&mut self, k: usize) -> Result<()> {
        while self.pool.len() < k {
            let idx = self.pool.len();
            let factory = self.factory.clone();
            let (tx, rx) = mpsc::channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("engine-worker-{idx}"))
                .spawn(move || {
                    let mut engine = match factory.build() {
                        Ok(e) => e,
                        Err(e) => {
                            // The pool reports a generic dead-worker error
                            // on dispatch; the cause is only known here.
                            crate::log!(
                                Error,
                                "[exec] engine worker {idx}: engine \
                                 construction failed: {e:#}"
                            );
                            return;
                        }
                    };
                    while let Ok(job) = rx.recv() {
                        job(engine.as_mut());
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawning engine worker: {e}"))?;
            self.pool.push(Worker { tx: Some(tx), handle: Some(handle) });
        }
        Ok(())
    }

    /// Execute `f` over every task, fanned out across up to `workers`
    /// threads (each with its own engine), and return the results **in
    /// task order**. With one worker (or one task) this degenerates to the
    /// plain serial loop on the primary engine; because workers are pure
    /// (see module docs) the outputs are bit-identical either way.
    ///
    /// Tasks are split into contiguous chunks, one per thread (chunk 0
    /// runs on the caller's thread); the concatenation of per-chunk
    /// outputs restores task order.
    pub fn map<T, R, F>(&mut self, tasks: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(&mut dyn TrainEngine, T) -> Result<R> + Sync,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            let t0 = Instant::now();
            let mut out = Vec::with_capacity(n);
            for task in tasks {
                out.push(f(self.primary.as_mut(), task)?);
            }
            self.busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            return Ok(out);
        }
        self.ensure_workers(workers - 1)?;
        let busy_ns = Arc::clone(&self.busy_ns);

        // Same contiguous chunking as the serial split would use.
        let base = n / workers;
        let extra = n % workers;
        let mut it = tasks.into_iter();
        let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
        for w in 0..workers {
            let take = base + usize::from(w < extra);
            chunks.push(it.by_ref().take(take).collect());
        }
        let mut chunks = chunks.into_iter();
        let chunk0 = chunks.next().expect("chunk 0 exists");

        let (res_tx, res_rx) = mpsc::channel::<(usize, Vec<Result<R>>)>();
        let mut guard =
            DrainGuard { rx: res_rx, tx: Some(res_tx), outstanding: 0 };
        let fref = &f;
        let mut dead_worker: Option<usize> = None;
        for (w, chunk) in chunks.enumerate() {
            if dead_worker.is_some() {
                // Don't create further jobs; their tasks are dropped here
                // and the error is reported after the live jobs drain.
                break;
            }
            let res_tx = guard.tx.as_ref().expect("sender open").clone();
            let chunk_busy = Arc::clone(&busy_ns);
            let job: Box<dyn FnOnce(&mut dyn TrainEngine) + Send + '_> =
                Box::new(move |engine| {
                    let t0 = Instant::now();
                    let out: Vec<Result<R>> =
                        chunk.into_iter().map(|t| fref(engine, t)).collect();
                    chunk_busy
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let _ = res_tx.send((w, out));
                });
            // SAFETY: the job borrows `f` and whatever `f` captures. Every
            // dispatched job either sends its result or drops its sender
            // when its worker dies, and this frame blocks until each
            // dispatched job has done one or the other — on the normal
            // path via the collection loop below, on the panic path via
            // `DrainGuard::drop` — so no borrow outlives this call,
            // making the lifetime erasure sound.
            let job: Job = unsafe { erase_job_lifetime(job) };
            match self.pool[w].tx.as_ref().expect("worker channel").send(job) {
                Ok(()) => guard.outstanding += 1,
                Err(_) => dead_worker = Some(w),
            }
        }

        // Chunk 0 on the caller's thread while the workers run theirs.
        let t0 = Instant::now();
        let out0: Vec<Result<R>> = chunk0
            .into_iter()
            .map(|t| f(self.primary.as_mut(), t))
            .collect();
        busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let mut per_chunk: Vec<Option<Vec<Result<R>>>> =
            (0..workers - 1).map(|_| None).collect();
        let mut disconnected = false;
        guard.tx.take();
        while guard.outstanding > 0 {
            match guard.rx.recv() {
                Ok((w, out)) => {
                    guard.outstanding -= 1;
                    per_chunk[w] = Some(out);
                }
                Err(_) => {
                    disconnected = true;
                    guard.outstanding = 0;
                }
            }
        }
        // Both paths are the same failure observed at different moments
        // (a worker died building its engine or panicked in a job); the
        // root cause is printed to stderr by the worker thread itself.
        anyhow::ensure!(
            dead_worker.is_none() && !disconnected,
            "an engine worker died (engine construction failure or panic — \
             see stderr for the cause)"
        );

        let mut out = Vec::with_capacity(n);
        for r in out0 {
            out.push(r?);
        }
        for chunk in per_chunk {
            for r in chunk.expect("all dispatched chunks received") {
                out.push(r?);
            }
        }
        Ok(out)
    }

    /// The common fan-out: run each task's local SGD burst and return the
    /// trained models (FedAvg, FedBuff, and the baseline use this; QuAFL
    /// layers quantized coding on top via [`EnginePool::map`]).
    pub fn run_local_sgd(&mut self, tasks: Vec<ClientTask>) -> Result<Vec<ClientResult>> {
        self.map(tasks, |engine, task| {
            let ClientTask { client_id, params, batches, lr, .. } = task;
            // The single materialization point: unwrap a uniquely-held
            // snapshot in place, deep-copy a shared one.
            let mut params =
                Arc::try_unwrap(params).unwrap_or_else(|a| (*a).clone());
            let loss = if batches.is_empty() {
                0.0
            } else {
                engine.train_steps(&mut params, &batches, lr)?
            };
            Ok(ClientResult { client_id, params, loss, steps: batches.len() })
        })
    }

    /// Parallel evaluation: shard `data` across the pool in contiguous
    /// spans aligned to [`TrainEngine::eval_batch`] boundaries and fold
    /// the per-chunk partial sums in **global chunk order** — bit-identical
    /// to `primary().evaluate(params, data)` for every worker count (see
    /// [`TrainEngine::evaluate_span`]).
    pub fn evaluate_sharded(
        &mut self,
        params: &[f32],
        data: &Dataset,
    ) -> Result<(f64, f64)> {
        anyhow::ensure!(!data.is_empty());
        let chunk = self.primary.eval_batch().max(1);
        let n_chunks = data.len().div_ceil(chunk);
        let shards = self.workers.min(n_chunks);
        if shards <= 1 {
            return self.primary.evaluate(params, data);
        }
        let base = n_chunks / shards;
        let extra = n_chunks % shards;
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(shards);
        let mut at = 0usize;
        for w in 0..shards {
            let take = base + usize::from(w < extra);
            let lo = at * chunk;
            let hi = ((at + take) * chunk).min(data.len());
            spans.push((lo, hi));
            at += take;
        }
        let partials = self.map(spans, |engine, (lo, hi)| {
            engine.evaluate_span(params, data, lo, hi)
        })?;
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for span in partials {
            for (l, c) in span {
                loss_sum += l;
                correct += c;
            }
        }
        Ok((loss_sum / data.len() as f64, correct / data.len() as f64))
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // Closing the channels ends the worker loops; join afterwards so
        // shutdown is clean even if a worker is mid-job.
        for w in &mut self.pool {
            w.tx.take();
        }
        for w in &mut self.pool {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthFamily, SynthSpec};
    use crate::util::rng::Rng;

    const BATCH: usize = 8;

    fn factory() -> EngineFactory {
        EngineFactory::new("mlp", false, "artifacts", BATCH, KernelKind::default())
    }

    fn setup(n_clients: usize) -> (Dataset, Vec<Shard>, Vec<f32>) {
        let (train, _) = SynthSpec::family(SynthFamily::Mnist, 256, 16, 3).generate();
        let mut rng = Rng::new(9);
        let shards = (0..n_clients)
            .map(|c| {
                let idx: Vec<usize> = (0..train.len()).collect();
                Shard::new(idx, rng.fork(c as u64))
            })
            .collect();
        let params = ModelSpec::by_name("mlp").unwrap().init_params(7);
        (train, shards, params)
    }

    fn make_tasks(
        train: &Dataset,
        shards: &mut [Shard],
        params: &[f32],
        per_client_h: &[usize],
    ) -> Vec<ClientTask> {
        per_client_h
            .iter()
            .enumerate()
            .map(|(i, &h)| {
                ClientTask::gather(
                    i,
                    Arc::new(params.to_vec()),
                    &mut shards[i],
                    train,
                    BATCH,
                    h,
                    0.1,
                )
            })
            .collect()
    }

    #[test]
    fn workers_resolve_to_at_least_one() {
        let pool = EnginePool::new(factory(), 0).unwrap();
        assert!(pool.workers() >= 1);
        let pool = EnginePool::new(factory(), 3).unwrap();
        assert_eq!(pool.workers(), 3);
    }

    #[test]
    fn gather_draws_h_batches_of_right_shape() {
        let (train, mut shards, params) = setup(1);
        let task = ClientTask::gather(
            0,
            Arc::new(params),
            &mut shards[0],
            &train,
            BATCH,
            5,
            0.1,
        );
        assert_eq!(task.steps(), 5);
        for b in &task.batches {
            assert_eq!(b.batch, BATCH);
            assert_eq!(b.dim, 784);
        }
    }

    #[test]
    fn map_preserves_task_order() {
        let (train, mut shards, params) = setup(6);
        let tasks = make_tasks(&train, &mut shards, &params, &[1, 0, 2, 1, 0, 3]);
        let mut pool = EnginePool::new(factory(), 4).unwrap();
        let ids = pool
            .map(tasks, |_, task| Ok(task.client_id))
            .unwrap();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn map_empty_tasks_is_empty() {
        let mut pool = EnginePool::new(factory(), 2).unwrap();
        let out: Vec<usize> =
            pool.map(Vec::<ClientTask>::new(), |_, t| Ok(t.client_id)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn workers_persist_across_fan_outs() {
        // The persistent pool's contract: repeated fan-outs reuse the same
        // threads (no per-round spawns), and results stay in order.
        let (train, mut shards, params) = setup(6);
        let mut pool = EnginePool::new(factory(), 3).unwrap();
        for _ in 0..5 {
            let tasks = make_tasks(&train, &mut shards, &params, &[1, 1, 1, 1, 1, 1]);
            let ids = pool.map(tasks, |_, t| Ok(t.client_id)).unwrap();
            assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        }
        // 3 threads total => 2 spawned workers, reused every round.
        assert_eq!(pool.pool.len(), 2);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        // The subsystem's core contract: identical outputs for any worker
        // count, down to the bit.
        let (train, mut shards, params) = setup(7);
        let hs = [3usize, 0, 1, 4, 2, 1, 3];
        let run = |workers: usize, shards: &mut [Shard]| {
            let tasks = make_tasks(&train, shards, &params, &hs);
            let mut pool = EnginePool::new(factory(), workers).unwrap();
            pool.run_local_sgd(tasks).unwrap()
        };
        // Shard RNGs advance during gather; rebuild them per run.
        let serial = run(1, &mut shards);
        let (_, mut shards2, _) = setup(7);
        let parallel = run(4, &mut shards2);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.client_id, b.client_id);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.params, b.params);
        }
    }

    #[test]
    fn busy_counter_accumulates_on_serial_and_parallel_paths() {
        let (train, mut shards, params) = setup(6);
        let mut pool = EnginePool::new(factory(), 1).unwrap();
        assert_eq!(pool.busy_ns(), 0);
        let tasks = make_tasks(&train, &mut shards, &params, &[2, 1, 1, 2, 1, 1]);
        pool.run_local_sgd(tasks).unwrap();
        let serial_busy = pool.busy_ns();
        assert!(serial_busy > 0, "serial fan-out must record busy time");
        let (_, mut shards2, _) = setup(6);
        let mut pool4 = EnginePool::new(factory(), 4).unwrap();
        let tasks = make_tasks(&train, &mut shards2, &params, &[2, 1, 1, 2, 1, 1]);
        pool4.run_local_sgd(tasks).unwrap();
        assert!(pool4.busy_ns() > 0, "parallel fan-out must record busy time");
    }

    #[test]
    fn kernel_stats_shared_across_pool_workers() {
        // Every engine the pool builds (primary + spawned workers) adds
        // to the SAME tally, and the parallel total equals the serial
        // total: analytic counts depend only on the work, not the split.
        let (train, mut shards, params) = setup(6);
        let hs = [2usize, 1, 1, 2, 1, 1];
        let mut pool1 = EnginePool::new(factory(), 1).unwrap();
        assert_eq!(pool1.kernel_stats(), (0, 0));
        let tasks = make_tasks(&train, &mut shards, &params, &hs);
        pool1.run_local_sgd(tasks).unwrap();
        let (f1, b1) = pool1.kernel_stats();
        assert!(f1 > 0 && b1 > 0);
        let (_, mut shards2, _) = setup(6);
        let mut pool4 = EnginePool::new(factory(), 4).unwrap();
        let tasks = make_tasks(&train, &mut shards2, &params, &hs);
        pool4.run_local_sgd(tasks).unwrap();
        assert_eq!(pool4.kernel_stats(), (f1, b1));
    }

    #[test]
    fn zero_step_task_returns_params_unchanged() {
        let (train, mut shards, params) = setup(1);
        let tasks = make_tasks(&train, &mut shards, &params, &[0]);
        let mut pool = EnginePool::new(factory(), 2).unwrap();
        let out = pool.run_local_sgd(tasks).unwrap();
        assert_eq!(out[0].params, params);
        assert_eq!(out[0].loss, 0.0);
        assert_eq!(out[0].steps, 0);
    }

    #[test]
    fn worker_error_propagates() {
        let (train, mut shards, params) = setup(5);
        let tasks = make_tasks(&train, &mut shards, &params, &[1, 1, 1, 1, 1]);
        let mut pool = EnginePool::new(factory(), 2).unwrap();
        let res: Result<Vec<u8>> = pool.map(tasks, |_, task| {
            if task.client_id == 3 {
                anyhow::bail!("injected failure");
            }
            Ok(0)
        });
        assert!(res.is_err());
        assert!(format!("{:#}", res.err().unwrap()).contains("injected"));
    }

    #[test]
    fn sharded_eval_matches_primary_bitwise() {
        // The parallel-evaluation contract: same (loss, acc) bits as the
        // single-engine path, for several worker counts and for dataset
        // sizes that do / don't divide the eval chunk.
        let (train, _, params) = setup(1);
        for workers in [1usize, 2, 3, 8] {
            let mut pool = EnginePool::new(factory(), workers).unwrap();
            let (l_ser, a_ser) = pool.primary().evaluate(&params, &train).unwrap();
            let (l_par, a_par) = pool.evaluate_sharded(&params, &train).unwrap();
            assert_eq!(l_ser.to_bits(), l_par.to_bits(), "workers={workers}");
            assert_eq!(a_ser.to_bits(), a_par.to_bits(), "workers={workers}");
        }
        // Ragged tail: 100 rows over chunk size 8.
        let idx: Vec<usize> = (0..100).collect();
        let ragged = crate::coordinator::subset(&train, &idx);
        let mut pool = EnginePool::new(factory(), 4).unwrap();
        let (l_ser, a_ser) = pool.primary().evaluate(&params, &ragged).unwrap();
        let (l_par, a_par) = pool.evaluate_sharded(&params, &ragged).unwrap();
        assert_eq!(l_ser.to_bits(), l_par.to_bits());
        assert_eq!(a_ser.to_bits(), a_par.to_bits());
    }
}

//! Parallel client-execution subsystem: the per-round fan-out layer every
//! algorithm runs its sampled clients through.
//!
//! The paper's headline experiments simulate up to 300 heterogeneous
//! clients per round; executing each sampled client's local SGD serially
//! makes wall-clock scale linearly with `s`. This module fans the per-
//! client work out across an [`EnginePool`] — one [`TrainEngine`] instance
//! per worker thread, built by an [`EngineFactory`] and reused across
//! rounds — while keeping trajectories **bit-identical to the serial path
//! for any worker count**. Three invariants make that hold:
//!
//! 1. *Serial pre-pass*: everything that consumes shared or ordered
//!    randomness (client sampling, clock advancement, per-client batch
//!    draws from the shard RNG streams) happens before the fan-out, in
//!    sampled order, and is snapshotted into [`ClientTask`]s.
//! 2. *Pure workers*: a worker's output depends only on its task and on
//!    round-constant shared state (e.g. the server model a quantizer
//!    decodes against) — engines are deterministic given (params, batches,
//!    lr), and each client's state is touched by exactly one task.
//! 3. *Ordered reduction*: [`EnginePool::map`] returns results in task
//!    order, so the caller's floating-point accumulation order is exactly
//!    the serial loop's.
//!
//! The worker count comes from `ExperimentConfig::workers` (`--workers`;
//! 0 = available parallelism). `rust/tests/parallel_parity.rs` asserts the
//! bit-identity for workers ∈ {1, 2, 8} on all four algorithms, and
//! `benches/bench_round.rs` measures the scaling at n=300/s=32.

use anyhow::Result;

use crate::data::{Batch, Dataset, Shard};
use crate::engine::{build_engine, TrainEngine};
use crate::model::ModelSpec;

/// Recipe for building one worker's engine. Cloneable and cheap; the
/// expensive part (XLA artifact compilation, scratch allocation) happens in
/// [`EngineFactory::build`], once per pool worker.
#[derive(Clone, Debug)]
pub struct EngineFactory {
    pub model: String,
    pub use_xla: bool,
    pub artifacts_dir: String,
    pub batch: usize,
}

impl EngineFactory {
    pub fn new(model: &str, use_xla: bool, artifacts_dir: &str, batch: usize) -> Self {
        EngineFactory {
            model: model.to_string(),
            use_xla,
            artifacts_dir: artifacts_dir.to_string(),
            batch,
        }
    }

    pub fn build(&self) -> Result<Box<dyn TrainEngine>> {
        build_engine(&self.model, self.use_xla, &self.artifacts_dir, self.batch)
    }
}

/// One sampled client's unit of work: local SGD from `params` over the
/// pre-drawn `batches` at rate `lr`. Batches are materialized in the
/// serial pre-pass so the per-client RNG streams advance in sampled order
/// regardless of how tasks are scheduled across workers.
pub struct ClientTask {
    pub client_id: usize,
    /// starting model X^i (moved in; workers that need the pre-SGD point
    /// clone before training)
    pub params: Vec<f32>,
    /// one batch per local step, in step order (`len() == h`)
    pub batches: Vec<Batch>,
    pub lr: f32,
    /// per-task randomness stream, precomputed by the algorithm in event
    /// order (e.g. FedBuff's per-message compression seed); 0 if unused
    pub seed: u64,
}

impl ClientTask {
    /// Snapshot a task: draw `h` batches from the client's shard (this
    /// advances the shard's RNG exactly as the serial path would).
    pub fn gather(
        client_id: usize,
        params: Vec<f32>,
        shard: &mut Shard,
        data: &Dataset,
        batch_size: usize,
        h: usize,
        lr: f32,
    ) -> Self {
        let batches = (0..h)
            .map(|_| data.gather_batch(&shard.sample_batch(batch_size)))
            .collect();
        ClientTask { client_id, params, batches, lr, seed: 0 }
    }

    /// Local steps this task performs.
    pub fn steps(&self) -> usize {
        self.batches.len()
    }
}

/// Result of the plain local-SGD map ([`EnginePool::run_local_sgd`]).
pub struct ClientResult {
    pub client_id: usize,
    /// model after `steps` local SGD steps
    pub params: Vec<f32>,
    /// summed training loss over the steps (diagnostics)
    pub loss: f32,
    pub steps: usize,
}

/// A pool of per-worker training engines plus the deterministic fan-out
/// primitive. Engines are built lazily (the primary eagerly, workers on
/// first parallel use) and reused across rounds.
pub struct EnginePool {
    factory: EngineFactory,
    engines: Vec<Box<dyn TrainEngine>>,
    workers: usize,
}

impl EnginePool {
    /// `workers == 0` resolves to the machine's available parallelism.
    pub fn new(factory: EngineFactory, workers: usize) -> Result<Self> {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        let engines = vec![factory.build()?];
        Ok(EnginePool { factory, engines, workers })
    }

    /// Resolved worker count (>= 1).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The primary engine — used for evaluation and any serial work.
    pub fn primary(&mut self) -> &mut dyn TrainEngine {
        self.engines[0].as_mut()
    }

    pub fn spec(&self) -> &ModelSpec {
        self.engines[0].spec()
    }

    pub fn train_batch(&self) -> usize {
        self.engines[0].train_batch()
    }

    fn ensure_engines(&mut self, k: usize) -> Result<()> {
        while self.engines.len() < k {
            self.engines.push(self.factory.build()?);
        }
        Ok(())
    }

    /// Execute `f` over every task, fanned out across up to `workers`
    /// threads (each with its own engine), and return the results **in
    /// task order**. With one worker (or one task) this degenerates to the
    /// plain serial loop on the primary engine; because workers are pure
    /// (see module docs) the outputs are bit-identical either way.
    ///
    /// Tasks are split into contiguous chunks, one per worker; the
    /// concatenation of per-worker outputs restores task order.
    pub fn map<R, F>(&mut self, tasks: Vec<ClientTask>, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(&mut dyn TrainEngine, ClientTask) -> Result<R> + Sync,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            let mut out = Vec::with_capacity(n);
            for task in tasks {
                out.push(f(self.engines[0].as_mut(), task)?);
            }
            return Ok(out);
        }
        self.ensure_engines(workers)?;
        let base = n / workers;
        let extra = n % workers;
        let mut it = tasks.into_iter();
        let mut chunks: Vec<Vec<ClientTask>> = Vec::with_capacity(workers);
        for w in 0..workers {
            let take = base + usize::from(w < extra);
            chunks.push(it.by_ref().take(take).collect());
        }
        let f = &f;
        let per_worker: Vec<Vec<Result<R>>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for (engine, chunk) in self.engines.iter_mut().zip(chunks) {
                handles.push(scope.spawn(move || {
                    chunk
                        .into_iter()
                        .map(|task| f(engine.as_mut(), task))
                        .collect::<Vec<Result<R>>>()
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("engine worker panicked"))
                .collect()
        });
        let mut out = Vec::with_capacity(n);
        for chunk in per_worker {
            for r in chunk {
                out.push(r?);
            }
        }
        Ok(out)
    }

    /// The common fan-out: run each task's local SGD burst and return the
    /// trained models (FedAvg, FedBuff, and the baseline use this; QuAFL
    /// layers quantized coding on top via [`EnginePool::map`]).
    pub fn run_local_sgd(&mut self, tasks: Vec<ClientTask>) -> Result<Vec<ClientResult>> {
        self.map(tasks, |engine, task| {
            let ClientTask { client_id, mut params, batches, lr, .. } = task;
            let loss = if batches.is_empty() {
                0.0
            } else {
                engine.train_steps(&mut params, &batches, lr)?
            };
            Ok(ClientResult { client_id, params, loss, steps: batches.len() })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthFamily, SynthSpec};
    use crate::util::rng::Rng;

    const BATCH: usize = 8;

    fn factory() -> EngineFactory {
        EngineFactory::new("mlp", false, "artifacts", BATCH)
    }

    fn setup(n_clients: usize) -> (Dataset, Vec<Shard>, Vec<f32>) {
        let (train, _) = SynthSpec::family(SynthFamily::Mnist, 256, 16, 3).generate();
        let mut rng = Rng::new(9);
        let shards = (0..n_clients)
            .map(|c| {
                let idx: Vec<usize> = (0..train.len()).collect();
                Shard::new(idx, rng.fork(c as u64))
            })
            .collect();
        let params = ModelSpec::by_name("mlp").unwrap().init_params(7);
        (train, shards, params)
    }

    fn make_tasks(
        train: &Dataset,
        shards: &mut [Shard],
        params: &[f32],
        per_client_h: &[usize],
    ) -> Vec<ClientTask> {
        per_client_h
            .iter()
            .enumerate()
            .map(|(i, &h)| {
                ClientTask::gather(i, params.to_vec(), &mut shards[i], train, BATCH, h, 0.1)
            })
            .collect()
    }

    #[test]
    fn workers_resolve_to_at_least_one() {
        let pool = EnginePool::new(factory(), 0).unwrap();
        assert!(pool.workers() >= 1);
        let pool = EnginePool::new(factory(), 3).unwrap();
        assert_eq!(pool.workers(), 3);
    }

    #[test]
    fn gather_draws_h_batches_of_right_shape() {
        let (train, mut shards, params) = setup(1);
        let task =
            ClientTask::gather(0, params, &mut shards[0], &train, BATCH, 5, 0.1);
        assert_eq!(task.steps(), 5);
        for b in &task.batches {
            assert_eq!(b.batch, BATCH);
            assert_eq!(b.dim, 784);
        }
    }

    #[test]
    fn map_preserves_task_order() {
        let (train, mut shards, params) = setup(6);
        let tasks = make_tasks(&train, &mut shards, &params, &[1, 0, 2, 1, 0, 3]);
        let mut pool = EnginePool::new(factory(), 4).unwrap();
        let ids = pool
            .map(tasks, |_, task| Ok(task.client_id))
            .unwrap();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn map_empty_tasks_is_empty() {
        let mut pool = EnginePool::new(factory(), 2).unwrap();
        let out: Vec<usize> = pool.map(Vec::new(), |_, t| Ok(t.client_id)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        // The subsystem's core contract: identical outputs for any worker
        // count, down to the bit.
        let (train, mut shards, params) = setup(7);
        let hs = [3usize, 0, 1, 4, 2, 1, 3];
        let run = |workers: usize, shards: &mut [Shard]| {
            let tasks = make_tasks(&train, shards, &params, &hs);
            let mut pool = EnginePool::new(factory(), workers).unwrap();
            pool.run_local_sgd(tasks).unwrap()
        };
        // Shard RNGs advance during gather; rebuild them per run.
        let serial = run(1, &mut shards);
        let (_, mut shards2, _) = setup(7);
        let parallel = run(4, &mut shards2);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.client_id, b.client_id);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.params, b.params);
        }
    }

    #[test]
    fn zero_step_task_returns_params_unchanged() {
        let (train, mut shards, params) = setup(1);
        let tasks = make_tasks(&train, &mut shards, &params, &[0]);
        let mut pool = EnginePool::new(factory(), 2).unwrap();
        let out = pool.run_local_sgd(tasks).unwrap();
        assert_eq!(out[0].params, params);
        assert_eq!(out[0].loss, 0.0);
        assert_eq!(out[0].steps, 0);
    }

    #[test]
    fn worker_error_propagates() {
        let (train, mut shards, params) = setup(5);
        let tasks = make_tasks(&train, &mut shards, &params, &[1, 1, 1, 1, 1]);
        let mut pool = EnginePool::new(factory(), 2).unwrap();
        let res: Result<Vec<u8>> = pool.map(tasks, |_, task| {
            if task.client_id == 3 {
                anyhow::bail!("injected failure");
            }
            Ok(0)
        });
        assert!(res.is_err());
        assert!(format!("{:#}", res.err().unwrap()).contains("injected"));
    }
}

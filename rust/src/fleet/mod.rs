//! Copy-on-write fleet state: lazy client-model materialization.
//!
//! The paper's asynchronous, partial-participation design touches only s
//! clients per round, yet the pre-fleet simulator eagerly allocated all n
//! dense client models (`vec![init.clone(); n]`), making memory O(n·d)
//! (~100 KB per client on the mlp) and blocking the ROADMAP's n≥10⁴
//! sweeps. [`ClientModelStore`] removes that term: per-client models are
//! held as `Arc<Vec<f32>>` snapshots, untouched clients reference one
//! shared base allocation, and a model is deep-copied only when its
//! client actually diverges — memory is O(touched·d), with
//! touched ≤ min(n, s·rounds).
//!
//! The store's contract with the algorithms:
//!
//! - **Snapshots are cheap and immutable.** [`ClientModelStore::snapshot`]
//!   hands out an `Arc` clone; the worker that needs a mutable copy for
//!   its SGD burst deep-copies once ([`crate::exec`]'s single
//!   materialization point). Nothing mutates through a snapshot.
//! - **Writes are explicit.** [`ClientModelStore::set`] installs a
//!   client's diverged model (its own allocation);
//!   [`ClientModelStore::set_shared`] points a client at an existing
//!   shared snapshot — FedBuff uses it so every client pulling between
//!   the same two aggregations shares *one* allocation of the server
//!   model instead of each cloning it.
//! - **Dense reads preserve float order.**
//!   [`ClientModelStore::iter_dense`] yields every client's model slice
//!   in client order — shared or diverged is invisible to the consumer —
//!   so the paper's potential Φ_t and the server/client discrepancy fold
//!   in exactly the eager layout's order, keeping them bit-exact
//!   (rust/tests/fleet_parity.rs).
//! - **Residency is observable.** The store counts its distinct
//!   allocations (pointer identity over the entries it owns) and tracks
//!   the high-water mark; [`ClientModelStore::peak_bytes`] feeds the
//!   `peak_model_bytes` metric surfaced in every CSV.
//! - **Snapshots carry an epoch.** Every write stamps the store's current
//!   epoch (advanced once per server round / FedBuff aggregation via
//!   [`ClientModelStore::advance_epoch`]), so a client's snapshot
//!   *staleness* — rounds since its model was installed, the quantity the
//!   staleness-aware selection policy ranks on ([`crate::select`]) — is
//!   derivable directly from the store
//!   ([`ClientModelStore::snapshot_epoch`] /
//!   [`ClientModelStore::staleness`]). The algorithms keep it in
//!   lockstep with the participation tracker's own bookkeeping by
//!   stamping and advancing both at the same program points (the
//!   lockstep is debug-asserted every round).
//!
//! The reference layout is still available: `dense` mode (the
//! `--dense-fleet` knob) materializes every client up front and
//! deep-copies on every shared write, reproducing the eager O(n·d)
//! behaviour — the parity suite proves the two modes bit-identical on
//! full QuAFL/FedBuff trajectories.

use std::collections::HashMap;
use std::sync::Arc;

/// Per-client model storage with copy-on-write semantics (see the module
/// docs). All models have one fixed dimension `dim`.
pub struct ClientModelStore {
    /// client i's current model — possibly an allocation shared with
    /// other clients (the init base, or a pulled server snapshot)
    entries: Vec<Arc<Vec<f32>>>,
    /// distinct allocations currently referenced by `entries`:
    /// allocation address → number of entries pointing at it. Tracked
    /// pointers are kept alive by the entries that own them, so an
    /// address can never be recycled while it is a key here.
    refcounts: HashMap<usize, usize>,
    dim: usize,
    /// high-water mark of `refcounts.len()`
    peak_models: usize,
    /// reference layout: every write materializes (O(n·d), for parity)
    dense: bool,
    /// epoch (server round / aggregation index) at which each client's
    /// current snapshot was installed; 0 = the shared init
    epochs: Vec<u64>,
    /// the epoch stamped on writes; advanced by [`Self::advance_epoch`]
    current_epoch: u64,
    /// passive observability counter: materializations installed via
    /// [`Self::set`] — CoW divergences, plus (in dense mode) the deep
    /// copies `set_shared` routes through `set`. Polled by
    /// [`crate::trace`] at round boundaries; initial-construction copies
    /// are not counted.
    materializations: u64,
}

impl ClientModelStore {
    /// CoW store: all `n` clients share the single `base` allocation.
    pub fn new(n: usize, base: Vec<f32>) -> Self {
        Self::with_mode(n, base, false)
    }

    /// Reference layout: every client gets its own copy of `base` up
    /// front, and shared writes deep-copy (the pre-fleet behaviour).
    pub fn new_dense(n: usize, base: Vec<f32>) -> Self {
        Self::with_mode(n, base, true)
    }

    pub fn with_mode(n: usize, base: Vec<f32>, dense: bool) -> Self {
        let dim = base.len();
        let mut store = ClientModelStore {
            entries: Vec::with_capacity(n),
            refcounts: HashMap::new(),
            dim,
            peak_models: 0,
            dense,
            epochs: vec![0; n],
            current_epoch: 0,
            materializations: 0,
        };
        if dense {
            for _ in 0..n {
                let arc = Arc::new(base.clone());
                store.retain(&arc);
                store.entries.push(arc);
            }
        } else {
            let shared = Arc::new(base);
            for _ in 0..n {
                store.retain(&shared);
                store.entries.push(shared.clone());
            }
        }
        store
    }

    /// Number of clients.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Model dimension d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn is_dense(&self) -> bool {
        self.dense
    }

    /// Client `i`'s current model, read-only.
    pub fn get(&self, i: usize) -> &[f32] {
        self.entries[i].as_slice()
    }

    /// Cheap immutable snapshot of client `i`'s model (an `Arc` clone —
    /// no float is copied). The holder deep-copies if it needs to mutate.
    pub fn snapshot(&self, i: usize) -> Arc<Vec<f32>> {
        self.entries[i].clone()
    }

    /// Client `i` diverged: install `model` as its own allocation,
    /// stamped with the current epoch.
    pub fn set(&mut self, i: usize, model: Vec<f32>) {
        assert_eq!(model.len(), self.dim, "model dim mismatch");
        self.materializations += 1;
        let arc = Arc::new(model);
        self.retain(&arc);
        let old = std::mem::replace(&mut self.entries[i], arc);
        self.release(&old);
        self.epochs[i] = self.current_epoch;
    }

    /// Point client `i` at an existing shared snapshot (e.g. the server
    /// model current at its pull) without copying, stamped with the
    /// current epoch. In dense mode this deep-copies instead, reproducing
    /// the eager layout.
    pub fn set_shared(&mut self, i: usize, model: Arc<Vec<f32>>) {
        if self.dense {
            self.set(i, (*model).clone());
            return;
        }
        assert_eq!(model.len(), self.dim, "model dim mismatch");
        self.retain(&model);
        let old = std::mem::replace(&mut self.entries[i], model);
        self.release(&old);
        self.epochs[i] = self.current_epoch;
    }

    /// Advance the epoch stamped on subsequent writes (once per server
    /// round / FedBuff aggregation).
    pub fn advance_epoch(&mut self) {
        self.current_epoch += 1;
    }

    /// The epoch writes are currently stamped with.
    pub fn current_epoch(&self) -> u64 {
        self.current_epoch
    }

    /// Epoch at which client `i`'s current snapshot was installed
    /// (0 = the shared init).
    pub fn snapshot_epoch(&self, i: usize) -> u64 {
        self.epochs[i]
    }

    /// Rounds since client `i`'s snapshot was installed — the quantity
    /// the staleness-aware selection policy ranks on ([`crate::select`];
    /// equal to the participation tracker's bookkeeping).
    pub fn staleness(&self, i: usize) -> u64 {
        self.current_epoch - self.epochs[i]
    }

    /// Every client's model slice, in client order — the dense view the
    /// potential/discrepancy folds iterate. Shared and diverged entries
    /// are indistinguishable to the consumer, so the float order (and
    /// hence every accumulated sum) matches the eager layout bit for bit.
    pub fn iter_dense(
        &self,
    ) -> impl Iterator<Item = &[f32]> + ExactSizeIterator + Clone + '_ {
        self.entries.iter().map(|a| a.as_slice())
    }

    /// Whether `a`'s allocation currently backs one of the store's
    /// entries. FedBuff uses this to count popped-but-unprocessed pull
    /// snapshots: a client's old snapshot leaves the store at its re-pull
    /// but stays alive inside its task until the fan-out consumes it.
    pub fn is_resident(&self, a: &Arc<Vec<f32>>) -> bool {
        self.refcounts.contains_key(&(Arc::as_ptr(a) as usize))
    }

    /// Distinct model allocations currently resident in the store.
    pub fn resident_models(&self) -> usize {
        self.refcounts.len()
    }

    /// Bytes those allocations occupy (f32 payload only).
    pub fn resident_bytes(&self) -> u64 {
        (self.refcounts.len() * self.dim * 4) as u64
    }

    /// High-water mark of [`ClientModelStore::resident_models`].
    pub fn peak_models(&self) -> usize {
        self.peak_models
    }

    /// High-water mark in bytes — the `peak_model_bytes` metric.
    pub fn peak_bytes(&self) -> u64 {
        (self.peak_models * self.dim * 4) as u64
    }

    /// Models materialized through [`ClientModelStore::set`] since
    /// construction (the trace layer's `cow_materializations` counter).
    pub fn materializations(&self) -> u64 {
        self.materializations
    }

    /// Count `a` into the residency map and update the high-water mark —
    /// the peak is observed here, at the moment of maximum overlap (a
    /// write's new allocation coexists with the one it replaces until
    /// [`ClientModelStore::release`] runs).
    fn retain(&mut self, a: &Arc<Vec<f32>>) {
        *self.refcounts.entry(Arc::as_ptr(a) as usize).or_insert(0) += 1;
        self.note_peak();
    }

    fn release(&mut self, a: &Arc<Vec<f32>>) {
        let key = Arc::as_ptr(a) as usize;
        let c = self
            .refcounts
            .get_mut(&key)
            .expect("released an allocation the store does not track");
        *c -= 1;
        if *c == 0 {
            self.refcounts.remove(&key);
        }
    }

    fn note_peak(&mut self) {
        self.peak_models = self.peak_models.max(self.refcounts.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_store_shares_one_allocation() {
        let store = ClientModelStore::new(100, vec![1.0, 2.0, 3.0]);
        assert_eq!(store.len(), 100);
        assert_eq!(store.dim(), 3);
        assert_eq!(store.resident_models(), 1);
        assert_eq!(store.resident_bytes(), 12);
        for i in 0..100 {
            assert_eq!(store.get(i), &[1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn dense_store_materializes_everyone() {
        let store = ClientModelStore::new_dense(10, vec![0.5; 4]);
        assert!(store.is_dense());
        assert_eq!(store.resident_models(), 10);
        assert_eq!(store.resident_bytes(), 10 * 16);
    }

    #[test]
    fn set_diverges_only_the_touched_client() {
        let mut store = ClientModelStore::new(8, vec![0.0; 2]);
        store.set(3, vec![7.0, 8.0]);
        assert_eq!(store.resident_models(), 2);
        assert_eq!(store.get(3), &[7.0, 8.0]);
        assert_eq!(store.get(2), &[0.0, 0.0]);
        // Re-diverging the same client does not grow residency.
        store.set(3, vec![9.0, 9.0]);
        assert_eq!(store.resident_models(), 2);
        // But the peak saw the transient overlap of old + new.
        assert_eq!(store.peak_models(), 3);
    }

    #[test]
    fn set_shared_aliases_without_copying() {
        let mut store = ClientModelStore::new(4, vec![0.0; 2]);
        store.set(0, vec![5.0, 5.0]);
        let snap = store.snapshot(0);
        store.set_shared(1, snap.clone());
        store.set_shared(2, snap);
        // base (client 3) + the one diverged allocation shared by 0,1,2.
        assert_eq!(store.resident_models(), 2);
        assert_eq!(store.get(1), &[5.0, 5.0]);
        assert_eq!(store.get(2), &[5.0, 5.0]);
    }

    #[test]
    fn base_drops_out_when_last_reference_leaves() {
        let mut store = ClientModelStore::new(2, vec![1.0]);
        store.set(0, vec![2.0]);
        store.set(1, vec![3.0]);
        // The shared base is no longer referenced by any entry.
        assert_eq!(store.resident_models(), 2);
        assert!(store.peak_models() >= 3);
    }

    #[test]
    fn dense_mode_copies_on_shared_writes() {
        let mut store = ClientModelStore::new_dense(3, vec![0.0; 2]);
        let snap = store.snapshot(0);
        store.set_shared(1, snap);
        // Still one allocation per client: the shared write materialized.
        assert_eq!(store.resident_models(), 3);
        assert_eq!(store.get(1), &[0.0, 0.0]);
    }

    #[test]
    fn dense_view_walks_clients_in_order() {
        let mut store = ClientModelStore::new(3, vec![0.0]);
        store.set(1, vec![1.0]);
        let rows: Vec<&[f32]> = store.iter_dense().collect();
        assert_eq!(rows, vec![&[0.0][..], &[1.0][..], &[0.0][..]]);
    }

    #[test]
    fn epochs_stamp_writes_and_derive_staleness() {
        let mut store = ClientModelStore::new(3, vec![0.0; 2]);
        assert_eq!(store.current_epoch(), 0);
        assert_eq!(store.staleness(0), 0);
        store.advance_epoch();
        store.advance_epoch();
        // Untouched clients age with the epoch counter (init = epoch 0).
        assert_eq!(store.staleness(0), 2);
        store.set(1, vec![1.0, 1.0]);
        assert_eq!(store.snapshot_epoch(1), 2);
        assert_eq!(store.staleness(1), 0);
        store.advance_epoch();
        assert_eq!(store.staleness(1), 1);
        let snap = store.snapshot(1);
        store.set_shared(2, snap);
        assert_eq!(store.snapshot_epoch(2), 3);
        assert_eq!(store.staleness(2), 0);
        // Dense mode stamps identically (set_shared routes through set).
        let mut dense = ClientModelStore::new_dense(2, vec![0.0; 2]);
        dense.advance_epoch();
        let snap = dense.snapshot(0);
        dense.set_shared(1, snap);
        assert_eq!(dense.snapshot_epoch(1), 1);
    }

    #[test]
    fn materialization_counter_counts_set_calls() {
        let mut store = ClientModelStore::new(4, vec![0.0; 2]);
        assert_eq!(store.materializations(), 0);
        store.set(0, vec![1.0, 1.0]);
        store.set(0, vec![2.0, 2.0]);
        // Aliasing writes are free in CoW mode...
        let snap = store.snapshot(0);
        store.set_shared(1, snap);
        assert_eq!(store.materializations(), 2);
        // ...but deep-copy (and count) in dense mode.
        let mut dense = ClientModelStore::new_dense(2, vec![0.0; 2]);
        let snap = dense.snapshot(0);
        dense.set_shared(1, snap);
        assert_eq!(dense.materializations(), 1);
    }

    #[test]
    fn snapshot_outlives_divergence() {
        let mut store = ClientModelStore::new(2, vec![4.0]);
        let snap = store.snapshot(0);
        store.set(0, vec![5.0]);
        // The holder's view is immutable: divergence replaced the entry,
        // it did not mutate the shared allocation.
        assert_eq!(snap.as_slice(), &[4.0]);
        assert_eq!(store.get(0), &[5.0]);
    }
}

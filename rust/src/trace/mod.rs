//! Structured tracing & self-profiling (L3-trace).
//!
//! A zero-overhead-when-off observability layer threaded through every
//! subsystem: dual-stamped span events (wall-clock nanoseconds *and*
//! simulated seconds) around each round phase, cumulative counters for
//! the hot internals (EnginePool busy time, event-queue drains, Fenwick
//! operations, CoW materializations, encoded bits), and per-interaction
//! samples (delay, staleness) whose *distribution* — not just the mean —
//! is what the async-FL analyses say drives convergence.
//!
//! Design rules (enforced by rust/tests/trace_parity.rs):
//!
//! - **Bit-exact**: no code path here draws from any RNG or reorders a
//!   float fold. Enabling a sink changes bytes on disk, never a
//!   trajectory value.
//! - **Zero overhead when off**: the [`Tracer`] handle wraps an
//!   `Option<Arc<dyn TraceSink>>`; every hook starts with an `is_some()`
//!   check, and [`Tracer::start`] only reads the clock when a sink is
//!   armed, so the disabled path is a branch on a local option.
//! - **One channel**: diagnostics go through the leveled [`crate::log!`]
//!   macro (stderr by default); when the CLI installs a sink mirror they
//!   also land in the JSONL stream as `log` events.
//!
//! Event kinds and field-level stability guarantees are documented in
//! `docs/TRACE_SCHEMA.md`. Aggregation (`quafl trace-report`,
//! `BENCH_phase.json`) lives in [`report`].

pub mod report;

use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::{BufWriter, Write as _};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Verbosity level, total-ordered `Off < Error < Info < Debug`.
///
/// For the trace stream, `Info` (the default) records every structured
/// event kind; `Error` and `Off` suppress spans/counters/samples (the
/// sink then only sees mirrored `log` events at or below the level).
/// For the [`crate::log!`] macro the level gates stderr diagnostics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn parse(s: &str) -> Result<Level, String> {
        match s {
            "off" => Ok(Level::Off),
            "error" => Ok(Level::Error),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => Err(format!(
                "unknown trace level {other:?}; expected off|error|info|debug"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// One structured trace event. The JSONL encoding (see
/// [`Event::to_json`]) tags each line with a `kind` discriminator so
/// downstream tooling can dispatch without schema negotiation.
#[derive(Debug, Clone)]
pub enum Event {
    /// Run header: static facts about the experiment (algorithm, n, s,
    /// seed, workers, ...). Emitted once per run.
    Meta { fields: Vec<(&'static str, Json)> },
    /// A completed phase: `wall_ns` of host time and `sim_dt` of
    /// simulated seconds spent, stamped with the simulated clock
    /// (`sim_now`) at completion.
    Span {
        phase: &'static str,
        round: u64,
        wall_ns: u64,
        sim_dt: f64,
        sim_now: f64,
    },
    /// A named cumulative counter or gauge polled at a round boundary.
    Counter {
        name: &'static str,
        round: u64,
        value: f64,
        sim_now: f64,
    },
    /// One observation of a per-interaction quantity (delay seconds,
    /// staleness rounds, ...). High-volume; the report histograms these.
    Sample {
        name: &'static str,
        round: u64,
        value: f64,
    },
    /// A named telemetry metric flushed at a round boundary by the
    /// [`crate::telemetry`] registry: convergence probes (Φ_t,
    /// discrepancy), distribution-sketch summaries, selection-bias
    /// statistics. Owns its name (sketch summaries compose suffixes
    /// like `qerr_p95` at flush time).
    Metric {
        name: String,
        round: u64,
        value: f64,
        sim_now: f64,
    },
    /// A mirrored diagnostic line from [`crate::log!`].
    Log { level: Level, msg: String },
}

impl Event {
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Meta { .. } => "meta",
            Event::Span { .. } => "span",
            Event::Counter { .. } => "counter",
            Event::Sample { .. } => "sample",
            Event::Metric { .. } => "metric",
            Event::Log { .. } => "log",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kind".to_string(), Json::Str(self.kind().to_string()));
        match self {
            Event::Meta { fields } => {
                for (k, v) in fields {
                    o.insert(k.to_string(), v.clone());
                }
            }
            Event::Span {
                phase,
                round,
                wall_ns,
                sim_dt,
                sim_now,
            } => {
                o.insert("phase".into(), Json::Str(phase.to_string()));
                o.insert("round".into(), Json::Num(*round as f64));
                o.insert("wall_ns".into(), Json::Num(*wall_ns as f64));
                o.insert("sim_dt".into(), Json::Num(*sim_dt));
                o.insert("sim_now".into(), Json::Num(*sim_now));
            }
            Event::Counter {
                name,
                round,
                value,
                sim_now,
            } => {
                o.insert("name".into(), Json::Str(name.to_string()));
                o.insert("round".into(), Json::Num(*round as f64));
                o.insert("value".into(), Json::Num(*value));
                o.insert("sim_now".into(), Json::Num(*sim_now));
            }
            Event::Sample { name, round, value } => {
                o.insert("name".into(), Json::Str(name.to_string()));
                o.insert("round".into(), Json::Num(*round as f64));
                o.insert("value".into(), Json::Num(*value));
            }
            Event::Metric {
                name,
                round,
                value,
                sim_now,
            } => {
                o.insert("name".into(), Json::Str(name.clone()));
                o.insert("round".into(), Json::Num(*round as f64));
                o.insert("value".into(), Json::Num(*value));
                o.insert("sim_now".into(), Json::Num(*sim_now));
            }
            Event::Log { level, msg } => {
                o.insert("level".into(), Json::Str(level.name().to_string()));
                o.insert("msg".into(), Json::Str(msg.clone()));
            }
        }
        Json::Obj(o)
    }
}

/// Destination for trace events. Implementations must tolerate emission
/// from any thread (the log mirror can fire from worker threads).
pub trait TraceSink: Send + Sync {
    fn emit(&self, event: &Event);
    fn flush(&self) {}
}

fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panic while holding the sink lock poisons it; keep tracing
    // best-effort rather than cascading the panic.
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Buffered JSONL file sink: one [`Event`] per line, encoded with the
/// in-crate [`crate::util::json`] writer. Opens in *append* mode so the
/// sequential runs of a `figures`/`sweep` invocation accumulate into a
/// single trace file.
pub struct JsonlSink {
    out: Mutex<BufWriter<std::fs::File>>,
}

impl JsonlSink {
    pub fn append(path: &str) -> std::io::Result<JsonlSink> {
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlSink {
            out: Mutex::new(BufWriter::new(f)),
        })
    }
}

impl TraceSink for JsonlSink {
    fn emit(&self, event: &Event) {
        let mut line = json::to_string(&event.to_json());
        line.push('\n');
        let mut g = lock_or_recover(&self.out);
        // Every flush stays line-aligned: a line never straddles a buffer
        // boundary, so two sinks appending to one O_APPEND file (a run's
        // sink plus the CLI's log mirror) cannot interleave mid-line.
        // Trace IO failures must never abort a simulation.
        if g.buffer().len() + line.len() > g.capacity() {
            let _ = g.flush();
        }
        if line.len() > g.capacity() {
            let _ = g.get_mut().write_all(line.as_bytes());
        } else {
            let _ = g.write_all(line.as_bytes());
        }
    }

    fn flush(&self) {
        let _ = lock_or_recover(&self.out).flush();
    }
}

/// In-memory sink for tests: keeps every event in arrival order.
#[derive(Default)]
pub struct RingSink {
    events: Mutex<Vec<Event>>,
}

impl RingSink {
    pub fn new() -> RingSink {
        RingSink::default()
    }

    pub fn events(&self) -> Vec<Event> {
        lock_or_recover(&self.events).clone()
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.events).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl TraceSink for RingSink {
    fn emit(&self, event: &Event) {
        lock_or_recover(&self.events).push(event.clone());
    }
}

/// Started-span token. Holds the wall clock only when a sink is armed,
/// so the disabled path never calls `Instant::now()`.
#[derive(Debug, Clone, Copy)]
pub struct SpanStart(Option<Instant>);

/// Cheap cloneable handle threaded through [`crate::coordinator::FlRun`].
/// `Tracer::off()` (the default) is a `None` and every hook is a near
/// no-op; an armed tracer forwards events to its shared sink.
#[derive(Clone, Default)]
pub struct Tracer {
    sink: Option<Arc<dyn TraceSink>>,
    level: Level,
}

impl Default for Level {
    fn default() -> Level {
        Level::Info
    }
}

impl Tracer {
    /// The disabled tracer: no sink, hooks compile to option checks.
    pub fn off() -> Tracer {
        Tracer {
            sink: None,
            level: Level::Info,
        }
    }

    pub fn new(sink: Arc<dyn TraceSink>, level: Level) -> Tracer {
        Tracer {
            sink: Some(sink),
            level,
        }
    }

    /// Armed = a sink is installed *and* the level admits structured
    /// events (spans/counters/samples are `Info`-severity).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some() && self.level >= Level::Info
    }

    /// Begin a phase span; reads the clock only when armed.
    #[inline]
    pub fn start(&self) -> SpanStart {
        if self.enabled() {
            SpanStart(Some(Instant::now()))
        } else {
            SpanStart(None)
        }
    }

    /// Complete a phase span started with [`Tracer::start`].
    #[inline]
    pub fn span(&self, phase: &'static str, start: SpanStart, round: u64, sim_dt: f64, sim_now: f64) {
        if let (Some(t0), true) = (start.0, self.enabled()) {
            self.emit(&Event::Span {
                phase,
                round,
                wall_ns: t0.elapsed().as_nanos() as u64,
                sim_dt,
                sim_now,
            });
        }
    }

    #[inline]
    pub fn counter(&self, name: &'static str, round: u64, value: f64, sim_now: f64) {
        if self.enabled() {
            self.emit(&Event::Counter {
                name,
                round,
                value,
                sim_now,
            });
        }
    }

    #[inline]
    pub fn sample(&self, name: &'static str, round: u64, value: f64) {
        if self.enabled() {
            self.emit(&Event::Sample { name, round, value });
        }
    }

    /// Emit one [`Event::Metric`] (the [`crate::telemetry`] registry's
    /// flush path). Takes `&str` because sketch summaries compose their
    /// names at flush time; the allocation only happens when armed.
    #[inline]
    pub fn metric(&self, name: &str, round: u64, value: f64, sim_now: f64) {
        if self.enabled() {
            self.emit(&Event::Metric {
                name: name.to_string(),
                round,
                value,
                sim_now,
            });
        }
    }

    pub fn meta(&self, fields: Vec<(&'static str, Json)>) {
        if self.enabled() {
            self.emit(&Event::Meta { fields });
        }
    }

    fn emit(&self, e: &Event) {
        if let Some(s) = &self.sink {
            s.emit(e);
        }
    }

    pub fn flush(&self) {
        if let Some(s) = &self.sink {
            s.flush();
        }
    }
}

// ---------------------------------------------------------------------------
// Leveled diagnostics: the one channel for library stderr output.

static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static LOG_MIRROR: OnceLock<Arc<dyn TraceSink>> = OnceLock::new();

/// Set the process-wide diagnostic level (`--trace-level`).
pub fn set_log_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log_level() -> Level {
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Off,
        1 => Level::Error,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

#[inline]
pub fn log_enabled(level: Level) -> bool {
    level != Level::Off && level as u8 <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Mirror diagnostics into a trace sink (installed once by the CLI when
/// `--trace` is given; library code and tests never install one, so
/// parallel `cargo test` stays isolated).
pub fn install_log_mirror(sink: Arc<dyn TraceSink>) {
    let _ = LOG_MIRROR.set(sink);
}

/// Write one diagnostic line to stderr and the mirror sink, if any.
/// Call through [`crate::log!`], which gates on [`log_enabled`] first.
pub fn log_line(level: Level, msg: String) {
    eprintln!("{msg}");
    if let Some(s) = LOG_MIRROR.get() {
        s.emit(&Event::Log { level, msg });
        // Diagnostics are rare; flushing each keeps the mirror's lines
        // whole on disk even if the process aborts.
        s.flush();
    }
}

/// Leveled diagnostic logging: `crate::log!(Info, "[figures] {id} done")`.
/// Levels are [`Level`] variant names (`Error`, `Info`, `Debug`). Output
/// goes to stderr (matching the historical `eprintln!` call sites) and,
/// when the CLI installed a mirror, to the JSONL trace as `log` events.
/// The format arguments are not evaluated when the level is filtered.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $($arg:tt)*) => {{
        if $crate::trace::log_enabled($crate::trace::Level::$lvl) {
            $crate::trace::log_line($crate::trace::Level::$lvl, format!($($arg)*));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("off").unwrap(), Level::Off);
        assert_eq!(Level::parse("debug").unwrap(), Level::Debug);
        assert!(Level::parse("verbose").is_err());
        assert!(Level::Off < Level::Error && Level::Error < Level::Info && Level::Info < Level::Debug);
        assert_eq!(Level::parse("info").unwrap().name(), "info");
    }

    #[test]
    fn off_tracer_is_inert() {
        let t = Tracer::off();
        assert!(!t.enabled());
        let s = t.start();
        assert!(s.0.is_none());
        // None of these should panic or allocate a sink.
        t.span("round", s, 0, 1.0, 1.0);
        t.counter("bits_up", 0, 0.0, 0.0);
        t.sample("delay", 0, 0.5);
        t.flush();
    }

    #[test]
    fn ring_sink_captures_all_kinds() {
        let ring = Arc::new(RingSink::new());
        let t = Tracer::new(ring.clone(), Level::Info);
        assert!(t.enabled());
        t.meta(vec![("algorithm", Json::Str("quafl".into()))]);
        let s = t.start();
        t.span("select", s, 3, 0.25, 10.0);
        t.counter("fenwick_ops", 3, 42.0, 10.0);
        t.sample("delay", 3, 1.5);
        let evs = ring.events();
        assert_eq!(evs.len(), 4);
        let kinds: Vec<&str> = evs.iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, vec!["meta", "span", "counter", "sample"]);
        match &evs[1] {
            Event::Span {
                phase,
                round,
                sim_dt,
                sim_now,
                ..
            } => {
                assert_eq!(*phase, "select");
                assert_eq!(*round, 3);
                assert_eq!(*sim_dt, 0.25);
                assert_eq!(*sim_now, 10.0);
            }
            other => panic!("expected span, got {other:?}"),
        }
    }

    #[test]
    fn error_level_suppresses_structured_events() {
        let ring = Arc::new(RingSink::new());
        let t = Tracer::new(ring.clone(), Level::Error);
        assert!(!t.enabled());
        let s = t.start();
        t.span("round", s, 0, 0.0, 0.0);
        t.counter("bits_up", 0, 1.0, 0.0);
        assert!(ring.is_empty());
    }

    #[test]
    fn event_json_has_kind_and_fields() {
        let e = Event::Span {
            phase: "reduce",
            round: 7,
            wall_ns: 1500,
            sim_dt: 0.5,
            sim_now: 99.0,
        };
        let j = e.to_json();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("span"));
        assert_eq!(j.get("phase").and_then(|v| v.as_str()), Some("reduce"));
        assert_eq!(j.get("round").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("wall_ns").and_then(|v| v.as_f64()), Some(1500.0));
        // Round-trips through the writer/parser.
        let back = json::parse(&json::to_string(&j)).unwrap();
        assert_eq!(back.get("sim_now").and_then(|v| v.as_f64()), Some(99.0));

        let log = Event::Log {
            level: Level::Info,
            msg: "hello".into(),
        }
        .to_json();
        assert_eq!(log.get("level").and_then(|v| v.as_str()), Some("info"));
        assert_eq!(log.get("msg").and_then(|v| v.as_str()), Some("hello"));
    }

    #[test]
    fn metric_events_round_trip() {
        let ring = Arc::new(RingSink::new());
        let t = Tracer::new(ring.clone(), Level::Info);
        t.metric("qerr_p95", 4, 0.125, 17.0);
        let evs = ring.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind(), "metric");
        let j = evs[0].to_json();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("metric"));
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("qerr_p95"));
        assert_eq!(j.get("round").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(j.get("value").and_then(|v| v.as_f64()), Some(0.125));
        assert_eq!(j.get("sim_now").and_then(|v| v.as_f64()), Some(17.0));
        let back = json::parse(&json::to_string(&j)).unwrap();
        assert_eq!(back.get("value").and_then(|v| v.as_f64()), Some(0.125));
        // Disarmed levels suppress metrics like every structured kind.
        let quiet = Tracer::new(Arc::new(RingSink::new()), Level::Error);
        quiet.metric("phi", 0, 1.0, 0.0);
        let off = Tracer::off();
        off.metric("phi", 0, 1.0, 0.0);
    }

    #[test]
    fn jsonl_sink_appends_parseable_lines() {
        let path = std::env::temp_dir().join(format!(
            "quafl_trace_test_{}.jsonl",
            std::process::id()
        ));
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        {
            let sink = Arc::new(JsonlSink::append(&path_s).unwrap());
            let t = Tracer::new(sink, Level::Info);
            t.counter("bits_up", 0, 128.0, 1.0);
            t.sample("delay", 0, 2.5);
            t.flush();
        }
        {
            // Second sink on the same path must append, not truncate.
            let sink = Arc::new(JsonlSink::append(&path_s).unwrap());
            let t = Tracer::new(sink, Level::Info);
            t.sample("delay", 1, 3.5);
            t.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let j = json::parse(line).unwrap();
            assert!(j.get("kind").is_some());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn log_level_gating() {
        // Do not mutate the global level here (tests run in parallel);
        // just check the predicate against the default.
        assert!(!log_enabled(Level::Off));
        assert!(log_enabled(Level::Error) || log_level() == Level::Off);
    }
}

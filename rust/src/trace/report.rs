//! Trace aggregation: turn a JSONL trace stream into a per-phase
//! wall-time/sim-time breakdown, per-interaction sample histograms, and
//! the canonical `BENCH_phase.json` artifact (`quafl trace-report`).
//!
//! The input is the event stream documented in `docs/TRACE_SCHEMA.md`;
//! unknown `kind`s are counted and skipped, never fatal, so newer traces
//! stay readable by older tooling and vice versa.

use std::collections::BTreeMap;

use crate::telemetry::sketch::QuantileSketch;
use crate::util::json::{self, Json};

/// Canonical phase display order; phases outside this list render after
/// it, alphabetically.
const PHASE_ORDER: &[&str] = &[
    "select",
    "broadcast",
    "quantize",
    "local_sgd",
    "reduce",
    "eval",
    "round",
];

/// Number of equal-width bins in sample histograms.
const HIST_BINS: usize = 8;

/// Fixed seed for the report-side sketches: summaries of the same trace
/// are identical across invocations.
const SAMPLE_SKETCH_SEED: u64 = 0x5A3C;

/// Distribution summary shared with `quafl health-report`: both reports
/// run their sample streams through the telemetry quantile sketch
/// ([`crate::telemetry::sketch`]) — one implementation, one set of error
/// bounds (exact below the sketch capacity, documented rank-error bound
/// above it).
fn sample_sketch(values: &[f64]) -> QuantileSketch {
    let mut sk = QuantileSketch::new(SAMPLE_SKETCH_SEED);
    for &v in values {
        sk.update(v);
    }
    sk
}

#[derive(Debug, Default, Clone)]
pub struct SpanAgg {
    pub count: u64,
    pub wall_ns_total: f64,
    pub wall_ns_max: f64,
    pub sim_dt_total: f64,
}

#[derive(Debug, Default, Clone)]
pub struct CounterAgg {
    pub count: u64,
    pub last: f64,
    pub max: f64,
}

/// Summary of one telemetry metric series (`kind: "metric"` events —
/// the full per-round series rendering is `quafl health-report`'s job;
/// trace-report only summarizes).
#[derive(Debug, Clone)]
pub struct MetricAgg {
    pub count: u64,
    pub first: f64,
    pub last: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for MetricAgg {
    fn default() -> MetricAgg {
        MetricAgg {
            count: 0,
            first: 0.0,
            last: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Aggregated view of one trace file.
#[derive(Debug, Default)]
pub struct Report {
    pub events: usize,
    pub meta: Vec<Json>,
    pub spans: BTreeMap<String, SpanAgg>,
    pub counters: BTreeMap<String, CounterAgg>,
    pub samples: BTreeMap<String, Vec<f64>>,
    pub metrics: BTreeMap<String, MetricAgg>,
    pub logs: usize,
    pub unknown: usize,
}

/// Fold a parsed event stream (see [`json::parse_lines`]) into a report.
pub fn aggregate(events: &[Json]) -> Report {
    let mut r = Report::default();
    for e in events {
        r.events += 1;
        match e.get("kind").and_then(|k| k.as_str()) {
            Some("meta") => r.meta.push(e.clone()),
            Some("span") => {
                let phase = e
                    .get("phase")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let wall = e.get("wall_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let sim = e.get("sim_dt").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let agg = r.spans.entry(phase).or_default();
                agg.count += 1;
                agg.wall_ns_total += wall;
                agg.wall_ns_max = agg.wall_ns_max.max(wall);
                agg.sim_dt_total += sim;
            }
            Some("counter") => {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let agg = r.counters.entry(name).or_default();
                agg.count += 1;
                agg.last = value;
                agg.max = agg.max.max(value);
            }
            Some("sample") => {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                r.samples.entry(name).or_default().push(value);
            }
            Some("metric") => {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let agg = r.metrics.entry(name).or_default();
                if agg.count == 0 {
                    agg.first = value;
                }
                agg.count += 1;
                agg.last = value;
                agg.min = agg.min.min(value);
                agg.max = agg.max.max(value);
            }
            Some("log") => r.logs += 1,
            _ => r.unknown += 1,
        }
    }
    r
}

fn fmt_wall(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

impl Report {
    /// Phase names in canonical-then-alphabetical display order.
    fn ordered_phases(&self) -> Vec<&str> {
        let mut out: Vec<&str> = PHASE_ORDER
            .iter()
            .copied()
            .filter(|p| self.spans.contains_key(*p))
            .collect();
        for p in self.spans.keys() {
            if !PHASE_ORDER.contains(&p.as_str()) {
                out.push(p);
            }
        }
        out
    }

    /// Human-readable breakdown table (what `trace-report` prints).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "trace: {} events ({} meta, {} spans, {} counters, {} samples, {} metrics, {} logs, {} unknown)\n",
            self.events,
            self.meta.len(),
            self.spans.values().map(|a| a.count).sum::<u64>(),
            self.counters.values().map(|a| a.count).sum::<u64>(),
            self.samples.values().map(|v| v.len()).sum::<usize>(),
            self.metrics.values().map(|a| a.count).sum::<u64>(),
            self.logs,
            self.unknown,
        ));
        for m in &self.meta {
            if let Some(o) = m.as_obj() {
                let mut parts = Vec::new();
                for (k, v) in o {
                    if k == "kind" {
                        continue;
                    }
                    parts.push(format!("{k}={}", json::to_string(v)));
                }
                s.push_str(&format!("run: {}\n", parts.join(" ")));
            }
        }
        if !self.spans.is_empty() {
            s.push_str(&format!(
                "\n{:<12} {:>8} {:>12} {:>12} {:>12} {:>14}\n",
                "phase", "count", "wall total", "wall mean", "wall max", "sim total"
            ));
            for phase in self.ordered_phases() {
                let a = &self.spans[phase];
                let mean = if a.count > 0 {
                    a.wall_ns_total / a.count as f64
                } else {
                    0.0
                };
                s.push_str(&format!(
                    "{:<12} {:>8} {:>12} {:>12} {:>12} {:>13.3}s\n",
                    phase,
                    a.count,
                    fmt_wall(a.wall_ns_total),
                    fmt_wall(mean),
                    fmt_wall(a.wall_ns_max),
                    a.sim_dt_total,
                ));
            }
        }
        if !self.samples.is_empty() {
            s.push_str(&format!(
                "\n{:<12} {:>8} {:>12} {:>12} {:>12} {:>12}\n",
                "sample", "count", "mean", "p50", "p95", "max"
            ));
            for (name, values) in &self.samples {
                let sk = sample_sketch(values);
                let mean = values.iter().sum::<f64>() / values.len().max(1) as f64;
                let (lo, hi, counts) = sk
                    .histogram(HIST_BINS)
                    .unwrap_or((0.0, 0.0, vec![0; HIST_BINS]));
                s.push_str(&format!(
                    "{:<12} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>12.4}\n",
                    name,
                    values.len(),
                    mean,
                    sk.quantile(0.50),
                    sk.quantile(0.95),
                    sk.max(),
                ));
                let bars: Vec<String> = counts.iter().map(|c| c.to_string()).collect();
                s.push_str(&format!(
                    "{:<12} hist [{lo:.4}..{hi:.4}]: {}\n",
                    "",
                    bars.join(" ")
                ));
            }
        }
        if !self.counters.is_empty() {
            s.push_str(&format!("\n{:<22} {:>8} {:>16}\n", "counter", "polls", "last"));
            for (name, a) in &self.counters {
                s.push_str(&format!("{:<22} {:>8} {:>16.0}\n", name, a.count, a.last));
            }
        }
        if !self.metrics.is_empty() {
            s.push_str(&format!(
                "\n{:<18} {:>8} {:>12} {:>12} {:>12} {:>12}\n",
                "metric", "points", "first", "last", "min", "max"
            ));
            for (name, a) in &self.metrics {
                s.push_str(&format!(
                    "{:<18} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>12.4}\n",
                    name, a.count, a.first, a.last, a.min, a.max
                ));
            }
            s.push_str(
                "(per-round metric series: quafl health-report FILE.jsonl)\n",
            );
        }
        if let Some(line) = self.kernel_throughput_line() {
            s.push_str(&line);
        }
        s
    }

    /// Derived engine-throughput line: cumulative kernel flops/bytes (the
    /// engine's analytic tally) over the wall time of the engine-bearing
    /// phases (local_sgd + eval). flops/ns is numerically GFLOP/s.
    /// `None` when the trace carries no kernel counters or no engine
    /// phase wall time.
    fn kernel_throughput_line(&self) -> Option<String> {
        let flops = self.counters.get("kernel_flops")?.last;
        let bytes = self.counters.get("kernel_bytes").map(|a| a.last).unwrap_or(0.0);
        let engine_ns: f64 = ["local_sgd", "eval"]
            .iter()
            .filter_map(|p| self.spans.get(*p))
            .map(|a| a.wall_ns_total)
            .sum();
        if flops <= 0.0 || engine_ns <= 0.0 {
            return None;
        }
        Some(format!(
            "\nengine: {:.2} GFLOP, {:.2} GB touched, {:.2} GFLOP/s over \
             local_sgd+eval wall ({})\n",
            flops / 1e9,
            bytes / 1e9,
            flops / engine_ns,
            fmt_wall(engine_ns),
        ))
    }

    /// The canonical `BENCH_phase.json` document: one row per phase,
    /// sample distribution, and counter, in the same `{bench, rows}`
    /// shape as `BENCH_fleet.json`.
    pub fn bench_json(&self) -> Json {
        let mut rows = Vec::new();
        for phase in self.ordered_phases() {
            let a = &self.spans[phase];
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("span".into()));
            row.insert("phase".into(), Json::Str(phase.to_string()));
            row.insert("count".into(), Json::Num(a.count as f64));
            row.insert("wall_ns_total".into(), Json::Num(a.wall_ns_total));
            row.insert(
                "wall_ns_mean".into(),
                Json::Num(if a.count > 0 {
                    a.wall_ns_total / a.count as f64
                } else {
                    0.0
                }),
            );
            row.insert("wall_ns_max".into(), Json::Num(a.wall_ns_max));
            row.insert("sim_dt_total".into(), Json::Num(a.sim_dt_total));
            rows.push(Json::Obj(row));
        }
        for (name, values) in &self.samples {
            let sk = sample_sketch(values);
            let (lo, hi, counts) = sk
                .histogram(HIST_BINS)
                .unwrap_or((0.0, 0.0, vec![0; HIST_BINS]));
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("sample".into()));
            row.insert("name".into(), Json::Str(name.clone()));
            row.insert("count".into(), Json::Num(values.len() as f64));
            row.insert(
                "mean".into(),
                Json::Num(values.iter().sum::<f64>() / values.len().max(1) as f64),
            );
            row.insert("p50".into(), Json::Num(sk.quantile(0.50)));
            row.insert("p95".into(), Json::Num(sk.quantile(0.95)));
            row.insert("max".into(), Json::Num(if sk.is_empty() { 0.0 } else { sk.max() }));
            row.insert("hist_min".into(), Json::Num(lo));
            row.insert("hist_max".into(), Json::Num(hi));
            row.insert(
                "hist".into(),
                Json::Arr(counts.iter().map(|c| Json::Num(*c as f64)).collect()),
            );
            rows.push(Json::Obj(row));
        }
        for (name, a) in &self.counters {
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("counter".into()));
            row.insert("name".into(), Json::Str(name.clone()));
            row.insert("polls".into(), Json::Num(a.count as f64));
            row.insert("last".into(), Json::Num(a.last));
            row.insert("max".into(), Json::Num(a.max));
            rows.push(Json::Obj(row));
        }
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("phase_breakdown".into()));
        doc.insert("rows".into(), Json::Arr(rows));
        Json::Obj(doc)
    }

    /// Write `BENCH_phase.json` under `out_dir`; returns the path.
    pub fn write_bench(&self, out_dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(out_dir)?;
        let path = format!("{out_dir}/BENCH_phase.json");
        std::fs::write(&path, json::to_string(&self.bench_json()) + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Event;

    fn span(phase: &'static str, round: u64, wall_ns: u64, sim_dt: f64) -> Json {
        Event::Span {
            phase,
            round,
            wall_ns,
            sim_dt,
            sim_now: round as f64,
        }
        .to_json()
    }

    fn sample(name: &'static str, value: f64) -> Json {
        Event::Sample {
            name,
            round: 0,
            value,
        }
        .to_json()
    }

    fn counter(name: &'static str, value: f64) -> Json {
        Event::Counter {
            name,
            round: 0,
            value,
            sim_now: 0.0,
        }
        .to_json()
    }

    #[test]
    fn aggregates_spans_counters_samples() {
        let events = vec![
            Event::Meta {
                fields: vec![("algorithm", Json::Str("quafl".into()))],
            }
            .to_json(),
            span("select", 0, 100, 0.0),
            span("select", 1, 300, 0.0),
            span("local_sgd", 0, 5000, 0.5),
            counter("bits_up", 128.0),
            counter("bits_up", 512.0),
            sample("delay", 1.0),
            sample("delay", 3.0),
            sample("delay", 2.0),
        ];
        let r = aggregate(&events);
        assert_eq!(r.events, events.len());
        assert_eq!(r.meta.len(), 1);
        let sel = &r.spans["select"];
        assert_eq!(sel.count, 2);
        assert_eq!(sel.wall_ns_total, 400.0);
        assert_eq!(sel.wall_ns_max, 300.0);
        assert_eq!(r.spans["local_sgd"].sim_dt_total, 0.5);
        let bits = &r.counters["bits_up"];
        assert_eq!(bits.count, 2);
        assert_eq!(bits.last, 512.0);
        assert_eq!(bits.max, 512.0);
        assert_eq!(r.samples["delay"], vec![1.0, 3.0, 2.0]);
        assert_eq!(r.unknown, 0);
    }

    #[test]
    fn unknown_kinds_are_counted_not_fatal() {
        let mut o = std::collections::BTreeMap::new();
        o.insert("kind".to_string(), Json::Str("future_kind".into()));
        let r = aggregate(&[Json::Obj(o), Json::Num(3.0)]);
        assert_eq!(r.unknown, 2);
        assert_eq!(r.events, 2);
    }

    #[test]
    fn sample_summary_via_shared_sketch() {
        // Below sketch capacity the shared implementation is exact
        // nearest-rank — the same numbers the old in-module percentile
        // computed.
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let sk = sample_sketch(&v);
        assert_eq!(sk.quantile(0.0), 1.0);
        assert_eq!(sk.quantile(0.5), 3.0);
        assert_eq!(sk.quantile(1.0), 5.0);
        let v8: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let (lo, hi, counts) = sample_sketch(&v8).histogram(8).unwrap();
        assert_eq!((lo, hi), (0.0, 7.0));
        assert_eq!(counts.iter().sum::<u64>(), 8);
        // Degenerate range: everything lands in bin 0.
        let (_, _, c1) = sample_sketch(&[2.0, 2.0, 2.0]).histogram(8).unwrap();
        assert_eq!(c1[0], 3);
        assert_eq!(c1.iter().sum::<u64>(), 3);
    }

    #[test]
    fn metric_events_aggregate_and_render() {
        let metric = |name: &str, round: u64, value: f64| {
            Event::Metric {
                name: name.to_string(),
                round,
                value,
                sim_now: round as f64,
            }
            .to_json()
        };
        let events = vec![
            metric("phi", 0, 4.0),
            metric("phi", 1, 2.0),
            metric("phi", 2, 1.0),
            metric("qerr_p95", 2, 0.25),
        ];
        let r = aggregate(&events);
        assert_eq!(r.unknown, 0);
        let phi = &r.metrics["phi"];
        assert_eq!(phi.count, 3);
        assert_eq!(phi.first, 4.0);
        assert_eq!(phi.last, 1.0);
        assert_eq!(phi.min, 1.0);
        assert_eq!(phi.max, 4.0);
        let text = r.render();
        assert!(text.contains("phi"), "{text}");
        assert!(text.contains("qerr_p95"), "{text}");
        assert!(text.contains("health-report"), "{text}");
        assert!(text.contains("4 metrics"), "{text}");
    }

    #[test]
    fn kernel_throughput_line_derived_from_counters_and_spans() {
        // 2e9 flops over 1e9 ns of local_sgd + 1e9 ns of eval = 1 GFLOP/s.
        let events = vec![
            span("local_sgd", 0, 1_000_000_000, 0.0),
            span("eval", 0, 1_000_000_000, 0.0),
            counter("kernel_flops", 2.0e9),
            counter("kernel_bytes", 5.0e8),
        ];
        let r = aggregate(&events);
        let text = r.render();
        assert!(text.contains("1.00 GFLOP/s"), "{text}");
        assert!(text.contains("2.00 GFLOP"), "{text}");
        // No kernel counters -> no derived line.
        let r = aggregate(&[span("local_sgd", 0, 1000, 0.0)]);
        assert!(!r.render().contains("GFLOP/s"));
        // Kernel counters but no engine spans -> no derived line (avoid
        // a divide-by-zero throughput claim).
        let r = aggregate(&[counter("kernel_flops", 1.0e9)]);
        assert!(!r.render().contains("GFLOP/s"));
    }

    #[test]
    fn render_and_bench_json() {
        let events = vec![
            span("round", 0, 2_000_000, 1.5),
            span("select", 0, 1000, 0.0),
            sample("delay", 0.5),
            sample("delay", 1.5),
            counter("cow_materializations", 7.0),
        ];
        let r = aggregate(&events);
        let text = r.render();
        assert!(text.contains("select"), "{text}");
        assert!(text.contains("round"), "{text}");
        assert!(text.contains("delay"), "{text}");
        assert!(text.contains("cow_materializations"), "{text}");
        // select renders before round (canonical phase order).
        assert!(text.find("select").unwrap() < text.find("round").unwrap());

        let doc = r.bench_json();
        assert_eq!(
            doc.get("bench").and_then(|v| v.as_str()),
            Some("phase_breakdown")
        );
        let rows = doc.get("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 4); // 2 spans + 1 sample + 1 counter
        // Canonical JSON round-trips through the in-crate parser.
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back, doc);
        let hist = rows
            .iter()
            .find(|r| r.get("kind").and_then(|k| k.as_str()) == Some("sample"))
            .and_then(|r| r.get("hist"))
            .and_then(|h| h.as_arr())
            .unwrap();
        assert_eq!(hist.len(), HIST_BINS);
    }
}

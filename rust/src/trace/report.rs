//! Trace aggregation: turn a JSONL trace stream into a per-phase
//! wall-time/sim-time breakdown, per-interaction sample histograms, and
//! the canonical `BENCH_phase.json` artifact (`quafl trace-report`).
//!
//! The input is the event stream documented in `docs/TRACE_SCHEMA.md`;
//! unknown `kind`s are counted and skipped, never fatal, so newer traces
//! stay readable by older tooling and vice versa.

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

/// Canonical phase display order; phases outside this list render after
/// it, alphabetically.
const PHASE_ORDER: &[&str] = &[
    "select",
    "broadcast",
    "quantize",
    "local_sgd",
    "reduce",
    "eval",
    "round",
];

/// Number of equal-width bins in sample histograms.
const HIST_BINS: usize = 8;

#[derive(Debug, Default, Clone)]
pub struct SpanAgg {
    pub count: u64,
    pub wall_ns_total: f64,
    pub wall_ns_max: f64,
    pub sim_dt_total: f64,
}

#[derive(Debug, Default, Clone)]
pub struct CounterAgg {
    pub count: u64,
    pub last: f64,
    pub max: f64,
}

/// Aggregated view of one trace file.
#[derive(Debug, Default)]
pub struct Report {
    pub events: usize,
    pub meta: Vec<Json>,
    pub spans: BTreeMap<String, SpanAgg>,
    pub counters: BTreeMap<String, CounterAgg>,
    pub samples: BTreeMap<String, Vec<f64>>,
    pub logs: usize,
    pub unknown: usize,
}

/// Fold a parsed event stream (see [`json::parse_lines`]) into a report.
pub fn aggregate(events: &[Json]) -> Report {
    let mut r = Report::default();
    for e in events {
        r.events += 1;
        match e.get("kind").and_then(|k| k.as_str()) {
            Some("meta") => r.meta.push(e.clone()),
            Some("span") => {
                let phase = e
                    .get("phase")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let wall = e.get("wall_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let sim = e.get("sim_dt").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let agg = r.spans.entry(phase).or_default();
                agg.count += 1;
                agg.wall_ns_total += wall;
                agg.wall_ns_max = agg.wall_ns_max.max(wall);
                agg.sim_dt_total += sim;
            }
            Some("counter") => {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let agg = r.counters.entry(name).or_default();
                agg.count += 1;
                agg.last = value;
                agg.max = agg.max.max(value);
            }
            Some("sample") => {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                r.samples.entry(name).or_default().push(value);
            }
            Some("log") => r.logs += 1,
            _ => r.unknown += 1,
        }
    }
    r
}

/// Nearest-rank percentile over a sorted slice, `q` in `[0, 1]`.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Equal-width histogram over `[min, max]`; returns (min, max, counts).
fn histogram(sorted: &[f64], bins: usize) -> (f64, f64, Vec<u64>) {
    if sorted.is_empty() {
        return (0.0, 0.0, vec![0; bins]);
    }
    let (lo, hi) = (sorted[0], sorted[sorted.len() - 1]);
    let mut counts = vec![0u64; bins];
    if hi <= lo {
        counts[0] = sorted.len() as u64;
        return (lo, hi, counts);
    }
    let width = (hi - lo) / bins as f64;
    for &v in sorted {
        let b = (((v - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    (lo, hi, counts)
}

fn fmt_wall(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

impl Report {
    /// Phase names in canonical-then-alphabetical display order.
    fn ordered_phases(&self) -> Vec<&str> {
        let mut out: Vec<&str> = PHASE_ORDER
            .iter()
            .copied()
            .filter(|p| self.spans.contains_key(*p))
            .collect();
        for p in self.spans.keys() {
            if !PHASE_ORDER.contains(&p.as_str()) {
                out.push(p);
            }
        }
        out
    }

    /// Human-readable breakdown table (what `trace-report` prints).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "trace: {} events ({} meta, {} spans, {} counters, {} samples, {} logs, {} unknown)\n",
            self.events,
            self.meta.len(),
            self.spans.values().map(|a| a.count).sum::<u64>(),
            self.counters.values().map(|a| a.count).sum::<u64>(),
            self.samples.values().map(|v| v.len()).sum::<usize>(),
            self.logs,
            self.unknown,
        ));
        for m in &self.meta {
            if let Some(o) = m.as_obj() {
                let mut parts = Vec::new();
                for (k, v) in o {
                    if k == "kind" {
                        continue;
                    }
                    parts.push(format!("{k}={}", json::to_string(v)));
                }
                s.push_str(&format!("run: {}\n", parts.join(" ")));
            }
        }
        if !self.spans.is_empty() {
            s.push_str(&format!(
                "\n{:<12} {:>8} {:>12} {:>12} {:>12} {:>14}\n",
                "phase", "count", "wall total", "wall mean", "wall max", "sim total"
            ));
            for phase in self.ordered_phases() {
                let a = &self.spans[phase];
                let mean = if a.count > 0 {
                    a.wall_ns_total / a.count as f64
                } else {
                    0.0
                };
                s.push_str(&format!(
                    "{:<12} {:>8} {:>12} {:>12} {:>12} {:>13.3}s\n",
                    phase,
                    a.count,
                    fmt_wall(a.wall_ns_total),
                    fmt_wall(mean),
                    fmt_wall(a.wall_ns_max),
                    a.sim_dt_total,
                ));
            }
        }
        if !self.samples.is_empty() {
            s.push_str(&format!(
                "\n{:<12} {:>8} {:>12} {:>12} {:>12} {:>12}\n",
                "sample", "count", "mean", "p50", "p95", "max"
            ));
            for (name, values) in &self.samples {
                let mut sorted = values.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
                let mean = sorted.iter().sum::<f64>() / sorted.len().max(1) as f64;
                let (lo, hi, counts) = histogram(&sorted, HIST_BINS);
                s.push_str(&format!(
                    "{:<12} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>12.4}\n",
                    name,
                    sorted.len(),
                    mean,
                    percentile(&sorted, 0.50),
                    percentile(&sorted, 0.95),
                    sorted.last().copied().unwrap_or(0.0),
                ));
                let bars: Vec<String> = counts.iter().map(|c| c.to_string()).collect();
                s.push_str(&format!(
                    "{:<12} hist [{lo:.4}..{hi:.4}]: {}\n",
                    "",
                    bars.join(" ")
                ));
            }
        }
        if !self.counters.is_empty() {
            s.push_str(&format!("\n{:<22} {:>8} {:>16}\n", "counter", "polls", "last"));
            for (name, a) in &self.counters {
                s.push_str(&format!("{:<22} {:>8} {:>16.0}\n", name, a.count, a.last));
            }
        }
        if let Some(line) = self.kernel_throughput_line() {
            s.push_str(&line);
        }
        s
    }

    /// Derived engine-throughput line: cumulative kernel flops/bytes (the
    /// engine's analytic tally) over the wall time of the engine-bearing
    /// phases (local_sgd + eval). flops/ns is numerically GFLOP/s.
    /// `None` when the trace carries no kernel counters or no engine
    /// phase wall time.
    fn kernel_throughput_line(&self) -> Option<String> {
        let flops = self.counters.get("kernel_flops")?.last;
        let bytes = self.counters.get("kernel_bytes").map(|a| a.last).unwrap_or(0.0);
        let engine_ns: f64 = ["local_sgd", "eval"]
            .iter()
            .filter_map(|p| self.spans.get(*p))
            .map(|a| a.wall_ns_total)
            .sum();
        if flops <= 0.0 || engine_ns <= 0.0 {
            return None;
        }
        Some(format!(
            "\nengine: {:.2} GFLOP, {:.2} GB touched, {:.2} GFLOP/s over \
             local_sgd+eval wall ({})\n",
            flops / 1e9,
            bytes / 1e9,
            flops / engine_ns,
            fmt_wall(engine_ns),
        ))
    }

    /// The canonical `BENCH_phase.json` document: one row per phase,
    /// sample distribution, and counter, in the same `{bench, rows}`
    /// shape as `BENCH_fleet.json`.
    pub fn bench_json(&self) -> Json {
        let mut rows = Vec::new();
        for phase in self.ordered_phases() {
            let a = &self.spans[phase];
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("span".into()));
            row.insert("phase".into(), Json::Str(phase.to_string()));
            row.insert("count".into(), Json::Num(a.count as f64));
            row.insert("wall_ns_total".into(), Json::Num(a.wall_ns_total));
            row.insert(
                "wall_ns_mean".into(),
                Json::Num(if a.count > 0 {
                    a.wall_ns_total / a.count as f64
                } else {
                    0.0
                }),
            );
            row.insert("wall_ns_max".into(), Json::Num(a.wall_ns_max));
            row.insert("sim_dt_total".into(), Json::Num(a.sim_dt_total));
            rows.push(Json::Obj(row));
        }
        for (name, values) in &self.samples {
            let mut sorted = values.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let (lo, hi, counts) = histogram(&sorted, HIST_BINS);
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("sample".into()));
            row.insert("name".into(), Json::Str(name.clone()));
            row.insert("count".into(), Json::Num(sorted.len() as f64));
            row.insert(
                "mean".into(),
                Json::Num(sorted.iter().sum::<f64>() / sorted.len().max(1) as f64),
            );
            row.insert("p50".into(), Json::Num(percentile(&sorted, 0.50)));
            row.insert("p95".into(), Json::Num(percentile(&sorted, 0.95)));
            row.insert("max".into(), Json::Num(sorted.last().copied().unwrap_or(0.0)));
            row.insert("hist_min".into(), Json::Num(lo));
            row.insert("hist_max".into(), Json::Num(hi));
            row.insert(
                "hist".into(),
                Json::Arr(counts.iter().map(|c| Json::Num(*c as f64)).collect()),
            );
            rows.push(Json::Obj(row));
        }
        for (name, a) in &self.counters {
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("counter".into()));
            row.insert("name".into(), Json::Str(name.clone()));
            row.insert("polls".into(), Json::Num(a.count as f64));
            row.insert("last".into(), Json::Num(a.last));
            row.insert("max".into(), Json::Num(a.max));
            rows.push(Json::Obj(row));
        }
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("phase_breakdown".into()));
        doc.insert("rows".into(), Json::Arr(rows));
        Json::Obj(doc)
    }

    /// Write `BENCH_phase.json` under `out_dir`; returns the path.
    pub fn write_bench(&self, out_dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(out_dir)?;
        let path = format!("{out_dir}/BENCH_phase.json");
        std::fs::write(&path, json::to_string(&self.bench_json()) + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Event;

    fn span(phase: &'static str, round: u64, wall_ns: u64, sim_dt: f64) -> Json {
        Event::Span {
            phase,
            round,
            wall_ns,
            sim_dt,
            sim_now: round as f64,
        }
        .to_json()
    }

    fn sample(name: &'static str, value: f64) -> Json {
        Event::Sample {
            name,
            round: 0,
            value,
        }
        .to_json()
    }

    fn counter(name: &'static str, value: f64) -> Json {
        Event::Counter {
            name,
            round: 0,
            value,
            sim_now: 0.0,
        }
        .to_json()
    }

    #[test]
    fn aggregates_spans_counters_samples() {
        let events = vec![
            Event::Meta {
                fields: vec![("algorithm", Json::Str("quafl".into()))],
            }
            .to_json(),
            span("select", 0, 100, 0.0),
            span("select", 1, 300, 0.0),
            span("local_sgd", 0, 5000, 0.5),
            counter("bits_up", 128.0),
            counter("bits_up", 512.0),
            sample("delay", 1.0),
            sample("delay", 3.0),
            sample("delay", 2.0),
        ];
        let r = aggregate(&events);
        assert_eq!(r.events, events.len());
        assert_eq!(r.meta.len(), 1);
        let sel = &r.spans["select"];
        assert_eq!(sel.count, 2);
        assert_eq!(sel.wall_ns_total, 400.0);
        assert_eq!(sel.wall_ns_max, 300.0);
        assert_eq!(r.spans["local_sgd"].sim_dt_total, 0.5);
        let bits = &r.counters["bits_up"];
        assert_eq!(bits.count, 2);
        assert_eq!(bits.last, 512.0);
        assert_eq!(bits.max, 512.0);
        assert_eq!(r.samples["delay"], vec![1.0, 3.0, 2.0]);
        assert_eq!(r.unknown, 0);
    }

    #[test]
    fn unknown_kinds_are_counted_not_fatal() {
        let mut o = std::collections::BTreeMap::new();
        o.insert("kind".to_string(), Json::Str("future_kind".into()));
        let r = aggregate(&[Json::Obj(o), Json::Num(3.0)]);
        assert_eq!(r.unknown, 2);
        assert_eq!(r.events, 2);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn histogram_covers_range() {
        let v = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let (lo, hi, counts) = histogram(&v, 8);
        assert_eq!((lo, hi), (0.0, 7.0));
        assert_eq!(counts.iter().sum::<u64>(), 8);
        // Degenerate range: everything lands in bin 0.
        let (_, _, c1) = histogram(&[2.0, 2.0, 2.0], 8);
        assert_eq!(c1[0], 3);
        assert_eq!(c1.iter().sum::<u64>(), 3);
    }

    #[test]
    fn kernel_throughput_line_derived_from_counters_and_spans() {
        // 2e9 flops over 1e9 ns of local_sgd + 1e9 ns of eval = 1 GFLOP/s.
        let events = vec![
            span("local_sgd", 0, 1_000_000_000, 0.0),
            span("eval", 0, 1_000_000_000, 0.0),
            counter("kernel_flops", 2.0e9),
            counter("kernel_bytes", 5.0e8),
        ];
        let r = aggregate(&events);
        let text = r.render();
        assert!(text.contains("1.00 GFLOP/s"), "{text}");
        assert!(text.contains("2.00 GFLOP"), "{text}");
        // No kernel counters -> no derived line.
        let r = aggregate(&[span("local_sgd", 0, 1000, 0.0)]);
        assert!(!r.render().contains("GFLOP/s"));
        // Kernel counters but no engine spans -> no derived line (avoid
        // a divide-by-zero throughput claim).
        let r = aggregate(&[counter("kernel_flops", 1.0e9)]);
        assert!(!r.render().contains("GFLOP/s"));
    }

    #[test]
    fn render_and_bench_json() {
        let events = vec![
            span("round", 0, 2_000_000, 1.5),
            span("select", 0, 1000, 0.0),
            sample("delay", 0.5),
            sample("delay", 1.5),
            counter("cow_materializations", 7.0),
        ];
        let r = aggregate(&events);
        let text = r.render();
        assert!(text.contains("select"), "{text}");
        assert!(text.contains("round"), "{text}");
        assert!(text.contains("delay"), "{text}");
        assert!(text.contains("cow_materializations"), "{text}");
        // select renders before round (canonical phase order).
        assert!(text.find("select").unwrap() < text.find("round").unwrap());

        let doc = r.bench_json();
        assert_eq!(
            doc.get("bench").and_then(|v| v.as_str()),
            Some("phase_breakdown")
        );
        let rows = doc.get("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 4); // 2 spans + 1 sample + 1 counter
        // Canonical JSON round-trips through the in-crate parser.
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back, doc);
        let hist = rows
            .iter()
            .find(|r| r.get("kind").and_then(|k| k.as_str()) == Some("sample"))
            .and_then(|r| r.get("hist"))
            .and_then(|h| h.as_arr())
            .unwrap();
        assert_eq!(hist.len(), HIST_BINS);
    }
}

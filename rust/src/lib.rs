#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # QuAFL — Quantized Asynchronous Federated Learning
//!
//! Rust + JAX + Pallas reproduction of *"Communication-Efficient Federated
//! Learning With Data and Client Heterogeneity"* (Zakerinia, Talaei,
//! Nadiradze, Alistarh — ISTA, 2022).
//!
//! Layer map (see DESIGN.md):
//!
//! - **L3 (this crate)** — the paper's system contribution: the QuAFL
//!   server/client protocol ([`algorithms::quafl`]), its baselines
//!   (FedAvg, FedBuff, sequential SGD), the lattice/QSGD quantizers
//!   ([`quant`]), the discrete-event timing simulation ([`sim`]), dataset
//!   synthesis + heterogeneous partitioning ([`data`]), and the experiment
//!   coordinator + figure harness ([`coordinator`], [`figures`]).
//! - **L3-net** — the simulated transport & client-availability subsystem
//!   ([`net`]): per-client uplink/downlink bandwidth and latency drawn
//!   from constant/lognormal/Pareto mixtures, a [`net::Transport`] that
//!   prices every exchange from the *actual* encoded bit counts, and a
//!   churn/duty-cycle availability process that gates sampling. The
//!   default `Ideal` profile is a bit-exact no-op
//!   (rust/tests/net_parity.rs), so the subsystem opens the
//!   bandwidth-skew/churn scenario axis without touching any existing
//!   trajectory.
//! - **L3-exec** — the parallel client-execution subsystem ([`exec`]):
//!   an [`exec::EnginePool`] holds one engine per worker thread (built by
//!   an [`exec::EngineFactory`]; workers are long-lived threads fed over
//!   channels), and every algorithm's per-round client work flows through
//!   its deterministic fan-out — serial pre-pass (sampling, clocks,
//!   per-client batch draws) → chunked map over [`exec::ClientTask`]s →
//!   reduction in sampled order. Evaluation shards the validation set
//!   across the same pool with an order-preserving fold. The worker count
//!   is `ExperimentConfig::workers` (`--workers`, 0 = all cores) and is
//!   purely a wall-clock knob: trajectories are bit-identical for every
//!   value (rust/tests/parallel_parity.rs).
//! - **L3-select** — the pluggable client-selection subsystem
//!   ([`select`]): a [`select::SelectionPolicy`] trait (plus a FedBuff
//!   admission hook) over a [`select::SelectionView`] of reachability and
//!   the server's [`select::ParticipationTracker`] (participation counts,
//!   last-served time, snapshot staleness, last observed loss). Four
//!   policies ship behind `--select`: `uniform` (default — a bit-exact
//!   wrapper over the pre-subsystem RNG path,
//!   rust/tests/select_parity.rs), `staleness` (oldest-snapshot-first
//!   with a hard `--select-cap`; FedBuff drops over-cap updates),
//!   `fairness` (min-participation quota / round-robin), and `loss-poc`
//!   (power-of-choice over `--select-candidates`, keeping the highest
//!   tracked losses). Participation Gini and max/mean staleness flow into
//!   every CSV; `figures select_churn` compares the policies under churn.
//! - **L3-fleet** — copy-on-write fleet state ([`fleet`]): per-client
//!   models live in a [`fleet::ClientModelStore`] of `Arc<Vec<f32>>`
//!   snapshots. Untouched clients share one base allocation (the init,
//!   or in FedBuff the server snapshot current at their last pull) and a
//!   model is deep-copied only when its client diverges, so resident
//!   client-model memory is O(touched·d) instead of O(n·d) — the change
//!   that unlocks n≥10⁴ sweeps (`figures net_fleet`). Task snapshots are
//!   `Arc` clones and the worker's deep-copy is the single
//!   materialization point; a client-order dense-view iterator keeps the
//!   potential Φ_t fold bit-exact, and the store's high-water mark is
//!   surfaced as `peak_model_bytes` in every CSV
//!   (rust/tests/fleet_parity.rs proves CoW ≡ dense bit for bit).
//! - **L3-scale** — the event-driven round engine that removes the last
//!   O(n) per-round terms: [`net::ClientAvailability`] in event mode
//!   (`--event-driven`, default on) keeps a `BinaryHeap` of next up/down
//!   transitions — touched only when due — and a Fenwick-tree up-set
//!   ([`util::fenwick`]) whose rank-`select` serves reachability and
//!   sampling in O(s log n) without materialising candidate vectors
//!   (uniform draws use the sparse Fisher–Yates
//!   `Rng::sample_distinct_sparse`, bit-identical to the dense one);
//!   [`select::ParticipationTracker`]'s Gini/staleness metrics are
//!   incrementally maintained aggregates with the old full scans retained
//!   as oracles. Together these unlock n=10⁶–10⁷ rounds (`figures
//!   net_fleet` writes the BENCH_fleet.json scaling curve); the legacy
//!   O(n) path is kept and rust/tests/scale_parity.rs proves both modes
//!   bit-identical on every query, policy, and end-to-end trajectory.
//! - **L3-kernel** — the GEMM kernel subsystem under the native engine
//!   ([`engine::kernel`]): a [`engine::MatmulKernel`] trait over the three
//!   dense products every MLP layer needs (forward affine, backward data
//!   gradient, SGD update), with three backends selected by
//!   `--engine-kernel`: `scalar` (the pre-subsystem loops, kept as the
//!   bit-exact oracle), `blocked` (default — cache-blocked 4×8
//!   register-tiled panels, proven **bit-identical** to scalar by
//!   property tests and whole-run trajectory identity,
//!   rust/tests/kernel_parity.rs), and `simd` (`std::simd` + FMA behind
//!   the nightly-only `simd` cargo feature; approximate parity). Engines
//!   report analytic flop/byte counts through a shared
//!   [`engine::KernelStats`] that the trace layer polls as
//!   `kernel_flops`/`kernel_bytes`. Contract and tile layout:
//!   docs/KERNELS.md.
//! - **L3-trace** — the structured tracing & self-profiling layer
//!   ([`trace`]): a zero-overhead-when-off [`trace::Tracer`] handle on
//!   [`coordinator::FlRun`] emits dual-stamped span events (wall-clock ns
//!   + simulated seconds) around every round phase (select, broadcast,
//!   quantize, local SGD, reduce, eval), cumulative counters for the hot
//!   internals (EnginePool busy time, availability event-queue drains,
//!   Fenwick operations, CoW materializations, encoded bits), and
//!   per-interaction delay/staleness samples, to a pluggable
//!   [`trace::TraceSink`] (buffered JSONL file via [`util::json`]; ring
//!   buffer for tests). `--trace out.jsonl` arms it, `quafl trace-report`
//!   aggregates a trace into a per-phase breakdown + `BENCH_phase.json`,
//!   and the leveled [`log!`] macro is the one diagnostics channel
//!   (stderr, mirrored into the sink). Event schema and stability rules:
//!   docs/TRACE_SCHEMA.md; rust/tests/trace_parity.rs proves an armed
//!   sink perturbs no RNG draw or trajectory value.
//! - **L3-telemetry** — the fleet-telemetry & convergence-diagnostics
//!   layer ([`telemetry`]): a typed streaming-metrics registry
//!   ([`telemetry::Telemetry`] — counters, gauges, and fixed-memory
//!   distribution sketches, [`telemetry::sketch::QuantileSketch`] +
//!   mergeable reservoir) riding the trace sink as the `metric` event
//!   kind, plus convergence probes threaded through all four
//!   algorithms: the paper's potential Φ_t and the server–client
//!   discrepancy maintained incrementally from fleet-store write deltas
//!   in O(touched·d)/round ([`telemetry::probe::DivergenceProbe`];
//!   `--track-potential` uses it by default, `--dense-potential` keeps
//!   the O(n·d) folds as the oracle), per-exchange quantization-error
//!   norms from the [`quant::Quantizer`] seam, and selection-bias
//!   statistics (χ² vs. uniform, Gini) from O(1) tracker aggregates.
//!   `quafl health-report` renders the metric stream as a fleet-health
//!   dashboard + `BENCH_health.json`, and `quafl bench-compare` gates
//!   wall-time regressions between canonical BENCH artifacts. Catalog
//!   and error bounds: docs/TELEMETRY.md; rust/tests/telemetry_parity.rs
//!   proves armed telemetry is bit-free and the probes agree with the
//!   dense oracles.
//! - **L3-fault** — the fault-injection & failure-handling subsystem
//!   ([`fault`]): a seeded chaos engine (private RNG tree off the master
//!   seed, one leaf per round/client/decision — worker-count invariant)
//!   injecting client crashes after local SGD (wasted compute priced,
//!   repeat offenders permanently evicted from the availability index),
//!   per-attempt uplink/downlink message loss with bounded
//!   retry + exponential backoff priced through the real
//!   [`net::Transport`], checksum-framed payload corruption
//!   ([`quant::frame_checksum`], detected server-side and treated as a
//!   drop), and seeded straggler slowdowns — behind
//!   `--fault-crash/--fault-drop/--fault-corrupt/--fault-straggle`.
//!   Recovery: a `--round-deadline` closes rounds K-of-s quorum-style
//!   (`--fault-quorum`; QuAFL's natural semantics, generalized to
//!   FedAvg/FedBuff with arrival-reweighting) and degrades gracefully
//!   below quorum instead of hanging. Fault/recovery counters flow into
//!   trace counters, telemetry gauges, `health-report`, and the
//!   `figures chaos` sweep (`BENCH_chaos.json`). `--faults off`
//!   (default) constructs no engine and is a bit-exact no-op
//!   (rust/tests/fault_parity.rs). Contract: docs/FAULTS.md.
//! - **L2/L1 (build-time Python)** — the client model's fwd/bwd/update as
//!   JAX functions over Pallas kernels, AOT-lowered once to
//!   `artifacts/*.hlo.txt`; [`runtime`] loads and [`engine::XlaEngine`]
//!   executes them via PJRT (the offline build stubs the PJRT bindings —
//!   see [`runtime::stub`]). Python is never on the simulation path.
//!
//! The crate is fully self-contained after `make artifacts`.

pub mod algorithms;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod exec;
pub mod fault;
pub mod figures;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod select;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod trace;
pub mod util;

pub use config::ExperimentConfig;

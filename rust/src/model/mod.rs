//! Model specifications and flat parameter vectors.
//!
//! The FL protocol treats the model as a flat `Vec<f32>` of dimension d
//! (that is what gets averaged and quantized); the engines view it as a
//! sequence of (W_i, b_i) layer tensors. `ModelSpec` owns the mapping and
//! must agree with `python/compile/model.py::MODELS` — the runtime
//! cross-checks against `artifacts/meta.json` at load time.

use crate::util::rng::Rng;

/// An MLP architecture: `sizes = [input, hidden..., classes]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub sizes: Vec<usize>,
}

impl ModelSpec {
    pub fn new(name: &str, sizes: Vec<usize>) -> Self {
        assert!(sizes.len() >= 2, "model needs input and output sizes");
        ModelSpec { name: name.to_string(), sizes }
    }

    /// The model zoo — must match python/compile/model.py.
    pub fn by_name(name: &str) -> Result<Self, String> {
        let sizes = match name {
            "mlp" => vec![784, 32, 10],
            "mlp_wide" => vec![784, 256, 10],
            "mlp_deep" => vec![784, 256, 128, 10],
            // 16-dim head for the `tiny` synthetic family: keeps d small
            // enough that million-client fleet benches fit in memory.
            "mlp_tiny" => vec![16, 16, 10],
            other => return Err(format!("unknown model {other:?}")),
        };
        Ok(ModelSpec::new(name, sizes))
    }

    pub fn input_dim(&self) -> usize {
        self.sizes[0]
    }

    pub fn num_classes(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    pub fn num_layers(&self) -> usize {
        self.sizes.len() - 1
    }

    /// Total parameter dimension d.
    pub fn num_params(&self) -> usize {
        (0..self.num_layers())
            .map(|i| self.sizes[i] * self.sizes[i + 1] + self.sizes[i + 1])
            .sum()
    }

    /// Flat-layout segments in AOT argument order: w0, b0, w1, b1, ...
    /// Each entry is (offset, shape) with shape.len() in {1, 2}.
    pub fn segments(&self) -> Vec<(usize, Vec<usize>)> {
        let mut out = Vec::new();
        let mut off = 0;
        for i in 0..self.num_layers() {
            let (fan_in, fan_out) = (self.sizes[i], self.sizes[i + 1]);
            out.push((off, vec![fan_in, fan_out]));
            off += fan_in * fan_out;
            out.push((off, vec![fan_out]));
            off += fan_out;
        }
        out
    }

    /// He-uniform init over the flat vector (bound sqrt(6/fan_in) for
    /// weights, zero biases) — same family as the python-side init.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut p = vec![0f32; self.num_params()];
        for i in 0..self.num_layers() {
            let (off, shape) = self.segments()[2 * i].clone();
            let fan_in = shape[0];
            let bound = (6.0 / fan_in as f64).sqrt();
            for v in &mut p[off..off + shape.iter().product::<usize>()] {
                *v = rng.uniform(-bound, bound) as f32;
            }
            // biases stay zero
        }
        p
    }
}

/// Flat parameter vector with elementwise helpers used by the averaging
/// steps of the algorithms. Kept free-function style to work on plain
/// slices (the hot loop avoids allocation by mutating in place).
pub mod params {
    /// y += alpha * x
    pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        assert_eq!(y.len(), x.len());
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// y = alpha * y
    pub fn scale(y: &mut [f32], alpha: f32) {
        for yi in y.iter_mut() {
            *yi *= alpha;
        }
    }

    /// out = sum_i w_i * x_i (convex combination if weights sum to 1)
    pub fn weighted_sum(terms: &[(&[f32], f32)]) -> Vec<f32> {
        assert!(!terms.is_empty());
        let n = terms[0].0.len();
        let mut out = vec![0f32; n];
        for (x, w) in terms {
            assert_eq!(x.len(), n);
            for (o, &xi) in out.iter_mut().zip(x.iter()) {
                *o += w * xi;
            }
        }
        out
    }

    /// y = x - s (elementwise), returning new vector.
    pub fn sub(x: &[f32], s: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), s.len());
        x.iter().zip(s).map(|(&a, &b)| a - b).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_matches_python_dims() {
        // num_params values asserted against python (compile.model.num_params).
        assert_eq!(ModelSpec::by_name("mlp").unwrap().num_params(), 25_450);
        assert_eq!(ModelSpec::by_name("mlp_wide").unwrap().num_params(), 203_530);
        assert_eq!(ModelSpec::by_name("mlp_deep").unwrap().num_params(), 235_146);
        // 16*16 + 16 + 16*10 + 10
        assert_eq!(ModelSpec::by_name("mlp_tiny").unwrap().num_params(), 442);
        assert!(ModelSpec::by_name("nope").is_err());
    }

    #[test]
    fn segments_cover_flat_vector_exactly() {
        let m = ModelSpec::by_name("mlp_deep").unwrap();
        let segs = m.segments();
        let mut expected_off = 0;
        for (off, shape) in &segs {
            assert_eq!(*off, expected_off);
            expected_off += shape.iter().product::<usize>();
        }
        assert_eq!(expected_off, m.num_params());
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let m = ModelSpec::by_name("mlp").unwrap();
        let a = m.init_params(42);
        let b = m.init_params(42);
        assert_eq!(a, b);
        let bound = (6.0f32 / 784.0).sqrt();
        // First segment is w0 with fan_in 784.
        assert!(a[..784 * 32].iter().all(|&v| v.abs() <= bound));
        // b0 is zero.
        let (b0_off, _) = m.segments()[1].clone();
        assert!(a[b0_off..b0_off + 32].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn init_differs_across_seeds() {
        let m = ModelSpec::by_name("mlp").unwrap();
        assert_ne!(m.init_params(1), m.init_params(2));
    }

    #[test]
    fn params_helpers() {
        use params::*;
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, 2.0, &[1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.0]);
        let w = weighted_sum(&[(&[2.0, 0.0], 0.5), (&[0.0, 4.0], 0.25)]);
        assert_eq!(w, vec![1.0, 1.0]);
        assert_eq!(sub(&[3.0, 3.0], &[1.0, 2.0]), vec![2.0, 1.0]);
    }
}

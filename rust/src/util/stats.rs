//! Small statistics helpers used by metrics and the bench harness.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile over a copy of the samples (p in [0, 100]).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0 * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// L2 norm of an f32 slice, accumulated in f64.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// L2 distance between two equal-length slices.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// erf via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|error| < 1.5e-7 — plenty for copula ranks).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
        - 0.284496736)
        * t
        + 0.254829592;
    sign * (1.0 - poly * t * (-x * x).exp())
}

/// Standard normal CDF Φ(x).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal quantile Φ⁻¹(p) for p ∈ (0, 1) — Acklam's rational
/// approximation (|relative error| < 1.2e-9 over the whole range). Used
/// by the Gaussian-copula link draws ([`crate::net`]) and the
/// inverse-CDF [`crate::net::Dist::quantile`].
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "normal_quantile: p={p} outside (0,1)");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.variance() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((l2_dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }

    #[test]
    fn normal_cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!(normal_cdf(8.0) > 0.999999);
        assert!(normal_cdf(-8.0) < 1e-6);
        // Symmetry: Φ(x) + Φ(−x) = 1.
        for x in [0.1, 0.7, 1.3, 2.9] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-7);
        }
    }

    #[test]
    fn normal_quantile_inverts_cdf() {
        assert!(normal_quantile(0.5).abs() < 1e-8);
        assert!((normal_quantile(0.975) - 1.9599).abs() < 1e-3);
        // Round trip over the whole range, including both tail branches.
        for &p in &[1e-6, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0 - 1e-6] {
            let x = normal_quantile(p);
            assert!((normal_cdf(x) - p).abs() < 1e-5, "p={p} x={x}");
        }
        // Monotone and antisymmetric.
        assert!(normal_quantile(0.2) < normal_quantile(0.8));
        assert!(
            (normal_quantile(0.2) + normal_quantile(0.8)).abs() < 1e-8
        );
    }

    #[test]
    #[should_panic(expected = "outside (0,1)")]
    fn normal_quantile_rejects_boundary() {
        normal_quantile(0.0);
    }
}

//! Small statistics helpers used by metrics and the bench harness.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile over a copy of the samples (p in [0, 100]).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0 * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// L2 norm of an f32 slice, accumulated in f64.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// L2 distance between two equal-length slices.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.variance() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((l2_dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }
}

//! Deterministic RNG: splitmix64 seeding + xoshiro256++ core, plus the
//! distributions the simulation needs (uniform, normal, exponential,
//! Poisson-ish step processes are built on exponential in sim/).
//!
//! Every stochastic component of the system (data synthesis, client
//! sampling, step timing, quantizer randomness) takes an explicit seed, so
//! whole experiments are bit-reproducible — a requirement for the
//! engine-parity tests (XLA vs native) and for figure regeneration.

/// splitmix64 — used to expand a u64 seed into xoshiro state and to derive
/// independent stream seeds (e.g. per-client, per-round).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive a fresh independent seed from (seed, stream) — cheap “key
/// splitting” for per-client / per-round RNGs.
pub fn derive_seed(seed: u64, stream: u64) -> u64 {
    let mut s = seed ^ stream.wrapping_mul(0xA0761D6478BD642F);
    let a = splitmix64(&mut s);
    let b = splitmix64(&mut s);
    a ^ b.rotate_left(23)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Independent child stream (for per-client RNGs etc.).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(derive_seed(self.next_u64(), stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda (mean 1/lambda). Used for per-step
    /// client compute times (paper Appendix A.2: X ~ exp(λ), λ = 1/2 fast,
    /// 1/8 slow).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample k distinct indices from [0, n) uniformly (partial
    /// Fisher–Yates over an index map); order is random. k <= n.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        // For small k relative to n use a hash-free swap map.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Exactly [`Rng::sample_distinct`] — same `gen_range` call sequence,
    /// same result vector, same residual stream — but O(k) time and
    /// memory instead of O(n): the dense `(0..n)` index array is replaced
    /// by a sparse overlay recording only displaced entries. The two are
    /// interchangeable bit for bit (rust/tests/scale_parity.rs); this one
    /// makes million-client uniform draws affordable.
    pub fn sample_distinct_sparse(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct_sparse: k={k} > n={n}");
        let mut moved: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(2 * k);
        let at = |moved: &std::collections::HashMap<usize, usize>, p: usize| {
            moved.get(&p).copied().unwrap_or(p)
        };
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            // idx.swap(i, j) on the virtual identity array.
            let vi = at(&moved, i);
            let vj = at(&moved, j);
            moved.insert(i, vj);
            moved.insert(j, vi);
            // Position i is final after the swap.
            out.push(vj);
        }
        out
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample from a Dirichlet(alpha, ..., alpha) over `k` categories via
    /// Gamma(alpha, 1) draws (Marsaglia–Tsang; alpha < 1 handled by boost).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            // Degenerate; fall back to uniform.
            return vec![1.0 / k as f64; k];
        }
        for x in g.iter_mut() {
            *x /= s;
        }
        g
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.next_f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.gen_range(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let lambda = 0.5; // mean 2
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let s = r.sample_distinct(30, 10);
            assert_eq!(s.len(), 10);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10);
            assert!(s.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn sample_distinct_uniform_marginals() {
        // Each index should appear with probability k/n.
        let mut r = Rng::new(19);
        let (n, k, trials) = (20, 5, 40_000);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in r.sample_distinct(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials * k / n;
        for c in counts {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.06,
                "c={c} expect={expect}"
            );
        }
    }

    #[test]
    fn sparse_sampling_is_bitwise_identical_to_dense() {
        for seed in [1u64, 7, 42, 1234] {
            for &(n, k) in &[(1usize, 1usize), (10, 3), (50, 50), (1000, 17)] {
                let mut dense = Rng::new(seed);
                let mut sparse = Rng::new(seed);
                assert_eq!(
                    dense.sample_distinct(n, k),
                    sparse.sample_distinct_sparse(n, k),
                    "seed={seed} n={n} k={k}"
                );
                // Residual streams agree: same randomness consumed.
                assert_eq!(dense.next_u64(), sparse.next_u64());
            }
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(23);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 8);
            assert_eq!(p.len(), 8);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_seed_varies_with_stream() {
        let s0 = derive_seed(99, 0);
        let s1 = derive_seed(99, 1);
        assert_ne!(s0, s1);
    }
}

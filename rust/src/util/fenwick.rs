//! Fenwick (binary-indexed) tree over non-negative integer weights, with
//! O(log n) point update, prefix sum, and rank-select.
//!
//! Two roles in the event-driven fleet path ([`crate::net::availability`]):
//!
//! - as a **dynamic bitset with order statistics** (all weights 0/1):
//!   `select(j)` returns the id of the j-th reachable client in ascending
//!   order — exactly `up[j]` of the legacy materialized candidate vector,
//!   without ever building it;
//! - as a **weighted sampler**: draw `k = rng.gen_range(total)` and map it
//!   through `select(k)` — each index lands with probability
//!   `weight/total`, updating in O(log n) when weights change.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::Rng;

/// Fenwick tree over `n` slots of non-negative i64 weights.
#[derive(Debug)]
pub struct Fenwick {
    /// 1-indexed partial sums (classic BIT layout); tree[0] unused
    tree: Vec<i64>,
    n: usize,
    total: i64,
    /// passive observability counter: add/prefix/select calls since
    /// construction ([`crate::trace`] polls it at round boundaries).
    /// Atomic only for interior mutability through `&self` queries —
    /// no RNG, no float, no behavioral effect.
    ops: AtomicU64,
}

impl Clone for Fenwick {
    fn clone(&self) -> Self {
        Fenwick {
            tree: self.tree.clone(),
            n: self.n,
            total: self.total,
            ops: AtomicU64::new(self.ops.load(Ordering::Relaxed)),
        }
    }
}

impl Fenwick {
    /// All-zero tree over `n` slots.
    pub fn new(n: usize) -> Self {
        Fenwick { tree: vec![0; n + 1], n, total: 0, ops: AtomicU64::new(0) }
    }

    /// Build from per-slot values in O(n): each leaf's partial sum is
    /// folded into exactly one parent node.
    pub fn from_values(values: &[i64]) -> Self {
        let n = values.len();
        let mut tree = vec![0i64; n + 1];
        let mut total = 0i64;
        for (i, &v) in values.iter().enumerate() {
            debug_assert!(v >= 0, "fenwick weights must be non-negative");
            total += v;
            tree[i + 1] += v;
        }
        for idx in 1..=n {
            let parent = idx + (idx & idx.wrapping_neg());
            if parent <= n {
                tree[parent] += tree[idx];
            }
        }
        Fenwick { tree, n, total, ops: AtomicU64::new(0) }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Total add/prefix/select calls served since construction (passive
    /// trace counter; `get` counts as two prefixes, `sample` as one
    /// select).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    #[inline]
    fn count_op(&self) {
        self.ops.fetch_add(1, Ordering::Relaxed);
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Sum of all weights.
    pub fn total(&self) -> i64 {
        self.total
    }

    /// Add `delta` to slot `i` (the result must stay non-negative).
    pub fn add(&mut self, i: usize, delta: i64) {
        debug_assert!(i < self.n, "fenwick add out of range: {i} >= {}", self.n);
        self.count_op();
        if delta == 0 {
            return;
        }
        self.total += delta;
        debug_assert!(self.total >= 0, "fenwick total went negative");
        let mut idx = i + 1;
        while idx <= self.n {
            self.tree[idx] += delta;
            idx += idx & idx.wrapping_neg();
        }
    }

    /// Sum of weights over `[0, i)`.
    pub fn prefix(&self, i: usize) -> i64 {
        debug_assert!(i <= self.n, "fenwick prefix out of range");
        self.count_op();
        let mut s = 0i64;
        let mut idx = i;
        while idx > 0 {
            s += self.tree[idx];
            idx -= idx & idx.wrapping_neg();
        }
        s
    }

    /// Weight at slot `i`.
    pub fn get(&self, i: usize) -> i64 {
        self.prefix(i + 1) - self.prefix(i)
    }

    /// Smallest index `i` with `prefix(i + 1) > k` — for 0/1 weights, the
    /// id of the (k+1)-th set slot in ascending order. Requires
    /// `0 <= k < total()`. O(log n) binary lifting.
    pub fn select(&self, k: i64) -> usize {
        debug_assert!(
            k >= 0 && k < self.total,
            "fenwick select rank {k} outside [0, {})",
            self.total
        );
        self.count_op();
        let mut remaining = k;
        let mut pos = 0usize; // 1-indexed cursor, currently before slot 1
        let mut step = self.n.next_power_of_two();
        while step > 0 {
            let next = pos + step;
            if next <= self.n && self.tree[next] <= remaining {
                remaining -= self.tree[next];
                pos = next;
            }
            step >>= 1;
        }
        // pos is the count of slots whose cumulative weight is <= k.
        pos
    }

    /// Weighted draw: index `i` with probability `get(i) / total()`.
    /// Consumes exactly one `gen_range(total)` call. Panics if total is 0.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        assert!(self.total > 0, "cannot sample from an empty fenwick");
        let k = rng.gen_range(self.total as usize) as i64;
        self.select(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive mirror: plain weight vector with O(n) queries.
    struct Naive {
        w: Vec<i64>,
    }

    impl Naive {
        fn prefix(&self, i: usize) -> i64 {
            self.w[..i].iter().sum()
        }

        fn select(&self, k: i64) -> usize {
            let mut acc = 0i64;
            for (i, &v) in self.w.iter().enumerate() {
                acc += v;
                if acc > k {
                    return i;
                }
            }
            panic!("rank {k} out of range");
        }
    }

    #[test]
    fn prefix_sums_match_naive_after_random_updates() {
        for seed in [3u64, 11, 29] {
            let mut rng = Rng::new(seed);
            let n = 64;
            let mut f = Fenwick::new(n);
            let mut naive = Naive { w: vec![0; n] };
            for _ in 0..500 {
                let i = rng.gen_range(n);
                // Insert, remove, or bump — never below zero.
                let delta = match rng.gen_range(3) {
                    0 => 1,
                    1 => -(naive.w[i].min(1)),
                    _ => rng.gen_range(5) as i64,
                };
                f.add(i, delta);
                naive.w[i] += delta;
                let q = rng.gen_range(n + 1);
                assert_eq!(f.prefix(q), naive.prefix(q), "prefix({q})");
                assert_eq!(f.total(), naive.prefix(n));
                assert_eq!(f.get(i), naive.w[i]);
            }
        }
    }

    #[test]
    fn select_matches_naive_scan_on_every_rank() {
        let mut rng = Rng::new(17);
        let n = 40;
        let mut f = Fenwick::new(n);
        let mut naive = Naive { w: vec![0; n] };
        for round in 0..50 {
            let i = rng.gen_range(n);
            let delta = if naive.w[i] > 0 && rng.gen_range(4) == 0 {
                -naive.w[i]
            } else {
                1 + rng.gen_range(3) as i64
            };
            f.add(i, delta);
            naive.w[i] += delta;
            for k in 0..f.total() {
                assert_eq!(f.select(k), naive.select(k), "round {round} rank {k}");
            }
        }
    }

    #[test]
    fn select_inverts_prefix_for_unit_weights() {
        // 0/1 weights: select(j) is the j-th set bit — the order-statistic
        // role the availability index relies on.
        let bits = [1i64, 0, 0, 1, 1, 0, 1, 0, 0, 1];
        let f = Fenwick::from_values(&bits);
        let set: Vec<usize> = bits
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == 1)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(f.total() as usize, set.len());
        for (j, &id) in set.iter().enumerate() {
            assert_eq!(f.select(j as i64), id, "rank {j}");
        }
    }

    #[test]
    fn from_values_equals_incremental_build() {
        let vals = [3i64, 0, 7, 1, 0, 0, 2, 5];
        let built = Fenwick::from_values(&vals);
        let mut inc = Fenwick::new(vals.len());
        for (i, &v) in vals.iter().enumerate() {
            inc.add(i, v);
        }
        for i in 0..=vals.len() {
            assert_eq!(built.prefix(i), inc.prefix(i));
        }
        assert_eq!(built.total(), inc.total());
    }

    #[test]
    fn sampled_distribution_matches_naive_weighted_rejection() {
        // Satellite requirement: 10⁵ draws at fixed seeds, fenwick-sampled
        // frequencies must match a naive weighted rejection sampler (same
        // target distribution, independent streams).
        let weights = [5i64, 0, 1, 10, 4, 0, 20, 8];
        let n = weights.len();
        let total: i64 = weights.iter().sum();
        let max_w = *weights.iter().max().unwrap();
        let f = Fenwick::from_values(&weights);
        let draws = 100_000usize;

        let mut fen_counts = vec![0usize; n];
        let mut rng = Rng::new(2024);
        for _ in 0..draws {
            fen_counts[f.sample(&mut rng)] += 1;
        }

        let mut rej_counts = vec![0usize; n];
        let mut rej_rng = Rng::new(4048);
        for _ in 0..draws {
            loop {
                let i = rej_rng.gen_range(n);
                if (rej_rng.gen_range(max_w as usize) as i64) < weights[i] {
                    rej_counts[i] += 1;
                    break;
                }
            }
        }

        for i in 0..n {
            let expect = draws as f64 * weights[i] as f64 / total as f64;
            let fen = fen_counts[i] as f64;
            let rej = rej_counts[i] as f64;
            // Zero-weight slots must never be drawn by either sampler.
            if weights[i] == 0 {
                assert_eq!(fen_counts[i], 0, "slot {i}");
                assert_eq!(rej_counts[i], 0, "slot {i}");
                continue;
            }
            let tol = (expect * 5.0).sqrt().max(50.0); // ~5 sigma
            assert!((fen - expect).abs() < tol, "slot {i}: fen {fen} vs {expect}");
            assert!((rej - expect).abs() < tol, "slot {i}: rej {rej} vs {expect}");
            assert!((fen - rej).abs() < 2.0 * tol, "slot {i}: fen {fen} vs rej {rej}");
        }
    }

    #[test]
    fn ops_counter_counts_calls_and_survives_clone() {
        let mut f = Fenwick::new(8);
        assert_eq!(f.ops(), 0);
        f.add(2, 1); // 1 op
        f.add(3, 0); // counted even when delta == 0
        let _ = f.prefix(4); // 1 op
        let _ = f.get(2); // 2 prefixes
        let _ = f.select(0); // 1 op
        assert_eq!(f.ops(), 6);
        let g = f.clone();
        assert_eq!(g.ops(), 6);
    }

    #[test]
    #[should_panic(expected = "empty fenwick")]
    fn sampling_empty_tree_panics() {
        let f = Fenwick::new(4);
        let mut rng = Rng::new(1);
        f.sample(&mut rng);
    }
}

//! Minimal JSON parser/writer (no serde in the offline build). The parser
//! covers the full JSON grammar we emit from `aot.py` (objects, arrays,
//! strings with escapes, numbers, bools, null) and is used by the runtime
//! to read `artifacts/meta.json`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(s: &str) -> Result<Json, ParseError> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first >> 5 == 0b110 {
        2
    } else if first >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

/// Tiny JSON writer used for result manifests.
pub fn write(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => out.push_str(&crate::util::csv::fmt_f64(*n)),
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(&Json::Str(k.clone()), out);
                out.push(':');
                write(x, out);
            }
            out.push('}');
        }
    }
}

pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write(v, &mut s);
    s
}

/// Parse newline-delimited JSON (JSONL): one document per non-empty
/// line. Used for trace streams ([`crate::trace`]); errors carry the
/// 1-based line number so a corrupt trace points at the bad record.
pub fn parse_lines(s: &str) -> Result<Vec<Json>, ParseError> {
    let mut out = Vec::new();
    for (i, line) in s.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match parse(line) {
            Ok(v) => out.push(v),
            Err(e) => {
                return Err(ParseError {
                    msg: format!("line {}: {}", i + 1, e.msg),
                    pos: e.pos,
                })
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_document() {
        let doc = r#"{
          "train_batch": 32,
          "models": {
            "mlp": {"sizes": [784, 32, 10], "num_params": 25450,
                    "train_step": "mlp_train_step.hlo.txt"}
          }
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("train_batch").unwrap().as_usize(), Some(32));
        let mlp = j.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(
            mlp.get("sizes").unwrap().idx(0).unwrap().as_usize(),
            Some(784)
        );
        assert_eq!(
            mlp.get("train_step").unwrap().as_str(),
            Some("mlp_train_step.hlo.txt")
        );
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_write_parse() {
        let mut obj = BTreeMap::new();
        obj.insert("a".into(), Json::Arr(vec![Json::Num(1.0), Json::Null]));
        obj.insert("s".into(), Json::Str("x\"y".into()));
        let v = Json::Obj(obj);
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_lines_jsonl() {
        let text = "{\"a\":1}\n\n{\"b\":2}\n";
        let docs = parse_lines(text).unwrap();
        assert_eq!(docs.len(), 2);
        assert_eq!(docs[1].get("b").unwrap().as_f64(), Some(2.0));
        let err = parse_lines("{\"a\":1}\nnot json\n").unwrap_err();
        assert!(err.msg.contains("line 2"), "{}", err.msg);
    }
}

//! Dependency-free utilities: RNG + distributions, fast Walsh–Hadamard
//! transform, bit packing, Fenwick-tree order statistics, CSV/JSON
//! writers, CLI parsing, stats.
//!
//! No `rand`/`serde`/`clap` — this environment builds offline with only
//! the `xla` and `anyhow` crates, so these substrates are implemented here
//! and unit-tested in place.

pub mod bits;
pub mod cli;
pub mod csv;
pub mod fenwick;
pub mod hadamard;
pub mod json;
pub mod rng;
pub mod stats;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Next power of two >= x (x >= 1).
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }
}

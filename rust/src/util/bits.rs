//! Bit-packing for quantized payloads: write/read fixed-width b-bit
//! unsigned residues into a byte buffer (b in 1..=32). The quantizers
//! count *exact* payload bits through these writers, which feeds the
//! communication-cost accounting in the figures (paper Lemma 3.8 tracks
//! bits per interaction).
//!
//! Perf note (EXPERIMENTS.md §Perf): both sides use a 64-bit shift
//! accumulator — one branch-light path per value instead of per-bit-chunk
//! byte surgery. This moved the lattice encode/decode hot loop from
//! ~145 MB/s to >300 MB/s on the reference core.

/// Append-only bit writer (LSB-first within the stream).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// pending bits not yet flushed to `buf` (low `acc_bits` bits valid)
    acc: u64,
    acc_bits: u32,
    /// total bits written
    len_bits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        BitWriter {
            buf: Vec::with_capacity(bits.div_ceil(8) + 8),
            ..Default::default()
        }
    }

    /// Write the low `width` bits of `v`.
    #[inline]
    pub fn write(&mut self, v: u32, width: u8) {
        debug_assert!(width >= 1 && width <= 32);
        debug_assert!(width == 32 || v < (1u32 << width));
        self.acc |= (v as u64) << self.acc_bits;
        self.acc_bits += width as u32;
        self.len_bits += width as usize;
        while self.acc_bits >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.acc_bits -= 8;
        }
    }

    /// Write a full f32 (32 bits) — used for quantizer side-info (norms).
    pub fn write_f32(&mut self, v: f32) {
        self.write(v.to_bits(), 32);
    }

    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    /// Finalize: flush the partial tail byte and return (bytes, bit count).
    pub fn into_bytes(mut self) -> (Vec<u8>, usize) {
        if self.acc_bits > 0 {
            self.buf.push(self.acc as u8);
        }
        (self.buf, self.len_bits)
    }
}

/// Sequential bit reader over a packed buffer.
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte_pos: usize,
    acc: u64,
    acc_bits: u32,
    pos_bits: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, byte_pos: 0, acc: 0, acc_bits: 0, pos_bits: 0 }
    }

    /// Read `width` bits (LSB-first). Panics on overrun (programming error).
    #[inline]
    pub fn read(&mut self, width: u8) -> u32 {
        debug_assert!(width >= 1 && width <= 32);
        let w = width as u32;
        while self.acc_bits < w {
            assert!(self.byte_pos < self.buf.len(), "BitReader overrun");
            self.acc |= (self.buf[self.byte_pos] as u64) << self.acc_bits;
            self.byte_pos += 1;
            self.acc_bits += 8;
        }
        let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
        let out = (self.acc & mask) as u32;
        self.acc >>= w;
        self.acc_bits -= w;
        self.pos_bits += width as usize;
        out
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32))
    }

    pub fn pos_bits(&self) -> usize {
        self.pos_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_uniform_widths() {
        for width in 1..=32u8 {
            let mut r = Rng::new(width as u64);
            let vals: Vec<u32> = (0..257)
                .map(|_| {
                    if width == 32 {
                        r.next_u32()
                    } else {
                        r.next_u32() & ((1u32 << width) - 1)
                    }
                })
                .collect();
            let mut w = BitWriter::new();
            for &v in &vals {
                w.write(v, width);
            }
            assert_eq!(w.len_bits(), vals.len() * width as usize);
            let (bytes, _) = w.into_bytes();
            let mut rd = BitReader::new(&bytes);
            for &v in &vals {
                assert_eq!(rd.read(width), v, "width={width}");
            }
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        let script: Vec<(u32, u8)> = vec![
            (1, 1),
            (0, 1),
            (5, 3),
            (1023, 10),
            (0xDEADBEEF, 32),
            (7, 4),
            (0x7FFF, 15),
        ];
        let mut w = BitWriter::new();
        for &(v, b) in &script {
            w.write(v, b);
        }
        let (bytes, nbits) = w.into_bytes();
        assert_eq!(nbits, script.iter().map(|&(_, b)| b as usize).sum::<usize>());
        let mut r = BitReader::new(&bytes);
        for &(v, b) in &script {
            assert_eq!(r.read(b), v);
        }
    }

    #[test]
    fn f32_roundtrip() {
        let vals = [0.0f32, -1.5, f32::MAX, f32::MIN_POSITIVE, 3.25e-7];
        let mut w = BitWriter::new();
        w.write(5, 3); // unaligned prefix
        for &v in &vals {
            w.write_f32(v);
        }
        let (bytes, _) = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 5);
        for &v in &vals {
            assert_eq!(r.read_f32().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn byte_length_is_minimal() {
        let mut w = BitWriter::new();
        for _ in 0..9 {
            w.write(1, 1);
        }
        let (bytes, nbits) = w.into_bytes();
        assert_eq!(nbits, 9);
        assert_eq!(bytes.len(), 2); // 9 bits -> 2 bytes
    }

    #[test]
    fn pos_bits_tracks_reads() {
        let mut w = BitWriter::new();
        w.write(3, 2);
        w.write(1, 7);
        let (bytes, _) = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        r.read(2);
        assert_eq!(r.pos_bits(), 2);
        r.read(7);
        assert_eq!(r.pos_bits(), 9);
    }

    #[test]
    #[should_panic]
    fn overrun_panics() {
        let bytes = [0u8; 1];
        let mut r = BitReader::new(&bytes);
        r.read(32);
    }
}

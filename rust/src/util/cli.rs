//! Tiny CLI argument parser (no clap in the offline build).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value] ...`
//! Unknown keys are an error (surfaced with the set of known keys), which
//! keeps experiment definitions honest.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub kv: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

pub fn parse(argv: &[String]) -> Args {
    parse_with_bool_flags(argv, &[])
}

/// Like [`parse`], but the named keys never consume a value: with
/// `bool_flags = ["smoke"]`, `--smoke fig2` keeps `fig2` positional
/// instead of swallowing it as the flag's value. (The generic grammar
/// cannot tell a boolean flag from a key expecting a value, so commands
/// with trailing positionals declare their booleans explicitly.)
pub fn parse_with_bool_flags(argv: &[String], bool_flags: &[&str]) -> Args {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    // First non-flag token is the subcommand.
    if let Some(first) = it.peek() {
        if !first.starts_with('-') {
            args.subcommand = Some(it.next().unwrap().clone());
        }
    }
    while let Some(tok) = it.next() {
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some(eq) = stripped.find('=') {
                args.kv.insert(
                    stripped[..eq].to_string(),
                    stripped[eq + 1..].to_string(),
                );
            } else if bool_flags.contains(&stripped) {
                // A declared boolean still accepts an explicit value
                // (`--weighted false`); anything else stays positional.
                if it
                    .peek()
                    .map(|n| n.as_str() == "true" || n.as_str() == "false")
                    .unwrap_or(false)
                {
                    args.kv.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if it
                .peek()
                .map(|n| !n.starts_with("--"))
                .unwrap_or(false)
            {
                args.kv.insert(stripped.to_string(), it.next().unwrap().clone());
            } else {
                args.flags.push(stripped.to_string());
            }
        } else {
            args.positional.push(tok.clone());
        }
    }
    args
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Boolean option: the bare `--name` flag form, or an explicit
    /// `--name=true|false` / `--name true|false` value. The single home
    /// of the flag-or-"true" idiom — subcommands must not re-implement it.
    pub fn bool(&self, name: &str) -> bool {
        self.flag(name) || self.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Error out on keys/flags outside the allowed set (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k}; known options: {}",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        let a = parse(&sv(&[
            "figures", "--out-dir", "results", "--paper-scale",
            "--n=40", "fig1",
        ]));
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("out-dir"), Some("results"));
        assert!(a.flag("paper-scale"));
        assert_eq!(a.get_usize("n", 0), 40);
        assert_eq!(a.positional, vec!["fig1".to_string()]);
    }

    #[test]
    fn typed_getters_defaults() {
        let a = parse(&sv(&["run"]));
        assert_eq!(a.get_usize("rounds", 100), 100);
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
        assert_eq!(a.get_str("model", "mlp"), "mlp");
    }

    #[test]
    fn check_known_catches_typo() {
        let a = parse(&sv(&["run", "--roundz", "5"]));
        assert!(a.check_known(&["rounds"]).is_err());
        assert!(a.check_known(&["roundz"]).is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&sv(&["run", "--lr", "-0.5"]));
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }

    #[test]
    fn declared_bool_flags_do_not_swallow_positionals() {
        let a = parse_with_bool_flags(
            &sv(&["figures", "--smoke", "fig2", "fig1", "--out-dir", "x"]),
            &["smoke", "paper-scale"],
        );
        assert!(a.bool("smoke"));
        assert!(!a.bool("paper-scale"));
        assert_eq!(a.positional, vec!["fig2".to_string(), "fig1".to_string()]);
        assert_eq!(a.get("out-dir"), Some("x"));
        // Without the declaration the old behavior stands.
        let b = parse(&sv(&["figures", "--smoke", "fig2"]));
        assert_eq!(b.get("smoke"), Some("fig2"));
    }

    #[test]
    fn declared_bool_flags_keep_explicit_values() {
        // `--weighted false` must stay an explicit negative, not flip to
        // an asserted flag with a stray positional.
        let a = parse_with_bool_flags(
            &sv(&["run", "--weighted", "false", "--xla", "true", "--smoke"]),
            &["weighted", "xla", "smoke"],
        );
        assert!(!a.bool("weighted"));
        assert!(a.bool("xla"));
        assert!(a.bool("smoke"));
        assert!(a.positional.is_empty());
        let b = parse_with_bool_flags(&sv(&["run", "--smoke=true"]), &["smoke"]);
        assert!(b.bool("smoke"));
    }
}

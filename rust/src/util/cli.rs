//! Tiny CLI argument parser (no clap in the offline build).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value] ...`
//! Unknown keys are an error (surfaced with the set of known keys), which
//! keeps experiment definitions honest.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub kv: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

pub fn parse(argv: &[String]) -> Args {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    // First non-flag token is the subcommand.
    if let Some(first) = it.peek() {
        if !first.starts_with('-') {
            args.subcommand = Some(it.next().unwrap().clone());
        }
    }
    while let Some(tok) = it.next() {
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some(eq) = stripped.find('=') {
                args.kv.insert(
                    stripped[..eq].to_string(),
                    stripped[eq + 1..].to_string(),
                );
            } else if it
                .peek()
                .map(|n| !n.starts_with("--"))
                .unwrap_or(false)
            {
                args.kv.insert(stripped.to_string(), it.next().unwrap().clone());
            } else {
                args.flags.push(stripped.to_string());
            }
        } else {
            args.positional.push(tok.clone());
        }
    }
    args
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Error out on keys/flags outside the allowed set (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k}; known options: {}",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        let a = parse(&sv(&[
            "figures", "--out-dir", "results", "--paper-scale",
            "--n=40", "fig1",
        ]));
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("out-dir"), Some("results"));
        assert!(a.flag("paper-scale"));
        assert_eq!(a.get_usize("n", 0), 40);
        assert_eq!(a.positional, vec!["fig1".to_string()]);
    }

    #[test]
    fn typed_getters_defaults() {
        let a = parse(&sv(&["run"]));
        assert_eq!(a.get_usize("rounds", 100), 100);
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
        assert_eq!(a.get_str("model", "mlp"), "mlp");
    }

    #[test]
    fn check_known_catches_typo() {
        let a = parse(&sv(&["run", "--roundz", "5"]));
        assert!(a.check_known(&["rounds"]).is_err());
        assert!(a.check_known(&["roundz"]).is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&sv(&["run", "--lr", "-0.5"]));
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }
}

//! Minimal CSV writer for figure/metric series. Columns are fixed at
//! construction; rows are f64 (formatted compactly) or strings.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    out: BufWriter<File>,
    ncols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, ncols: header.len() })
    }

    pub fn row(&mut self, vals: &[f64]) -> std::io::Result<()> {
        assert_eq!(vals.len(), self.ncols, "csv row arity mismatch");
        let s: Vec<String> = vals.iter().map(|v| fmt_f64(*v)).collect();
        writeln!(self.out, "{}", s.join(","))
    }

    pub fn row_strs(&mut self, vals: &[String]) -> std::io::Result<()> {
        assert_eq!(vals.len(), self.ncols, "csv row arity mismatch");
        writeln!(self.out, "{}", vals.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Compact float formatting: integers without trailing .0, otherwise up to
/// 6 significant decimals.
pub fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        let s = format!("{v:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_compact() {
        assert_eq!(fmt_f64(3.0), "3");
        assert_eq!(fmt_f64(0.5), "0.5");
        assert_eq!(fmt_f64(0.123456789), "0.123457");
        assert_eq!(fmt_f64(-2.0), "-2");
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("quafl_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a,b\n1,2.5\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join("quafl_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}

//! In-place fast Walsh–Hadamard transform (FWHT) — the rotation half of
//! the lattice quantizer's random rotation (random sign flip ∘ Hadamard),
//! the practical instantiation of Davies et al. [7] used by the paper
//! ("a random rotation followed by direct quantization").
//!
//! `fwht` computes H_n x (unnormalized); with the 1/sqrt(n) scale applied
//! it is orthonormal and self-inverse. Length must be a power of two — the
//! quantizer zero-pads to the next power of two.

/// Unnormalized in-place FWHT. `x.len()` must be a power of two.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht: len {n} not a power of two");
    let mut h = 1;
    while h < n {
        let stride = h * 2;
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += stride;
        }
        h = stride;
    }
}

/// Orthonormal FWHT: H_n / sqrt(n). Self-inverse.
pub fn fwht_normalized(x: &mut [f32]) {
    let n = x.len();
    fwht(x);
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Apply the seeded random-sign diagonal D (±1 per coordinate) in place.
/// Both encoder and decoder derive the same signs from the shared seed.
pub fn sign_flip(x: &mut [f32], seed: u64) {
    let mut rng = crate::util::rng::Rng::new(seed);
    // Consume sign bits in batches of 64.
    let mut i = 0;
    while i < x.len() {
        let bits = rng.next_u64();
        let upto = (x.len() - i).min(64);
        for j in 0..upto {
            if (bits >> j) & 1 == 1 {
                x[i + j] = -x[i + j];
            }
        }
        i += upto;
    }
}

/// Forward random rotation R = (1/sqrt(n)) H D: sign flip then FWHT.
pub fn rotate(x: &mut [f32], seed: u64) {
    sign_flip(x, seed);
    fwht_normalized(x);
}

/// Inverse rotation R^{-1} = D H (1/sqrt(n)): FWHT then sign flip.
pub fn rotate_inverse(x: &mut [f32], seed: u64) {
    fwht_normalized(x);
    sign_flip(x, seed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    fn l2(x: &[f32]) -> f64 {
        x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    #[test]
    fn fwht_matches_naive_n8() {
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut y = x.clone();
        fwht(&mut y);
        // Naive H_8 multiply.
        let mut expect = vec![0f32; 8];
        for (i, e) in expect.iter_mut().enumerate() {
            for (j, &v) in x.iter().enumerate() {
                let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                *e += sign * v;
            }
        }
        assert_eq!(y, expect);
    }

    #[test]
    fn normalized_is_self_inverse() {
        for &n in &[1usize, 2, 8, 64, 1024] {
            let x = randvec(n, 42);
            let mut y = x.clone();
            fwht_normalized(&mut y);
            fwht_normalized(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn rotation_preserves_l2_norm() {
        for &n in &[8usize, 256, 4096] {
            let x = randvec(n, 7);
            let before = l2(&x);
            let mut y = x.clone();
            rotate(&mut y, 123);
            let after = l2(&y);
            assert!(
                (before - after).abs() / before < 1e-5,
                "n={n} {before} {after}"
            );
        }
    }

    #[test]
    fn rotate_then_inverse_is_identity() {
        let x = randvec(512, 3);
        let mut y = x.clone();
        rotate(&mut y, 999);
        rotate_inverse(&mut y, 999);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn different_seeds_give_different_rotations() {
        let x = randvec(256, 5);
        let mut a = x.clone();
        let mut b = x.clone();
        rotate(&mut a, 1);
        rotate(&mut b, 2);
        let diff = a.iter().zip(&b).filter(|(p, q)| (*p - *q).abs() > 1e-6).count();
        assert!(diff > 200);
    }

    #[test]
    fn rotation_spreads_spike() {
        // A one-hot vector must spread to ~uniform magnitude — the property
        // that makes per-coordinate quantization error dimension-friendly.
        let n = 1024;
        let mut x = vec![0f32; n];
        x[17] = 1.0;
        rotate(&mut x, 77);
        let maxabs = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!(maxabs < 5.0 / (n as f32).sqrt(), "maxabs={maxabs}");
    }

    #[test]
    #[should_panic]
    fn non_pow2_panics() {
        let mut x = vec![0f32; 12];
        fwht(&mut x);
    }
}

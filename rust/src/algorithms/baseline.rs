//! Sequential baseline — the paper's "Baseline": a single node (assumed
//! *slow*, per Appendix A) that both holds all the data and performs one
//! optimization step per round. Fastest per-round convergence, slowest in
//! wall-clock — the anchor for the time-vs-rounds comparisons
//! (Figures 3, 10–15).
//!
//! Routed through the same [`crate::exec`] fan-out as the federated
//! protocols (one single-step task per round — degenerates to the serial
//! path on the primary engine) so all four algorithms share one execution
//! substrate.
//!
//! The client-selection subsystem ([`crate::select`]) is structurally a
//! no-op here: a single sequential node never samples clients, so the
//! policy is never consulted and the participation tracker stays empty
//! (its Gini/staleness CSV columns read 0) — pinned, along with the
//! other three algorithms, by rust/tests/select_parity.rs.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::FlRun;
use crate::data::Shard;
use crate::exec::ClientTask;
use crate::metrics::{CommTally, RunMetrics};
use crate::telemetry::{names, Telemetry};
use crate::util::rng::{derive_seed, Rng};

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let mut metrics = RunMetrics::new("baseline");

    // L3-telemetry: a single sequential node has no fleet, selection, or
    // quantizer — its loss stream is the one meaningful metric.
    let mut tel = Telemetry::new(ctx.telemetry_armed(), cfg.seed);

    let mut x = ctx.spec.init_params(derive_seed(cfg.seed, 0x1417));
    // The baseline node sees the whole training set.
    let all: Vec<usize> = (0..ctx.train.len()).collect();
    let mut shard = Shard::new(all, Rng::new(derive_seed(cfg.seed, 0xBA5E)));
    // Slow node: one Exp(slow_lambda) step per round.
    let mut step_rng = Rng::new(derive_seed(cfg.seed, 0xBA5E + 1));

    let mut now = 0f64;
    // A single sequential node never communicates: the tally carries only
    // its step count (bits and transport time stay 0). Its one resident
    // model is the node's own.
    let mut tally = CommTally {
        peak_model_bytes: (x.len() * 4) as u64,
        ..Default::default()
    };

    ctx.eval_point(&mut metrics, 0, now, &tally, &x)?;

    for t in 0..cfg.rounds {
        let round_t0 = ctx.tracer.start();
        let round_sim0 = now;
        now += step_rng.exponential(cfg.timing.slow_lambda);
        // The task holds the sole reference, so the worker's unwrap
        // mutates the model in place without a copy.
        let task = ClientTask::gather(
            0,
            Arc::new(x),
            &mut shard,
            &ctx.train,
            cfg.batch,
            1,
            cfg.lr,
        );
        let sgd_t0 = ctx.tracer.start();
        let mut results = ctx.pool.run_local_sgd(vec![task])?;
        ctx.tracer.span("local_sgd", sgd_t0, t as u64, 0.0, now);
        let r = results.pop().expect("one task in, one result out");
        x = r.params;
        tally.total_steps += r.steps as u64;
        metrics.total_interactions += 1;
        metrics.sum_observed_steps += 1;
        if r.steps > 0 {
            let mean_loss = r.loss as f64 / r.steps as f64;
            tel.observe(names::CLIENT_LOSS, mean_loss);
            tel.observe_sampled(names::CLIENT_LOSS, mean_loss);
        }

        if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
            ctx.eval_point(&mut metrics, t + 1, now, &tally, &x)?;
        }
        ctx.emit_counters(t as u64, now, &tally, None);
        tel.flush(&ctx.tracer, t as u64, now);
        ctx.tracer.span("round", round_t0, t as u64, now - round_sim0, now);
    }
    Ok(metrics)
}

//! Sequential baseline — the paper's "Baseline": a single node (assumed
//! *slow*, per Appendix A) that both holds all the data and performs one
//! optimization step per round. Fastest per-round convergence, slowest in
//! wall-clock — the anchor for the time-vs-rounds comparisons
//! (Figures 3, 10–15).

use anyhow::Result;

use crate::coordinator::FlRun;
use crate::data::Shard;
use crate::metrics::RunMetrics;
use crate::util::rng::{derive_seed, Rng};

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let mut metrics = RunMetrics::new("baseline");

    let mut x = ctx.engine.spec().init_params(derive_seed(cfg.seed, 0x1417));
    // The baseline node sees the whole training set.
    let all: Vec<usize> = (0..ctx.train.len()).collect();
    let mut shard = Shard::new(all, Rng::new(derive_seed(cfg.seed, 0xBA5E)));
    // Slow node: one Exp(slow_lambda) step per round.
    let mut step_rng = Rng::new(derive_seed(cfg.seed, 0xBA5E + 1));

    let mut now = 0f64;
    let mut total_steps = 0u64;

    ctx.eval_point(&mut metrics, 0, now, 0, 0, 0, &x)?;

    for t in 0..cfg.rounds {
        now += step_rng.exponential(cfg.timing.slow_lambda);
        let idx = shard.sample_batch(cfg.batch);
        let batch = ctx.train.gather_batch(&idx);
        ctx.engine.train_step(&mut x, &batch, cfg.lr)?;
        total_steps += 1;
        metrics.total_interactions += 1;
        metrics.sum_observed_steps += 1;

        if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
            ctx.eval_point(&mut metrics, t + 1, now, total_steps, 0, 0, &x)?;
        }
    }
    Ok(metrics)
}

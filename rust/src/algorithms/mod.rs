//! The federated protocols under study.
//!
//! - [`quafl`] — Algorithm 1 of the paper: non-blocking rounds, partial
//!   client progress, speed-weighted averaging, fully-quantized traffic.
//! - [`fedavg`] — synchronous FedAvg [25]: the server waits for the
//!   slowest sampled client each round; uncompressed.
//! - [`fedbuff`] — buffered asynchronous aggregation [30], the SOTA
//!   asynchronous baseline, with optional QSGD update compression.
//! - [`baseline`] — a single sequential SGD node (the paper's "Baseline").
//!
//! All four consume the same [`crate::coordinator::FlRun`] context and
//! produce the same [`crate::metrics::RunMetrics`], so every figure
//! compares like with like (same data, same engine, same timing model).
//!
//! Every protocol executes its per-round client work through the parallel
//! fan-out subsystem ([`crate::exec`]): a serial pre-pass snapshots each
//! sampled client's work into a [`crate::exec::ClientTask`] (advancing the
//! per-client RNG streams in sampled/event order),
//! [`crate::exec::EnginePool::map`] runs the tasks across `cfg.workers`
//! engines, and the reduction folds results back **in task order** — so
//! trajectories are bit-identical to the serial path for any worker count
//! (rust/tests/parallel_parity.rs).
//!
//! Availability-query contract: every protocol queries
//! [`crate::net::ClientAvailability`] (via selection or `next_up`) at
//! **globally non-decreasing** simulated times — QuAFL and FedAvg advance
//! `now` monotonically across rounds, FedBuff pops its finish-time heap
//! in order. The event-driven availability index (`--event-driven`,
//! default on) relies on this to drain its transition queue forward-only;
//! a `debug_assert` in the drain enforces it on every debug test run.

pub mod baseline;
pub mod fedavg;
pub mod fedbuff;
pub mod quafl;

use std::sync::Arc;

use crate::coordinator::FlRun;
use crate::exec::ClientTask;

/// Snapshot client `client_id`'s next `h`-step SGD burst from `params`
/// into a task, drawing its batches from the client's shard (the draw
/// order is what makes the fan-out deterministic — see [`crate::exec`]).
/// `params` is a shared CoW snapshot ([`crate::fleet`]); the worker
/// deep-copies it once, so gathering s tasks allocates no model floats.
pub(crate) fn make_task(
    ctx: &mut FlRun,
    client_id: usize,
    params: Arc<Vec<f32>>,
    h: usize,
    lr: f32,
) -> ClientTask {
    ClientTask::gather(
        client_id,
        params,
        &mut ctx.shards[client_id],
        &ctx.train,
        ctx.cfg.batch,
        h,
        lr,
    )
}

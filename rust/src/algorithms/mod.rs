//! The federated protocols under study.
//!
//! - [`quafl`] — Algorithm 1 of the paper: non-blocking rounds, partial
//!   client progress, speed-weighted averaging, fully-quantized traffic.
//! - [`fedavg`] — synchronous FedAvg [25]: the server waits for the
//!   slowest sampled client each round; uncompressed.
//! - [`fedbuff`] — buffered asynchronous aggregation [30], the SOTA
//!   asynchronous baseline, with optional QSGD update compression.
//! - [`baseline`] — a single sequential SGD node (the paper's "Baseline").
//!
//! All four consume the same [`crate::coordinator::FlRun`] context and
//! produce the same [`crate::metrics::RunMetrics`], so every figure
//! compares like with like (same data, same engine, same timing model).

pub mod baseline;
pub mod fedavg;
pub mod fedbuff;
pub mod quafl;

use crate::coordinator::FlRun;
use crate::data::Batch;

/// Run `h` local SGD steps from `params` on client `client_id`'s shard.
/// Returns the summed training loss over the steps (diagnostics) — the
/// resulting parameters are written in place.
pub(crate) fn local_sgd(
    ctx: &mut FlRun,
    client_id: usize,
    params: &mut [f32],
    h: usize,
) -> anyhow::Result<f32> {
    local_sgd_lr(ctx, client_id, params, h, ctx.cfg.lr)
}

/// `local_sgd` with an explicit learning rate (the weighted QuAFL variant
/// rescales η globally — see quafl.rs). The whole h-step burst goes
/// through `TrainEngine::train_steps`, which the XLA engine fuses into a
/// single PJRT dispatch (§Perf L2).
pub(crate) fn local_sgd_lr(
    ctx: &mut FlRun,
    client_id: usize,
    params: &mut [f32],
    h: usize,
    lr: f32,
) -> anyhow::Result<f32> {
    let batch_size = ctx.cfg.batch;
    let batches: Vec<Batch> = (0..h)
        .map(|_| {
            let idx = ctx.shards[client_id].sample_batch(batch_size);
            ctx.train.gather_batch(&idx)
        })
        .collect();
    ctx.engine.train_steps(params, &batches, lr)
}

//! QuAFL — Algorithm 1 of the paper, simulated exactly as Appendix A.2
//! describes.
//!
//! Per server round t (server clock τ):
//!
//! 1. Sample S, |S| <= s, from the *reachable* clients (the [`crate::net`]
//!    availability process) through the pluggable selection policy
//!    ([`crate::select`], `--select`). The default `Uniform` policy is the
//!    paper's rule — under the default `Always` process it is exactly the
//!    pre-net uniform draw of s clients, bit for bit; staleness-, fairness-
//!    and loss-aware policies bias the draw using the server's
//!    participation tracker.
//! 2. For each i ∈ S (non-blocking — the client replies immediately):
//!    - the client's realized progress is H_i = (steps its Exp(λ_i)
//!      process completed since its last interaction, capped at K); those
//!      H_i SGD steps are *actually executed* on its shard now (lazy
//!      materialization — identical trajectory, no wasted compute);
//!    - it transmits Enc(Y^i), Y^i = X^i − η·η_i·h̃_i (speed-dampened
//!      progress; η_i = H_min/H_i in the weighted variant, 1 otherwise);
//!      the server decodes against its own model: Q(Y^i) = Dec(X_t, ·);
//!    - it receives Enc(X_t) and decodes against its own model:
//!      Q(X_t) = Dec(X^i, ·);
//!    - client update (averaging mode "both", the paper default):
//!      X^i ← Q(X_t)/(s+1) + s/(s+1)·Y^i, then restarts K local steps.
//! 3. Server update: X_{t+1} = (X_t + Σ_{i∈S} Q(Y^i))/(s+1).
//! 4. τ += sit + (slowest sampled exchange), then τ += swt before the next
//!    round. Each exchange is priced by the transport from its *actual*
//!    encoded bits (Enc(X_t) down, Enc(Y^i) up); the round extends by the
//!    max over the sampled clients since the exchanges overlap. Under the
//!    default `Ideal` transport every cost is exactly 0.0, reproducing the
//!    pre-net trajectory bit for bit.
//!
//! The Figure 4 ablation modes replace step 2/3's averaging:
//! `ServerOnly` has clients adopt Q(X_t) outright; `ClientOnly` has the
//! server adopt the mean of the received Q(Y^i).
//!
//! Step 2 is embarrassingly parallel across the sampled clients — each
//! touches only its own model/shard/clock and decodes against round-
//! constant keys (X_t, Enc(X_t)) — so it runs through the [`crate::exec`]
//! fan-out: clocks/metrics/batch draws in a serial pre-pass, SGD + both
//! coding directions in the workers, and the Σ Q(Y^i) accumulation in
//! sampled order during the reduction (bit-identical for any
//! `cfg.workers`).

use anyhow::Result;

use super::make_task;
use crate::config::AveragingMode;
use crate::coordinator::FlRun;
use crate::engine::TrainEngine;
use crate::metrics::{CommTally, RunMetrics};
use crate::model::params;
use crate::quant::Quantizer;
use crate::telemetry::{names, probe::DivergenceProbe, Telemetry};
use crate::util::rng::derive_seed;
use crate::util::stats::l2_dist;

/// One sampled client's fan-out output — everything the in-order
/// reduction needs.
struct ClientOutcome {
    client_id: usize,
    /// the server's decode of the client's reply, Q(Y^i)
    q_y: Vec<f32>,
    /// the client's next model X^i
    x_next: Vec<f32>,
    /// exact uplink cost of Enc(Y^i)
    up_bits: u64,
    /// summed training loss over the burst (participation-tracker
    /// observation — the trajectory never reads it)
    loss: f32,
    /// local steps actually executed (h)
    steps: usize,
    /// ‖Y^i − Q(Y^i)‖ quantization-error norm, computed only when
    /// telemetry is armed (`None` otherwise, so the trajectory's float
    /// work is untouched by the observation)
    qerr: Option<f64>,
    /// the encoded uplink payload bytes, kept only when chaos is armed
    /// so the fault layer can run the real checksum-frame corruption
    /// model on the wire ([`crate::fault`]); `None` on default runs
    wire: Option<Vec<u8>>,
}

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let d = ctx.spec.num_params();
    let mut metrics = RunMetrics::new("quafl");

    // Initial models: server and all clients start from the same init
    // (the paper initializes everything to the same point). Client models
    // live in the CoW fleet store: every client references the shared
    // init until its first sampled interaction diverges it, so resident
    // memory is O(touched·d), not O(n·d) ([`crate::fleet`]).
    let server_init = ctx.spec.init_params(derive_seed(cfg.seed, 0x1417));
    let mut x_server = server_init.clone();
    let mut fleet = ctx.fleet_store(server_init);

    // Convergence diagnostics (L3-telemetry). Φ_t / discrepancy come
    // from the incremental O(touched·d) probe unless `--dense-potential`
    // asks for the reference O(n·d) folds; the registry only arms on a
    // traced run with `--telemetry` left on. Neither path touches a
    // trajectory float or a simulation RNG stream
    // (rust/tests/telemetry_parity.rs).
    let tel_armed = ctx.telemetry_armed();
    let mut tel = Telemetry::new(tel_armed, cfg.seed);
    let want_phi = cfg.track_potential || tel_armed;
    let mut probe = (want_phi && !cfg.dense_potential)
        .then(|| DivergenceProbe::new(x_server.clone(), cfg.n));

    // η_i = H_min / H_i (weighted variant); 1 otherwise. The paper's
    // theory pairs the dampening with a global rate η ∝ 1/H_min
    // (Theorem 3.2); we keep total step mass comparable to the unweighted
    // variant by rescaling the local rate so η_i·H_i ≈ H̄ (mean speed)
    // rather than H_min — the same reparameterization, calibrated in
    // EXPERIMENTS.md §Weighting.
    let h_min = ctx
        .expected_h
        .iter()
        .cloned()
        .fold(f64::INFINITY, f64::min);
    let h_mean =
        ctx.expected_h.iter().sum::<f64>() / ctx.expected_h.len() as f64;
    let (eta, lr_eff): (Vec<f32>, f32) = if cfg.weighted {
        (
            ctx.expected_h.iter().map(|&h| (h_min / h) as f32).collect(),
            cfg.lr * (h_mean / h_min) as f32,
        )
    } else {
        (vec![1.0; cfg.n], cfg.lr)
    };

    let mut now = 0f64;
    let mut tally = CommTally {
        peak_model_bytes: fleet.peak_bytes(),
        ..Default::default()
    };
    if cfg.price_init_broadcast {
        now += ctx.price_init_broadcast(&mut tally);
    }

    ctx.eval_point(&mut metrics, 0, now, &tally, &x_server)?;

    for t in 0..cfg.rounds {
        let round_t0 = ctx.tracer.start();
        let round_sim0 = now;
        now += cfg.timing.swt;
        // Selection goes through the pluggable policy ([`crate::select`]);
        // the default `Uniform` consumes exactly the RNG stream the direct
        // `availability.sample` call consumed (tests/select_parity.rs).
        let select_t0 = ctx.tracer.start();
        let sampled = ctx.select_clients(now);
        ctx.tracer.span("select", select_t0, t as u64, 0.0, now);
        if cfg.track_selection {
            metrics.selections.push((now, sampled.clone()));
        }
        if sampled.len() < cfg.s {
            metrics.short_rounds += 1;
        }
        if sampled.is_empty() {
            // Nobody reachable: the server idles this round (the idle
            // round still ages every snapshot).
            now += cfg.timing.sit;
            ctx.tracker.advance_round();
            fleet.advance_epoch();
            if want_phi {
                let phi = phi_of(probe.as_ref(), &x_server, &fleet);
                if cfg.track_potential {
                    metrics.potential.push(phi);
                }
                tel.gauge_set(names::PHI, phi);
                tel.gauge_set(
                    names::DISCREPANCY,
                    disc_of(probe.as_ref(), &x_server, &fleet),
                );
            }
            tel.gauge_set(names::SELECT_CHI2, ctx.tracker.selection_bias_chi2());
            tel.gauge_set(names::GINI, ctx.tracker.participation_gini());
            if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
                ctx.eval_point(&mut metrics, t + 1, now, &tally, &x_server)?;
            }
            ctx.emit_counters(t as u64, now, &tally, Some(&fleet));
            tel.flush(&ctx.tracer, t as u64, now);
            ctx.tracer.span("round", round_t0, t as u64, now - round_sim0, now);
            continue;
        }
        // With churn a round may run below the configured s; the averaging
        // weight follows the realized sample size (equal to the configured
        // one — hence bit-identical — whenever everyone is reachable).
        let inv_s1 = 1.0 / (sampled.len() as f32 + 1.0);

        // Server's outgoing message is encoded once per round.
        let quant_t0 = ctx.tracer.start();
        let down_seed = derive_seed(cfg.seed, 0xD011 ^ ((t as u64) << 24));
        let enc_x = ctx.quantizer.encode(&x_server, down_seed);
        ctx.tracer.span("quantize", quant_t0, t as u64, 0.0, now);

        // Serial pre-pass (sampled order): realize each client's partial
        // progress on its clock, account it, and snapshot its SGD burst.
        let mut tasks = Vec::with_capacity(sampled.len());
        for &i in &sampled {
            let h = ctx.clocks[i].steps_completed(now, cfg.k);
            metrics.total_interactions += 1;
            metrics.sum_observed_steps += h as u64;
            if h == 0 {
                metrics.zero_progress_interactions += 1;
            }
            tally.total_steps += h as u64;
            tasks.push(make_task(ctx, i, fleet.snapshot(i), h, lr_eff));
        }

        // Fan out: local SGD, Y^i formation, and both directions of the
        // quantized exchange. X_t and Enc(X_t) are round constants, so
        // every worker decodes against exactly what the serial loop would.
        let sgd_t0 = ctx.tracer.start();
        let quantizer: &dyn Quantizer = ctx.quantizer.as_ref();
        let x_server_key = &x_server;
        let enc_x_ref = &enc_x;
        let eta_ref = &eta;
        let fault_armed = ctx.fault.is_some();
        let outcomes = ctx.pool.map(tasks, |engine: &mut dyn TrainEngine, task| {
            let i = task.client_id;
            // Execute the h steps the client actually took (from X^i).
            // The deep copy of the shared snapshot happens here, in the
            // worker — the fan-out's single materialization point.
            let steps = task.batches.len();
            let mut x_sgd = (*task.params).clone();
            let loss = if task.batches.is_empty() {
                0.0
            } else {
                engine.train_steps(&mut x_sgd, &task.batches, task.lr)?
            };
            // Y^i = X^i - η·η_i·h̃ = (1-η_i)·X^i + η_i·(SGD result).
            let y_i = if eta_ref[i] == 1.0 {
                x_sgd
            } else {
                let mut y = (*task.params).clone();
                params::scale(&mut y, 1.0 - eta_ref[i]);
                params::axpy(&mut y, eta_ref[i], &x_sgd);
                y
            };

            // Upstream: Enc(Y^i), decoded by the server against X_t.
            let up_seed = derive_seed(cfg.seed, ((t as u64) << 20) | i as u64);
            let enc_y = quantizer.encode(&y_i, up_seed);
            let up_bits = enc_y.bits as u64;
            let q_y = quantizer.decode(&enc_y, x_server_key);
            let wire = fault_armed.then(|| enc_y.payload);
            // Quantization-error observation for the telemetry sketch —
            // computed only when armed, and never fed back into any
            // trajectory value.
            let qerr = tel_armed.then(|| l2_dist(&y_i, &q_y));

            // Downstream: Enc(X_t), decoded by the client against X^i.
            let q_x = quantizer.decode(enc_x_ref, task.params.as_slice());

            // Client-side model update. The Figure 4 ablation *removes*
            // one side's averaging: in ServerOnly the client ignores the
            // server's message entirely and continues from its own
            // progress (no client-side averaging).
            let x_next = match cfg.averaging {
                AveragingMode::Both | AveragingMode::ClientOnly => {
                    let mut m = q_x;
                    params::scale(&mut m, inv_s1);
                    params::axpy(&mut m, sampled.len() as f32 * inv_s1, &y_i);
                    m
                }
                AveragingMode::ServerOnly => y_i,
            };
            Ok(ClientOutcome {
                client_id: i,
                q_y,
                x_next,
                up_bits,
                loss,
                steps,
                qerr,
                wire,
            })
        })?;
        ctx.tracer.span("local_sgd", sgd_t0, t as u64, 0.0, now);

        // Reduction-boundary high-water mark (same boundary FedBuff and
        // FedAvg measure at): store residents plus the s returned
        // next-models not yet installed. Worker SGD scratch and decoded
        // message buffers are excluded, as everywhere.
        tally.peak_model_bytes = tally
            .peak_model_bytes
            .max(fleet.resident_bytes() + (outcomes.len() * d * 4) as u64)
            .max(fleet.peak_bytes());

        // In-order reduction: Σ Q(Y^i) accumulates in sampled order, so
        // the floating-point sum matches the serial path bit for bit. Each
        // exchange is priced from its actual bits; the exchanges overlap,
        // so the round extends by the slowest one.
        let reduce_t0 = ctx.tracer.start();
        let mut sum_qy = vec![0f32; d];
        let mut round_comm = 0f64;
        // Server-side averaging weight follows the updates it actually
        // holds; equal to the sampled count (hence the legacy weight, bit
        // for bit) on every unfaulted run.
        let mut accepted_n = sampled.len();
        if ctx.fault.is_some() {
            accepted_n = faulted_reduce(
                ctx, t, now, &enc_x, outcomes, &mut sum_qy, &mut round_comm,
                &mut tally, &mut fleet, &mut probe, &mut tel,
            );
        } else {
        for out in outcomes {
            let down_t =
                ctx.transport.downlink_time(out.client_id, enc_x.bits as u64);
            let up_t = ctx.transport.uplink_time(out.client_id, out.up_bits);
            round_comm = round_comm.max(down_t + up_t);
            ctx.tracer.sample("delay", t as u64, down_t + up_t);
            tally.comm_down_time += down_t;
            tally.comm_up_time += up_t;
            tally.bits_up += out.up_bits;
            tally.bits_down += enc_x.bits as u64;
            params::axpy(&mut sum_qy, 1.0, &out.q_y);
            if let Some(p) = probe.as_mut() {
                p.note_write(fleet.get(out.client_id), &out.x_next);
            }
            if let Some(e) = out.qerr {
                tel.observe(names::QERR, e);
            }
            tel.observe(names::DELAY, down_t + up_t);
            if out.steps > 0 {
                let mean_loss = out.loss as f64 / out.steps as f64;
                tel.observe(names::CLIENT_LOSS, mean_loss);
                tel.observe_sampled(names::CLIENT_LOSS, mean_loss);
            }
            fleet.set(out.client_id, out.x_next);
            // Participation bookkeeping for the selection policies: the
            // client was served now, holds a round-t snapshot, and its
            // mean local loss is the freshest signal the server has.
            // Pure bookkeeping — no RNG, no trajectory float.
            ctx.tracker.record_participation(out.client_id, now);
            ctx.tracker.note_snapshot(out.client_id);
            if out.steps > 0 {
                ctx.tracker
                    .note_loss(out.client_id, out.loss as f64 / out.steps as f64);
            }
            // The client restarts its K local steps once it has received
            // and folded in the server's message.
            ctx.clocks[out.client_id].restart(now + cfg.timing.sit + down_t);
        }
        }
        ctx.tracer.span("reduce", reduce_t0, t as u64, 0.0, now);

        // Server-side model update over the updates the server actually
        // accepted (== the full sample on unfaulted runs, so the weight
        // is the legacy 1/(s+1) bit for bit). ClientOnly removes the
        // server's self-retention: it adopts the plain mean of client
        // replies.
        let inv_srv = 1.0 / (accepted_n as f32 + 1.0);
        match cfg.averaging {
            AveragingMode::Both | AveragingMode::ServerOnly => {
                // X_{t+1} = (X_t + Σ Q(Y^i)) / (s+1)
                params::scale(&mut x_server, inv_srv);
                params::axpy(&mut x_server, inv_srv, &sum_qy);
            }
            AveragingMode::ClientOnly => {
                if accepted_n > 0 {
                    x_server = sum_qy;
                    params::scale(&mut x_server, 1.0 / accepted_n as f32);
                }
                // A fully degraded round (nothing accepted) keeps X_t.
            }
        }

        now += cfg.timing.sit + round_comm;
        tally.peak_model_bytes = tally.peak_model_bytes.max(fleet.peak_bytes());
        ctx.tracker.advance_round();
        fleet.advance_epoch();
        debug_assert_eq!(
            ctx.tracker.round(),
            fleet.current_epoch(),
            "tracker round and fleet epoch must advance in lockstep"
        );

        if want_phi {
            let phi = phi_of(probe.as_ref(), &x_server, &fleet);
            if cfg.track_potential {
                metrics.potential.push(phi);
            }
            tel.gauge_set(names::PHI, phi);
            tel.gauge_set(
                names::DISCREPANCY,
                disc_of(probe.as_ref(), &x_server, &fleet),
            );
        }
        tel.gauge_set(names::SELECT_CHI2, ctx.tracker.selection_bias_chi2());
        tel.gauge_set(names::GINI, ctx.tracker.participation_gini());

        if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
            ctx.eval_point(&mut metrics, t + 1, now, &tally, &x_server)?;
        }
        ctx.emit_counters(t as u64, now, &tally, Some(&fleet));
        tel.flush(&ctx.tracer, t as u64, now);
        ctx.tracer.span("round", round_t0, t as u64, now - round_sim0, now);
    }
    Ok(metrics)
}

/// The reduce loop when chaos is armed ([`crate::fault`]): every
/// exchange runs through the fault engine — the server's Enc(X_t) and
/// the client's Enc(Y^i) each retry with exponential backoff on loss,
/// the uplink payload carries a checksum frame whose corruption is
/// detected server-side, stragglers pay a link-time multiplier, crashed
/// clients waste their SGD burst (repeat offenders are evicted from the
/// availability process), and a configured `--round-deadline` closes
/// the round K-of-s quorum-style. A client whose exchange completed
/// still applies its own update even when the server discarded a late
/// arrival. Returns the number of updates the server accepted.
#[allow(clippy::too_many_arguments)]
fn faulted_reduce(
    ctx: &mut FlRun,
    t: usize,
    now: f64,
    enc_x: &crate::quant::QuantMessage,
    outcomes: Vec<ClientOutcome>,
    sum_qy: &mut [f32],
    round_comm: &mut f64,
    tally: &mut CommTally,
    fleet: &mut crate::fleet::ClientModelStore,
    probe: &mut Option<DivergenceProbe>,
    tel: &mut Telemetry,
) -> usize {
    use crate::fault::LinkDir;
    use crate::quant::FRAME_HEADER_BITS;

    /// One sampled client's exchange fate, resolved before the quorum
    /// rule closes the round.
    struct Fate {
        out: ClientOutcome,
        crashed: bool,
        /// downlink delivered — the client folded the round locally
        served: bool,
        /// uplink delivered — the server holds Q(Y^i)
        arrived: bool,
        down_time: f64,
        /// exchange completion offset from round start (finite iff
        /// `arrived`)
        arrival: f64,
        compute_s: f64,
    }

    let round = t as u64;
    let header = FRAME_HEADER_BITS as u64;
    let sit = ctx.cfg.timing.sit;
    let mut fates = Vec::with_capacity(outcomes.len());
    let mut arrivals = Vec::new();
    let mut max_elapsed = 0f64;
    for out in outcomes {
        let i = out.client_id;
        let compute_s = out.steps as f64 / ctx.clocks[i].rate();
        if ctx.fault.as_ref().unwrap().crashes(round, i) {
            // Crash after local SGD, before upload: the burst is wasted
            // and the exchange never starts. First crash reboots the
            // client; repeat offenders are permanently evicted.
            let fe = ctx.fault.as_mut().unwrap();
            fe.waste(compute_s, 0);
            let evicted = fe.record_crash(i);
            tally.wasted_compute_time += compute_s;
            if evicted {
                ctx.availability.evict(i);
            } else {
                ctx.clocks[i].restart(now + sit); // reboot
            }
            fates.push(Fate {
                out,
                crashed: true,
                served: false,
                arrived: false,
                down_time: 0.0,
                arrival: f64::INFINITY,
                compute_s,
            });
            continue;
        }
        let mult = ctx.fault.as_ref().unwrap().slow_mult(i);
        let down_bits = enc_x.bits as u64 + header;
        let up_bits = out.up_bits + header;
        let down_link = ctx.transport.downlink_time(i, down_bits) * mult;
        let up_link = ctx.transport.uplink_time(i, up_bits) * mult;
        let down = ctx.fault.as_mut().unwrap().deliver(
            round,
            i,
            LinkDir::Down,
            down_link,
            down_bits,
            None,
        );
        // Retries cost real bits and real time, delivered or not.
        tally.bits_down += down_bits * down.attempts as u64;
        tally.comm_down_time += down.time;
        let mut arrival = f64::INFINITY;
        let mut arrived = false;
        if down.delivered {
            let up = ctx.fault.as_mut().unwrap().deliver(
                round,
                i,
                LinkDir::Up,
                up_link,
                up_bits,
                out.wire.as_deref(),
            );
            tally.bits_up += up_bits * up.attempts as u64;
            tally.comm_up_time += up.time;
            if up.delivered {
                arrived = true;
                arrival = down.time + up.time;
                arrivals.push(arrival);
            } else {
                tally.wasted_up_bits += up_bits * up.attempts as u64;
                tally.wasted_compute_time += compute_s;
            }
            max_elapsed = max_elapsed.max(down.time + up.time);
        } else {
            // The client never learned it was sampled: its realized
            // progress buys nothing this round.
            tally.wasted_compute_time += compute_s;
            max_elapsed = max_elapsed.max(down.time);
        }
        fates.push(Fate {
            out,
            crashed: false,
            served: down.delivered,
            arrived,
            down_time: down.time,
            arrival,
            compute_s,
        });
    }

    // Close the round: the quorum/deadline rule decides the cutoff; a
    // delivered update past it is discarded (its cost already paid).
    let cutoff = ctx.fault.as_mut().unwrap().quorum_cutoff(&arrivals).0;
    *round_comm = if ctx.cfg.fault.round_deadline > 0.0 {
        cutoff
    } else {
        // No deadline: the server waits out every retry chain.
        max_elapsed.max(cutoff)
    };

    let mut accepted_n = 0usize;
    for f in fates {
        let i = f.out.client_id;
        let accepted = f.arrived && f.arrival <= cutoff;
        if accepted {
            accepted_n += 1;
            params::axpy(sum_qy, 1.0, &f.out.q_y);
        } else if f.arrived {
            // Delivered but after the cutoff: the server discarded it.
            tally.wasted_up_bits += f.out.up_bits + header;
            tally.wasted_compute_time += f.compute_s;
        }
        if f.arrived {
            ctx.tracer.sample("delay", round, f.arrival);
            tel.observe(names::DELAY, f.arrival);
        }
        if f.served {
            // The client received Enc(X_t) and folded the round locally
            // whatever the server later accepted.
            if let Some(p) = probe.as_mut() {
                p.note_write(fleet.get(i), &f.out.x_next);
            }
            if let Some(e) = f.out.qerr {
                tel.observe(names::QERR, e);
            }
            if f.out.steps > 0 {
                let mean_loss = f.out.loss as f64 / f.out.steps as f64;
                tel.observe(names::CLIENT_LOSS, mean_loss);
                tel.observe_sampled(names::CLIENT_LOSS, mean_loss);
                ctx.tracker.note_loss(i, mean_loss);
            }
            fleet.set(i, f.out.x_next);
            ctx.tracker.record_participation(i, now);
            ctx.tracker.note_snapshot(i);
            ctx.clocks[i].restart(now + sit + f.down_time);
        } else if !f.crashed {
            // Unreached client: no exchange, fresh local burst.
            ctx.clocks[i].restart(now + sit);
        }
    }
    accepted_n
}

/// Round-boundary Φ_t: the incremental probe when one is maintained,
/// the reference dense fold otherwise (`--dense-potential`).
fn phi_of(
    probe: Option<&DivergenceProbe>,
    x_server: &[f32],
    fleet: &crate::fleet::ClientModelStore,
) -> f64 {
    match probe {
        Some(p) => p.potential(x_server),
        None => potential_view(x_server, fleet.iter_dense()),
    }
}

/// Round-boundary server–client discrepancy, same probe-or-dense split.
fn disc_of(
    probe: Option<&DivergenceProbe>,
    x_server: &[f32],
    fleet: &crate::fleet::ClientModelStore,
) -> f64 {
    match probe {
        Some(p) => p.discrepancy(x_server),
        None => server_client_discrepancy_view(x_server, fleet.iter_dense()),
    }
}

/// Diagnostic used by tests/benches: distance between server and the mean
/// of client models (the paper's potential Φ_t tracks exactly this kind of
/// discrepancy — Lemma 3.4 keeps it bounded).
pub fn server_client_discrepancy(x_server: &[f32], clients: &[Vec<f32>]) -> f64 {
    server_client_discrepancy_view(
        x_server,
        clients.iter().map(|c| c.as_slice()),
    )
}

/// [`server_client_discrepancy`] over any client-order dense view —
/// notably [`crate::fleet::ClientModelStore::iter_dense`], which folds
/// the CoW store's shared base in plain iteration order, so the result
/// is bit-identical to the eager `&[Vec<f32>]` layout's.
pub fn server_client_discrepancy_view<'a, I>(x_server: &[f32], clients: I) -> f64
where
    I: Iterator<Item = &'a [f32]> + ExactSizeIterator,
{
    let n = clients.len();
    let d = x_server.len();
    let mut mean = vec![0f32; d];
    for c in clients {
        params::axpy(&mut mean, 1.0 / n as f32, c);
    }
    l2_dist(x_server, &mean)
}

/// The paper's potential Φ_t = ‖X_t − μ_t‖² + Σᵢ‖Xⁱ − μ_t‖², with
/// μ_t = (X_t + Σᵢ Xⁱ)/(n+1) (Section 3.3). Lemma 3.4 proves a
/// supermartingale-type contraction; `track_potential` lets experiments
/// verify the boundedness empirically.
pub fn potential(x_server: &[f32], clients: &[Vec<f32>]) -> f64 {
    potential_view(x_server, clients.iter().map(|c| c.as_slice()))
}

/// [`potential`] over any client-order dense view (same float order as
/// the eager layout — the two accumulate identical sums bit for bit; the
/// fleet store's CoW sharing is invisible here).
pub fn potential_view<'a, I>(x_server: &[f32], clients: I) -> f64
where
    I: Iterator<Item = &'a [f32]> + ExactSizeIterator + Clone,
{
    let n1 = (clients.len() + 1) as f32;
    let mut mu = x_server.to_vec();
    for c in clients.clone() {
        params::axpy(&mut mu, 1.0, c);
    }
    params::scale(&mut mu, 1.0 / n1);
    let mut phi = l2_dist(x_server, &mu).powi(2);
    for c in clients {
        phi += l2_dist(c, &mu).powi(2);
    }
    phi
}

//! Synchronous FedAvg [25] — the paper's primary comparison point
//! (Appendix A.2 simulation rules):
//!
//! Each round the server samples s clients, sends them its model
//! *uncompressed*, and blocks until the slowest of them completes exactly
//! K local steps; it then averages the returned models equally. The round
//! duration is max_i(time for K steps) + sit, and swt = 0 (the server
//! calls again immediately) — both straight from the paper.
//!
//! The s independent K-step bursts run through the [`crate::exec`]
//! fan-out; the equal-weight average folds the returned models in sampled
//! order, so the trajectory is bit-identical to the serial path.

use anyhow::Result;

use super::make_task;
use crate::coordinator::FlRun;
use crate::metrics::RunMetrics;
use crate::model::params;
use crate::util::rng::derive_seed;

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let d = ctx.spec.num_params();
    let mut metrics = RunMetrics::new("fedavg");

    let mut x_server = ctx.spec.init_params(derive_seed(cfg.seed, 0x1417));
    let mut now = 0f64;
    let mut bits_up = 0u64;
    let mut bits_down = 0u64;
    let mut total_steps = 0u64;

    ctx.eval_point(&mut metrics, 0, now, 0, 0, 0, &x_server)?;

    // FedAvg transmits full-precision models in both directions.
    let model_bits = (d * 32) as u64;

    for t in 0..cfg.rounds {
        let sampled = ctx.rng.sample_distinct(cfg.n, cfg.s);

        // Synchronous barrier: the round takes as long as the slowest
        // sampled client needs for its K steps. Pre-pass advances clocks
        // and snapshots each client's K-step burst from X_t.
        let mut round_end = now;
        let mut tasks = Vec::with_capacity(sampled.len());
        for &i in &sampled {
            ctx.clocks[i].restart(now);
            let finish = ctx.clocks[i].finish_time_for(cfg.k);
            round_end = round_end.max(finish);

            metrics.total_interactions += 1;
            metrics.sum_observed_steps += cfg.k as u64;
            total_steps += cfg.k as u64;
            bits_down += model_bits;
            bits_up += model_bits;

            tasks.push(make_task(ctx, i, x_server.clone(), cfg.k, cfg.lr));
        }

        // Fan out the K-step bursts; average in sampled order.
        let results = ctx.pool.run_local_sgd(tasks)?;
        let mut sum = vec![0f32; d];
        for r in &results {
            params::axpy(&mut sum, 1.0 / cfg.s as f32, &r.params);
        }
        x_server = sum;
        now = round_end + cfg.timing.sit;

        if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
            ctx.eval_point(
                &mut metrics,
                t + 1,
                now,
                total_steps,
                bits_up,
                bits_down,
                &x_server,
            )?;
        }
    }
    Ok(metrics)
}

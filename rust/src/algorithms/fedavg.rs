//! Synchronous FedAvg [25] — the paper's primary comparison point
//! (Appendix A.2 simulation rules):
//!
//! Each round the server samples s reachable clients (through the
//! pluggable selection policy, [`crate::select`] — the default `Uniform`
//! is the paper's rule, bit for bit), sends them its model
//! *uncompressed*, and blocks until the slowest of them completes exactly
//! K local steps; it then averages the returned models equally. The round
//! duration is max_i(downlink_i + time for K steps + uplink_i) + sit, and
//! swt = 0 (the server calls again immediately) — the transport terms are
//! exactly 0.0 under the default `Ideal` profile, reproducing the paper's
//! rule (and the pre-net trajectory) bit for bit.
//!
//! `--broadcast-downlink` reprices the model broadcast as one
//! transmission on a shared medium: every sampled client receives at the
//! *slowest* sampled link's downlink time and the payload bits are
//! charged once per round, instead of the default s independent unicasts
//! (each client at its own link, s payloads). Off by default — the
//! unicast pricing is the bit-exact legacy path.
//!
//! The s independent K-step bursts run through the [`crate::exec`]
//! fan-out; the equal-weight average folds the returned models in sampled
//! order, so the trajectory is bit-identical to the serial path.

use std::sync::Arc;

use anyhow::Result;

use super::make_task;
use crate::coordinator::FlRun;
use crate::metrics::{CommTally, RunMetrics};
use crate::model::params;
use crate::telemetry::{names, Telemetry};
use crate::util::rng::derive_seed;

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let d = ctx.spec.num_params();
    let mut metrics = RunMetrics::new("fedavg");

    // L3-telemetry registry. FedAvg is synchronous and uncompressed, so
    // there is no Φ_t probe and no quantization error — selection-bias
    // gauges plus loss/delay distributions cover it.
    let mut tel = Telemetry::new(ctx.telemetry_armed(), cfg.seed);

    let mut x_server = ctx.spec.init_params(derive_seed(cfg.seed, 0x1417));
    let mut now = 0f64;
    // FedAvg clients are stateless between rounds: resident client-model
    // state is the round's shared broadcast snapshot (one allocation for
    // all s sampled clients) plus, at the reduction boundary, the s
    // returned models — tracked per round below. `--price-init-broadcast`
    // is a no-op here: every downlink, including round 0's, is priced
    // already.
    let mut tally = CommTally {
        peak_model_bytes: (d * 4) as u64,
        ..Default::default()
    };

    ctx.eval_point(&mut metrics, 0, now, &tally, &x_server)?;

    // FedAvg transmits full-precision models in both directions.
    let model_bits = (d * 32) as u64;

    for t in 0..cfg.rounds {
        let round_t0 = ctx.tracer.start();
        let round_sim0 = now;
        let select_t0 = ctx.tracer.start();
        let sampled = ctx.select_clients(now);
        ctx.tracer.span("select", select_t0, t as u64, 0.0, now);
        if cfg.track_selection {
            metrics.selections.push((now, sampled.clone()));
        }
        if sampled.len() < cfg.s {
            metrics.short_rounds += 1;
        }
        if sampled.is_empty() {
            // Nobody reachable: the server idles one interaction slot.
            now += cfg.timing.sit;
            ctx.tracker.advance_round();
            tel.gauge_set(names::SELECT_CHI2, ctx.tracker.selection_bias_chi2());
            tel.gauge_set(names::GINI, ctx.tracker.participation_gini());
            if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
                ctx.eval_point(&mut metrics, t + 1, now, &tally, &x_server)?;
            }
            ctx.emit_counters(t as u64, now, &tally, None);
            tel.flush(&ctx.tracer, t as u64, now);
            ctx.tracer.span("round", round_t0, t as u64, now - round_sim0, now);
            continue;
        }

        // `--broadcast-downlink`: one shared-medium transmission — all
        // sampled clients receive at the slowest sampled link's time, one
        // payload charged per round. None = the default per-client
        // unicast pricing (bit-exact legacy path).
        let bcast_t0 = ctx.tracer.start();
        let bcast_t = if cfg.broadcast_downlink {
            let slowest = sampled
                .iter()
                .map(|&i| ctx.transport.downlink_time(i, model_bits))
                .fold(0.0, f64::max);
            if ctx.fault.is_none() {
                tally.bits_down += model_bits;
                tally.comm_down_time += slowest;
            }
            // Under chaos the shared medium only sets the per-client base
            // link time; retransmissions are unicast re-sends, so the
            // armed pre-pass charges bits per client per attempt.
            Some(slowest)
        } else {
            None
        };

        // Synchronous barrier: the round takes as long as the slowest
        // sampled client needs to receive the model, run its K steps, and
        // push the result back. Pre-pass advances clocks and snapshots
        // each client's K-step burst from X_t.
        let mut round_end = now;
        // One broadcast snapshot shared by every sampled client's task;
        // each worker deep-copies it once for its K-step burst.
        let x_round = Arc::new(x_server.clone());
        if ctx.fault.is_some() {
            ctx.tracer.span("broadcast", bcast_t0, t as u64, 0.0, now);
            round_end = faulted_round(
                ctx, t, now, &sampled, bcast_t, model_bits, &x_round,
                &mut x_server, &mut metrics, &mut tally, &mut tel,
            )?;
            now = round_end + cfg.timing.sit;
            ctx.tracker.advance_round();
            tel.gauge_set(names::SELECT_CHI2, ctx.tracker.selection_bias_chi2());
            tel.gauge_set(names::GINI, ctx.tracker.participation_gini());
            if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
                ctx.eval_point(&mut metrics, t + 1, now, &tally, &x_server)?;
            }
            ctx.emit_counters(t as u64, now, &tally, None);
            tel.flush(&ctx.tracer, t as u64, now);
            ctx.tracer.span("round", round_t0, t as u64, now - round_sim0, now);
            continue;
        }
        let mut tasks = Vec::with_capacity(sampled.len());
        for &i in &sampled {
            let down_t = match bcast_t {
                Some(slowest) => slowest,
                None => ctx.transport.downlink_time(i, model_bits),
            };
            let up_t = ctx.transport.uplink_time(i, model_bits);
            ctx.clocks[i].restart(now + down_t);
            let finish = ctx.clocks[i].finish_time_for(cfg.k) + up_t;
            round_end = round_end.max(finish);

            metrics.total_interactions += 1;
            metrics.sum_observed_steps += cfg.k as u64;
            tally.total_steps += cfg.k as u64;
            if bcast_t.is_none() {
                tally.bits_down += model_bits;
                tally.comm_down_time += down_t;
            }
            tally.bits_up += model_bits;
            tally.comm_up_time += up_t;

            ctx.tracer.sample("delay", t as u64, down_t + up_t);
            tel.observe(names::DELAY, down_t + up_t);
            tasks.push(make_task(ctx, i, x_round.clone(), cfg.k, cfg.lr));
        }
        ctx.tracer.span("broadcast", bcast_t0, t as u64, 0.0, now);

        // Fan out the K-step bursts; average in sampled order (weights
        // follow the realized sample size, == s whenever all reachable).
        let sgd_t0 = ctx.tracer.start();
        let results = ctx.pool.run_local_sgd(tasks)?;
        ctx.tracer.span("local_sgd", sgd_t0, t as u64, 0.0, now);
        // Reduction-boundary high-water mark (same boundary QuAFL and
        // FedBuff measure at): the shared broadcast snapshot plus the s
        // returned client models held for averaging.
        tally.peak_model_bytes = tally
            .peak_model_bytes
            .max(((results.len() + 1) * d * 4) as u64);
        let reduce_t0 = ctx.tracer.start();
        let mut sum = vec![0f32; d];
        for r in &results {
            params::axpy(&mut sum, 1.0 / sampled.len() as f32, &r.params);
            // Selection-policy bookkeeping (no RNG, no trajectory float):
            // FedAvg clients are stateless, so a participation doubles as
            // a snapshot refresh; the mean per-step loss feeds loss-poc.
            ctx.tracker.record_participation(r.client_id, now);
            ctx.tracker.note_snapshot(r.client_id);
            if r.steps > 0 {
                let mean_loss = r.loss as f64 / r.steps as f64;
                ctx.tracker.note_loss(r.client_id, mean_loss);
                tel.observe(names::CLIENT_LOSS, mean_loss);
                tel.observe_sampled(names::CLIENT_LOSS, mean_loss);
            }
        }
        x_server = sum;
        ctx.tracer.span("reduce", reduce_t0, t as u64, 0.0, now);
        now = round_end + cfg.timing.sit;
        ctx.tracker.advance_round();
        tel.gauge_set(names::SELECT_CHI2, ctx.tracker.selection_bias_chi2());
        tel.gauge_set(names::GINI, ctx.tracker.participation_gini());

        if (t + 1) % cfg.eval_every == 0 || t + 1 == cfg.rounds {
            ctx.eval_point(&mut metrics, t + 1, now, &tally, &x_server)?;
        }
        ctx.emit_counters(t as u64, now, &tally, None);
        tel.flush(&ctx.tracer, t as u64, now);
        ctx.tracer.span("round", round_t0, t as u64, now - round_sim0, now);
    }
    Ok(metrics)
}

/// One synchronous round under chaos ([`crate::fault`]): both directions
/// of every exchange run through the fault engine (fp32 messages carry no
/// byte payload, so corruption is the bernoulli frame-failure draw),
/// stragglers pay a link-time multiplier, clients crash after their
/// K-step burst (wasted compute priced; repeat offenders evicted), and
/// the `--round-deadline` quorum rule decides which returned models the
/// equal-weight average accepts — arrival-reweighted to 1/accepted.
/// Returns the round-end time (the cutoff under a deadline; the last
/// retry chain otherwise). A fully degraded round keeps X_t.
#[allow(clippy::too_many_arguments)]
fn faulted_round(
    ctx: &mut FlRun,
    t: usize,
    now: f64,
    sampled: &[usize],
    bcast_t: Option<f64>,
    model_bits: u64,
    x_round: &Arc<Vec<f32>>,
    x_server: &mut Vec<f32>,
    metrics: &mut RunMetrics,
    tally: &mut CommTally,
    tel: &mut Telemetry,
) -> Result<f64> {
    use crate::fault::LinkDir;

    let round = t as u64;
    let k = ctx.cfg.k;
    let d = x_server.len();
    let mut tasks = Vec::new();
    /// per-arrived-result context, aligned with `tasks`
    struct Arrived {
        arrival: f64,
        compute_s: f64,
    }
    let mut arrived = Vec::new();
    let mut arrivals = Vec::new();
    let mut max_elapsed = 0f64;
    for &i in sampled {
        metrics.total_interactions += 1;
        let mult = ctx.fault.as_ref().unwrap().slow_mult(i);
        let down_link = match bcast_t {
            Some(slowest) => slowest,
            None => ctx.transport.downlink_time(i, model_bits),
        } * mult;
        let down = ctx.fault.as_mut().unwrap().deliver(
            round,
            i,
            LinkDir::Down,
            down_link,
            model_bits,
            None,
        );
        tally.bits_down += model_bits * down.attempts as u64;
        tally.comm_down_time += down.time;
        if !down.delivered {
            // The client never received the round model — it idles.
            max_elapsed = max_elapsed.max(down.time);
            metrics.zero_progress_interactions += 1;
            continue;
        }
        // The client runs its synchronous K-step burst.
        ctx.clocks[i].restart(now + down.time);
        let finish = ctx.clocks[i].finish_time_for(k);
        let compute_s = finish - (now + down.time);
        metrics.sum_observed_steps += k as u64;
        tally.total_steps += k as u64;
        if ctx.fault.as_ref().unwrap().crashes(round, i) {
            // Crash after the burst, before upload.
            let fe = ctx.fault.as_mut().unwrap();
            fe.waste(compute_s, 0);
            let evicted = fe.record_crash(i);
            tally.wasted_compute_time += compute_s;
            if evicted {
                ctx.availability.evict(i);
            }
            max_elapsed = max_elapsed.max(finish - now);
            continue;
        }
        let up_link = ctx.transport.uplink_time(i, model_bits) * mult;
        let up = ctx.fault.as_mut().unwrap().deliver(
            round,
            i,
            LinkDir::Up,
            up_link,
            model_bits,
            None,
        );
        tally.bits_up += model_bits * up.attempts as u64;
        tally.comm_up_time += up.time;
        let elapsed = finish - now + up.time;
        max_elapsed = max_elapsed.max(elapsed);
        if up.delivered {
            arrivals.push(elapsed);
            ctx.tracer.sample("delay", round, down.time + up.time);
            tel.observe(names::DELAY, down.time + up.time);
            arrived.push(Arrived { arrival: elapsed, compute_s });
            tasks.push(make_task(ctx, i, x_round.clone(), k, ctx.cfg.lr));
        } else {
            tally.wasted_up_bits += model_bits * up.attempts as u64;
            tally.wasted_compute_time += compute_s;
        }
    }

    // Quorum/deadline close over what actually arrived.
    let cutoff = ctx.fault.as_mut().unwrap().quorum_cutoff(&arrivals).0;
    let round_end = if ctx.cfg.fault.round_deadline > 0.0 {
        now + cutoff
    } else {
        now + max_elapsed.max(cutoff)
    };

    let sgd_t0 = ctx.tracer.start();
    let results = ctx.pool.run_local_sgd(tasks)?;
    ctx.tracer.span("local_sgd", sgd_t0, round, 0.0, now);
    tally.peak_model_bytes = tally
        .peak_model_bytes
        .max(((results.len() + 1) * d * 4) as u64);

    let reduce_t0 = ctx.tracer.start();
    let accepted_n =
        arrived.iter().filter(|a| a.arrival <= cutoff).count();
    let mut sum = vec![0f32; d];
    for (a, r) in arrived.iter().zip(&results) {
        // The server received the model either way; participation and
        // loss history update even for a deadline-missed arrival.
        ctx.tracker.record_participation(r.client_id, now);
        ctx.tracker.note_snapshot(r.client_id);
        if r.steps > 0 {
            let mean_loss = r.loss as f64 / r.steps as f64;
            ctx.tracker.note_loss(r.client_id, mean_loss);
            tel.observe(names::CLIENT_LOSS, mean_loss);
            tel.observe_sampled(names::CLIENT_LOSS, mean_loss);
        }
        if a.arrival <= cutoff {
            params::axpy(&mut sum, 1.0 / accepted_n as f32, &r.params);
        } else {
            // Arrived past the cutoff: the average excludes it.
            tally.wasted_up_bits += model_bits;
            tally.wasted_compute_time += a.compute_s;
        }
    }
    if accepted_n > 0 {
        *x_server = sum;
    }
    ctx.tracer.span("reduce", reduce_t0, round, 0.0, now);
    Ok(round_end)
}

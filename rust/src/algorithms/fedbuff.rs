//! FedBuff [30] — buffered asynchronous aggregation, the SOTA async
//! baseline the paper compares against (Figures 6 and 16).
//!
//! Clients run free: each pulls the current server model, performs exactly
//! K local steps at its own speed, and pushes the update
//! Δ = X_pulled − X_local at its finish time (optionally QSGD-compressed —
//! FedBuff has no decoding key, so the *lattice* scheme is inapplicable,
//! exactly as the paper notes). The server accumulates updates in a buffer
//! of size Z; when full it applies X ← X − η_g·mean(Δ) and the round
//! counter advances.
//!
//! The paper's qualitative claim reproduced here: under heterogeneous
//! speeds slow clients contribute systematically fewer buffer entries, so
//! with non-i.i.d. data the model skews toward fast clients' distributions
//! (QuAFL instead folds in partial progress from everyone).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::Result;

use super::local_sgd;
use crate::config::QuantizerKind;
use crate::coordinator::FlRun;
use crate::metrics::RunMetrics;
use crate::model::params;
use crate::quant::{QsgdQuantizer, Quantizer};
use crate::util::rng::derive_seed;

/// Event-queue entry: client `id` finishes its K steps at `time`.
#[derive(PartialEq)]
struct Finish {
    time: f64,
    id: usize,
}

impl Eq for Finish {}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.id.cmp(&other.id))
    }
}

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let d = ctx.engine.spec().num_params();
    let mut metrics = RunMetrics::new("fedbuff");

    // FedBuff compresses *updates* with QSGD when quantization is on;
    // lattice is structurally incompatible (no key), mirroring the paper.
    let up_quant: Option<QsgdQuantizer> = match cfg.quantizer {
        QuantizerKind::Qsgd { bits } | QuantizerKind::Lattice { bits } => {
            Some(QsgdQuantizer::new(bits))
        }
        QuantizerKind::None => None,
    };

    let mut x_server = ctx.engine.spec().init_params(derive_seed(cfg.seed, 0x1417));
    // Every client starts computing on the init model at time 0.
    let mut pulled: Vec<Vec<f32>> = vec![x_server.clone(); cfg.n];
    let mut queue: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    for i in 0..cfg.n {
        ctx.clocks[i].restart(0.0);
        let t = ctx.clocks[i].finish_time_for(cfg.k);
        queue.push(Reverse(Finish { time: t, id: i }));
    }

    let mut buffer: Vec<Vec<f32>> = Vec::with_capacity(cfg.fedbuff_buffer);
    let mut now = 0f64;
    let mut bits_up = 0u64;
    let mut bits_down = 0u64;
    let mut total_steps = 0u64;
    let model_bits = (d * 32) as u64;
    let mut aggregations = 0usize;
    let mut msg_counter = 0u64;

    ctx.eval_point(&mut metrics, 0, now, 0, 0, 0, &x_server)?;

    while aggregations < cfg.rounds {
        let Reverse(Finish { time, id }) = queue.pop().expect("queue non-empty");
        now = time;

        // Client `id` finished K steps on its pulled snapshot: materialize.
        let mut x_local = pulled[id].clone();
        local_sgd(ctx, id, &mut x_local, cfg.k)?;
        total_steps += cfg.k as u64;
        metrics.total_interactions += 1;
        metrics.sum_observed_steps += cfg.k as u64;

        // Δ = pulled - local (a descent direction scaled by η·h̃).
        let mut delta = params::sub(&pulled[id], &x_local);
        if let Some(q) = &up_quant {
            msg_counter += 1;
            let msg = q.encode(&delta, derive_seed(cfg.seed, 0xFB0F ^ msg_counter));
            bits_up += msg.bits as u64;
            delta = q.decode(&msg, &delta);
        } else {
            bits_up += model_bits;
        }
        buffer.push(delta);

        // Client pulls the current model (uncompressed, as in [30]) and
        // restarts immediately.
        pulled[id] = x_server.clone();
        bits_down += model_bits;
        ctx.clocks[id].restart(now);
        let t_next = ctx.clocks[id].finish_time_for(cfg.k);
        queue.push(Reverse(Finish { time: t_next, id }));

        // Server aggregates when the buffer fills.
        if buffer.len() >= cfg.fedbuff_buffer {
            let scale = cfg.fedbuff_server_lr / buffer.len() as f32;
            for delta in buffer.drain(..) {
                params::axpy(&mut x_server, -scale, &delta);
            }
            aggregations += 1;
            now += cfg.timing.sit;

            if aggregations % cfg.eval_every == 0 || aggregations == cfg.rounds {
                ctx.eval_point(
                    &mut metrics,
                    aggregations,
                    now,
                    total_steps,
                    bits_up,
                    bits_down,
                    &x_server,
                )?;
            }
        }
    }
    Ok(metrics)
}

//! FedBuff [30] — buffered asynchronous aggregation, the SOTA async
//! baseline the paper compares against (Figures 6 and 16).
//!
//! Clients run free: each pulls the current server model, performs exactly
//! K local steps at its own speed, and pushes the update
//! Δ = X_pulled − X_local (optionally QSGD-compressed — FedBuff has no
//! decoding key, so the *lattice* scheme is inapplicable, exactly as the
//! paper notes). The server accumulates updates in a buffer of size Z;
//! when full it applies X ← X − η_g·mean(Δ) and the round counter
//! advances.
//!
//! Transport integration: a push *arrives* at its finish time plus the
//! client's uplink time for the Δ's exact encoded size (QSGD sizes are a
//! deterministic function of the dimension — `Quantizer::encoded_bits`,
//! property-tested against the encoder in rust/tests/net_parity.rs — so
//! the arrival is known when the event is scheduled); the re-pull starts
//! after the model's downlink time, delayed to the client's next
//! availability window if it churned off. Buffer order is *arrival*
//! order. Under the default `Ideal` network every term is exactly 0.0 and
//! the pre-net event schedule is reproduced bit for bit.
//!
//! Memory: pulled snapshots live in the CoW fleet store
//! ([`crate::fleet`]) — every client pulling between the same two
//! aggregations shares *one* allocation of the server snapshot current at
//! its pull (instead of each `x_server.clone()`), so resident
//! client-model bytes scale with the number of referenced snapshots, not
//! with n.
//!
//! Parallel structure: the server model only changes at aggregation
//! boundaries, so the Z arrival-events that fill one buffer are fully
//! determined (which client, from which pulled snapshot, on which batches)
//! *before* any of their SGD runs. The event-queue walk stays serial —
//! popping events, advancing clocks, drawing batches, assigning per-
//! message compression seeds in event order — and the Z K-step bursts +
//! Δ compression fan out through [`crate::exec`]; the buffer then applies
//! in event order (bit-identical to the serial path). A fast client can
//! legitimately appear twice in one buffer; both its bursts land in event
//! order because its batches were drawn serially.
//!
//! Selection integration ([`crate::select`]): FedBuff has no per-round
//! sampling step, so the policy acts as an **admission gate** on
//! arrivals. Under the default `Uniform` policy every push is admitted
//! without consuming randomness — the bit-exact legacy path. Non-uniform
//! policies may reject a push (`StalenessAware` drops updates whose
//! pulled snapshot is older than the cap in aggregations — FADAS-style
//! bounded staleness; `Fairness` holds fast clients to a one-participation
//! quota lead; `LossPoc` gates on the tracked-loss median): the compute
//! and uplink are already spent and stay charged, the Δ is simply never
//! aggregated, and the client re-pulls and restarts — so its next push
//! is fresh and the event loop cannot livelock. Rejections are counted
//! in `RunMetrics::rejected_interactions`.
//!
//! The paper's qualitative claim reproduced here: under heterogeneous
//! speeds slow clients contribute systematically fewer buffer entries, so
//! with non-i.i.d. data the model skews toward fast clients' distributions
//! (QuAFL instead folds in partial progress from everyone).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use anyhow::Result;

use super::make_task;
use crate::config::QuantizerKind;
use crate::coordinator::FlRun;
use crate::engine::TrainEngine;
use crate::metrics::{CommTally, RunMetrics};
use crate::model::params;
use crate::quant::{QsgdQuantizer, Quantizer};
use crate::telemetry::{names, probe::DivergenceProbe, Telemetry};
use crate::util::rng::derive_seed;
use crate::util::stats::l2_dist;

/// Event-queue entry: client `id`'s push arrives at the server at `time`.
#[derive(PartialEq)]
struct Finish {
    time: f64,
    id: usize,
}

impl Eq for Finish {}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.id.cmp(&other.id))
    }
}

pub fn run(ctx: &mut FlRun) -> Result<RunMetrics> {
    let cfg = ctx.cfg.clone();
    let d = ctx.spec.num_params();
    let mut metrics = RunMetrics::new("fedbuff");

    // FedBuff compresses *updates* with QSGD when quantization is on;
    // lattice is structurally incompatible (no key), mirroring the paper.
    let up_quant: Option<QsgdQuantizer> = match cfg.quantizer {
        QuantizerKind::Qsgd { bits } | QuantizerKind::Lattice { bits } => {
            Some(QsgdQuantizer::new(bits))
        }
        QuantizerKind::None => None,
    };

    let model_bits = (d * 32) as u64;
    // Exact wire size of one Δ push — deterministic given d, so arrival
    // times can be scheduled before the payload exists.
    let delta_bits = match &up_quant {
        Some(q) => q.encoded_bits(d) as u64,
        None => model_bits,
    };

    let mut x_server = ctx.spec.init_params(derive_seed(cfg.seed, 0x1417));
    // Pulled snapshots live in the CoW fleet store: every client
    // references the shared init until it re-pulls, and clients pulling
    // between the same two aggregations share one server-snapshot
    // allocation ([`crate::fleet`]).
    let mut fleet = ctx.fleet_store(x_server.clone());
    // The snapshot clients pull until the next aggregation — starts as
    // the store's shared base (the init).
    let mut server_snap: Arc<Vec<f32>> = fleet.snapshot(0);

    // Convergence diagnostics (L3-telemetry): FedBuff never records
    // `metrics.potential`, so the Φ_t/discrepancy probe exists only for
    // the armed metric stream. Incremental O(touched·d) maintenance —
    // every pull is a "write" of the shared snapshot.
    let tel_armed = ctx.telemetry_armed();
    let mut tel = Telemetry::new(tel_armed, cfg.seed);
    let mut probe =
        tel_armed.then(|| DivergenceProbe::new(x_server.clone(), cfg.n));

    let mut now = 0f64;
    // At t=0 the live snapshot aliases the store's base, so the store's
    // own count is the whole resident set.
    let mut tally = CommTally {
        peak_model_bytes: fleet.peak_bytes(),
        ..Default::default()
    };

    // Every client starts computing on the init model at time 0 (the
    // initial broadcast is free by default, matching the paper's setup;
    // `--price-init-broadcast` charges it and delays each client's first
    // burst by its own downlink time).
    let mut queue: BinaryHeap<Reverse<Finish>> = BinaryHeap::new();
    for i in 0..cfg.n {
        let recv = if cfg.price_init_broadcast {
            let t = ctx.transport.downlink_time(i, model_bits);
            tally.bits_down += model_bits;
            tally.comm_down_time += t;
            t
        } else {
            0.0
        };
        ctx.clocks[i].restart(recv);
        // Under chaos the uplink is priced at pop time through the fault
        // engine (retries shift the arrival), so the scheduled event is
        // the bare compute finish.
        let t = if ctx.fault.is_some() {
            ctx.clocks[i].finish_time_for(cfg.k)
        } else {
            ctx.clocks[i].finish_time_for(cfg.k)
                + ctx.transport.uplink_time(i, delta_bits)
        };
        queue.push(Reverse(Finish { time: t, id: i }));
    }

    let mut aggregations = 0usize;
    let mut msg_counter = 0u64;

    ctx.eval_point(&mut metrics, 0, now, &tally, &x_server)?;

    while aggregations < cfg.rounds {
        let agg = aggregations as u64;
        let round_t0 = ctx.tracer.start();
        let round_sim0 = now;
        // Serial event-queue walk: pop the Z arrivals that fill this
        // buffer, in arrival order. Each popped client materializes its
        // burst (start snapshot + batch draws) and immediately re-pulls
        // the current server model and restarts — delayed by the model's
        // downlink time, and by the client's next availability window if
        // it churned off.
        let select_t0 = ctx.tracer.start();
        let mut tasks = Vec::with_capacity(cfg.fedbuff_buffer);
        if ctx.fault.is_some() {
            faulted_fill(
                ctx, agg, round_sim0, &mut now, &mut queue, &mut tasks,
                &mut fleet, &server_snap, &mut probe, &mut tel, &mut tally,
                &mut metrics, &mut msg_counter, delta_bits, model_bits,
                up_quant.is_some(),
            );
            if tasks.len() < cfg.fedbuff_buffer {
                metrics.short_rounds += 1;
            }
            if tasks.is_empty() {
                // The whole fleet is dead: degrade by ending the run at
                // the last completed aggregation instead of hanging.
                ctx.tracer.span("select", select_t0, agg, now - round_sim0, now);
                break;
            }
        } else {
        while tasks.len() < cfg.fedbuff_buffer {
            let Reverse(Finish { time, id }) = queue.pop().expect("queue non-empty");
            now = time;
            // Admission gate ([`crate::select`]): the default `Uniform`
            // policy admits every arrival without touching the RNG (the
            // bit-exact legacy path); staleness/fairness/loss policies
            // may drop the update — see the module docs.
            let admitted = ctx.admit_update(now, id);
            metrics.total_interactions += 1;
            metrics.sum_observed_steps += cfg.k as u64;
            tally.total_steps += cfg.k as u64;

            if admitted {
                // Client `id` finished K steps on its pulled snapshot;
                // its burst joins the buffer fan-out. The staleness of the
                // admitted update is sampled before the re-pull below
                // refreshes the client's snapshot.
                ctx.tracer
                    .sample("staleness", agg, ctx.tracker.staleness(id) as f64);
                tel.observe(names::STALENESS, ctx.tracker.staleness(id) as f64);
                let start = fleet.snapshot(id);
                let mut task = make_task(ctx, id, start, cfg.k, cfg.lr);
                if up_quant.is_some() {
                    msg_counter += 1;
                    task.seed = derive_seed(cfg.seed, 0xFB0F ^ msg_counter);
                }
                tasks.push(task);
                ctx.tracker.record_participation(id, now);
                if cfg.track_selection {
                    metrics.selections.push((now, vec![id]));
                }
            } else {
                // Rejected: the compute and the transmission already
                // happened — the Δ's exact wire bits stay charged (the
                // admitted path charges them at aggregation) — but the
                // update is never aggregated. The waste is priced too:
                // rejection's cost used to be invisible next to the
                // event-count `rejected_interactions`.
                metrics.rejected_interactions += 1;
                tally.bits_up += delta_bits;
                tally.wasted_up_bits += delta_bits;
                tally.wasted_compute_time += cfg.k as f64 / ctx.clocks[id].rate();
            }

            // Admitted or not, the client pulls the current model
            // (uncompressed, as in [30]) and restarts. The pull aliases
            // the shared server snapshot — no model floats are copied
            // here — and refreshes the client's snapshot epoch.
            if let Some(p) = probe.as_mut() {
                p.note_write(fleet.get(id), server_snap.as_slice());
            }
            fleet.set_shared(id, server_snap.clone());
            ctx.tracker.note_snapshot(id);
            let down_t = ctx.transport.downlink_time(id, model_bits);
            let up_t = ctx.transport.uplink_time(id, delta_bits);
            ctx.tracer.sample("delay", agg, down_t + up_t);
            tel.observe(names::DELAY, down_t + up_t);
            tally.bits_down += model_bits;
            tally.comm_down_time += down_t;
            tally.comm_up_time += up_t;
            let resume = ctx.availability.next_up(id, now);
            ctx.clocks[id].restart(resume + down_t);
            let t_next = ctx.clocks[id].finish_time_for(cfg.k) + up_t;
            queue.push(Reverse(Finish { time: t_next, id }));
        }
        }
        ctx.tracer.span("select", select_t0, agg, now - round_sim0, now);

        // High-water measurement at the buffer boundary, where residency
        // peaks: store residents + the live pull snapshot + popped start
        // snapshots that already left the store but are still alive in
        // the tasks (deduplicated by allocation — several tasks can hold
        // the same epoch snapshot). Worker-side SGD scratch copies are
        // deliberately excluded: transient compute state, identical under
        // the dense layout.
        let mut extra: Vec<usize> = tasks
            .iter()
            .filter(|t| !fleet.is_resident(&t.params))
            .map(|t| Arc::as_ptr(&t.params) as usize)
            .collect();
        if !fleet.is_resident(&server_snap) {
            extra.push(Arc::as_ptr(&server_snap) as usize);
        }
        extra.sort_unstable();
        extra.dedup();
        tally.peak_model_bytes = tally
            .peak_model_bytes
            .max(fleet.resident_bytes() + (extra.len() * d * 4) as u64)
            .max(fleet.peak_bytes());

        // Fan out the Z bursts; each worker also forms and (optionally)
        // compresses its Δ = pulled − local with its pre-assigned seed.
        let sgd_t0 = ctx.tracer.start();
        let up_quant_ref = up_quant.as_ref();
        let deltas = ctx.pool.map(tasks, |engine: &mut dyn TrainEngine, task| {
            let id = task.client_id;
            // Deep-copy the shared pulled snapshot for the SGD burst —
            // the fan-out's single materialization point.
            let mut x_local = (*task.params).clone();
            let loss = engine.train_steps(&mut x_local, &task.batches, task.lr)?;
            // Δ = pulled - local (a descent direction scaled by η·h̃).
            let mut delta = params::sub(task.params.as_slice(), &x_local);
            let (bits, qerr) = if let Some(q) = up_quant_ref {
                let msg = q.encode(&delta, task.seed);
                let b = msg.bits as u64;
                let decoded = q.decode(&msg, &delta);
                // Roundtrip quantization error of the compressed Δ —
                // telemetry-only, never folded into the trajectory.
                let e = tel_armed.then(|| l2_dist(&delta, &decoded));
                delta = decoded;
                (b, e)
            } else {
                (model_bits, None)
            };
            Ok((id, delta, bits, loss, qerr))
        })?;
        ctx.tracer.span("local_sgd", sgd_t0, agg, 0.0, now);

        // Server aggregates the full buffer, applying Δs in event order.
        let reduce_t0 = ctx.tracer.start();
        // Arrival-reweighting: an early quorum close aggregates fewer
        // than Z deltas and the mean follows the realized count.
        let scale = cfg.fedbuff_server_lr / deltas.len() as f32;
        let armed = ctx.fault.is_some();
        for (id, delta, bits, loss, qerr) in deltas {
            if !armed {
                // Armed runs charged the push (with its retries) at
                // delivery time in `faulted_fill`.
                tally.bits_up += bits;
            }
            params::axpy(&mut x_server, -scale, &delta);
            // Tracker observation for the loss-aware policies (pure
            // bookkeeping — no RNG, no trajectory float).
            let mean_loss = loss as f64 / cfg.k as f64;
            ctx.tracker.note_loss(id, mean_loss);
            if let Some(e) = qerr {
                tel.observe(names::QERR, e);
            }
            tel.observe(names::CLIENT_LOSS, mean_loss);
            tel.observe_sampled(names::CLIENT_LOSS, mean_loss);
        }
        ctx.tracer.span("reduce", reduce_t0, agg, 0.0, now);
        aggregations += 1;
        now += cfg.timing.sit;
        // The aggregation is FedBuff's "round": age every snapshot in
        // both the tracker and the fleet store. The two derive the same
        // staleness by construction — every pull stamps both (above) and
        // the counters only advance here, together.
        ctx.tracker.advance_round();
        fleet.advance_epoch();
        debug_assert_eq!(
            ctx.tracker.round(),
            fleet.current_epoch(),
            "tracker round and fleet epoch must advance in lockstep"
        );
        // Clients pulling from here until the next aggregation share this
        // snapshot: one allocation, not Z (or n) clones of x_server. It
        // is fresh, so at this instant it is exactly one allocation on
        // top of the store's residents.
        server_snap = Arc::new(x_server.clone());
        tally.peak_model_bytes = tally
            .peak_model_bytes
            .max(fleet.resident_bytes() + (d * 4) as u64)
            .max(fleet.peak_bytes());

        if let Some(p) = probe.as_ref() {
            tel.gauge_set(names::PHI, p.potential(&x_server));
            tel.gauge_set(names::DISCREPANCY, p.discrepancy(&x_server));
        }
        tel.gauge_set(names::SELECT_CHI2, ctx.tracker.selection_bias_chi2());
        tel.gauge_set(names::GINI, ctx.tracker.participation_gini());

        if aggregations % cfg.eval_every == 0 || aggregations == cfg.rounds {
            ctx.eval_point(&mut metrics, aggregations, now, &tally, &x_server)?;
        }
        ctx.emit_counters(agg, now, &tally, Some(&fleet));
        tel.flush(&ctx.tracer, agg, now);
        ctx.tracer.span("round", round_t0, agg, now - round_sim0, now);
    }
    Ok(metrics)
}

/// The event-queue walk under chaos ([`crate::fault`]): fills the buffer
/// through the fault engine instead of the legacy pop loop. Scheduled
/// events carry the bare compute finish; the uplink (Δ push, framed when
/// compressed) is delivered at pop time with retry/backoff, so a retried
/// push admits late — `now` advances to the delivered arrival and never
/// rewinds past a later pop. Clients crash at push time (wasted burst
/// priced; repeat offenders evicted and never re-queued — the queue
/// permanently forgets them), a failed re-pull leaves the client
/// computing on its stale snapshot, and a `--round-deadline` closes the
/// buffer early K-of-Z quorum-style once the next arrival would land
/// past the deadline (the aggregation mean reweights to the realized
/// count). Admission-rejected pushes price their waste exactly like the
/// legacy path.
#[allow(clippy::too_many_arguments)]
fn faulted_fill(
    ctx: &mut FlRun,
    agg: u64,
    round_sim0: f64,
    now: &mut f64,
    queue: &mut BinaryHeap<Reverse<Finish>>,
    tasks: &mut Vec<crate::exec::ClientTask>,
    fleet: &mut crate::fleet::ClientModelStore,
    server_snap: &Arc<Vec<f32>>,
    probe: &mut Option<DivergenceProbe>,
    tel: &mut Telemetry,
    tally: &mut CommTally,
    metrics: &mut RunMetrics,
    msg_counter: &mut u64,
    delta_bits: u64,
    model_bits: u64,
    compress: bool,
) {
    use crate::fault::LinkDir;
    use crate::quant::FRAME_HEADER_BITS;

    let k = ctx.cfg.k;
    let lr = ctx.cfg.lr;
    let buffer = ctx.cfg.fedbuff_buffer;
    let deadline = ctx.cfg.fault.round_deadline;
    let quorum = ctx.cfg.fault.quorum;
    let track_selection = ctx.cfg.track_selection;
    // Only quantized payloads are checksum-framed; raw fp32 Δs are not.
    let push_bits = delta_bits
        + if compress { FRAME_HEADER_BITS as u64 } else { 0 };
    while tasks.len() < buffer {
        // Early quorum close: at quorum strength the server aggregates
        // what it holds rather than waiting past its deadline.
        if deadline > 0.0 && !tasks.is_empty() && tasks.len() >= quorum {
            if let Some(Reverse(peek)) = queue.peek() {
                if peek.time - round_sim0 > deadline {
                    let fe = ctx.fault.as_mut().unwrap();
                    fe.counters.deadline_misses +=
                        (buffer - tasks.len()) as u64;
                    break;
                }
            }
        }
        let Some(Reverse(Finish { time, id })) = queue.pop() else {
            break; // every client evicted — nothing left to wait for
        };
        if deadline > 0.0
            && time - round_sim0 > deadline
            && tasks.len() < quorum
        {
            // Below quorum the server waits out its deadline for more.
            ctx.fault.as_mut().unwrap().counters.quorum_waits += 1;
        }
        *now = time.max(*now);
        metrics.total_interactions += 1;
        metrics.sum_observed_steps += k as u64;
        tally.total_steps += k as u64;
        let compute_s = k as f64 / ctx.clocks[id].rate();

        let mut push_ok = false;
        let mut evicted = false;
        if ctx.fault.as_ref().unwrap().crashes(agg, id) {
            // Crash at push time: the K-step burst is lost.
            let fe = ctx.fault.as_mut().unwrap();
            fe.waste(compute_s, 0);
            evicted = fe.record_crash(id);
            tally.wasted_compute_time += compute_s;
            if evicted {
                ctx.availability.evict(id);
            }
        } else {
            let mult = ctx.fault.as_ref().unwrap().slow_mult(id);
            let up_link = ctx.transport.uplink_time(id, push_bits) * mult;
            let up = ctx.fault.as_mut().unwrap().deliver(
                agg,
                id,
                LinkDir::Up,
                up_link,
                push_bits,
                None,
            );
            tally.bits_up += push_bits * up.attempts as u64;
            tally.comm_up_time += up.time;
            // The retried push admits at its delivered arrival, which
            // can land past the next scheduled pop — never rewind.
            *now = (time + up.time).max(*now);
            if up.delivered {
                push_ok = true;
            } else {
                tally.wasted_up_bits += push_bits * up.attempts as u64;
                tally.wasted_compute_time += compute_s;
            }
        }

        let admitted = push_ok && ctx.admit_update(*now, id);
        if admitted {
            ctx.tracer
                .sample("staleness", agg, ctx.tracker.staleness(id) as f64);
            tel.observe(names::STALENESS, ctx.tracker.staleness(id) as f64);
            let start = fleet.snapshot(id);
            let mut task = make_task(ctx, id, start, k, lr);
            if compress {
                *msg_counter += 1;
                task.seed = derive_seed(ctx.cfg.seed, 0xFB0F ^ *msg_counter);
            }
            tasks.push(task);
            ctx.tracker.record_participation(id, *now);
            if track_selection {
                metrics.selections.push((*now, vec![id]));
            }
        } else if push_ok {
            // Delivered but admission-rejected: same waste pricing as
            // the legacy rejected path (bits were charged at delivery).
            metrics.rejected_interactions += 1;
            tally.wasted_up_bits += push_bits;
            tally.wasted_compute_time += compute_s;
        }

        // Re-pull and restart — unless the client is permanently dead.
        if !evicted {
            let mult = ctx.fault.as_ref().unwrap().slow_mult(id);
            let down_link =
                ctx.transport.downlink_time(id, model_bits) * mult;
            let down = ctx.fault.as_mut().unwrap().deliver(
                agg,
                id,
                LinkDir::Down,
                down_link,
                model_bits,
                None,
            );
            tally.bits_down += model_bits * down.attempts as u64;
            tally.comm_down_time += down.time;
            if down.delivered {
                if let Some(p) = probe.as_mut() {
                    p.note_write(fleet.get(id), server_snap.as_slice());
                }
                fleet.set_shared(id, server_snap.clone());
                ctx.tracker.note_snapshot(id);
            }
            // else: the pull failed for good — the client keeps its
            // stale snapshot and its next push is computed on it.
            ctx.tracer.sample("delay", agg, down.time);
            tel.observe(names::DELAY, down.time);
            let resume = ctx.availability.next_up(id, *now);
            ctx.clocks[id].restart(resume + down.time);
            let t_next = ctx.clocks[id].finish_time_for(k);
            queue.push(Reverse(Finish { time: t_next, id }));
        }
    }
}

//! Pluggable client-selection subsystem.
//!
//! The paper's analysis assumes the server samples `s` clients uniformly
//! per interaction, but its own system model — partial asynchrony plus
//! churn — is exactly the regime where *which* clients the server picks
//! dominates convergence. This subsystem makes the selection rule a
//! first-class, swappable component:
//!
//! - [`policy::SelectionPolicy`] — the trait every rule implements:
//!   `select(view, rng, s)` picks up to `s` distinct reachable clients,
//!   and `admit(view, rng, client)` gates event-driven buffer admission
//!   (FedBuff, which has no per-round sampling step).
//! - [`policy::SelectionView`] — what a policy may observe: the
//!   availability process (reachability at the current simulated time)
//!   and the [`tracker::ParticipationTracker`]'s per-client history.
//! - [`tracker::ParticipationTracker`] — server-side bookkeeping:
//!   participation counts, last-served simulated time, current snapshot
//!   staleness (rounds since the client's model snapshot), and the last
//!   observed local loss. It also computes the participation Gini
//!   coefficient and max/mean staleness surfaced in every CSV.
//!
//! Four policies ship ([`SelectionKind`], the `--select` CLI axis):
//!
//! - **`uniform`** (default) — a bit-exact wrapper over the pre-subsystem
//!   RNG path ([`crate::net::ClientAvailability::sample`]): same stream,
//!   same picks, so every existing trajectory is reproduced bit for bit
//!   (rust/tests/select_parity.rs).
//! - **`staleness`** — staleness-bounded participation: reachable clients
//!   whose model snapshot is at least `--select-cap` rounds old are
//!   selected first (oldest first); remaining slots are filled by a
//!   uniform draw. For FedBuff the cap becomes an admission bound:
//!   updates computed from a snapshot older than `cap` aggregations are
//!   dropped (FADAS-style bounded staleness, arXiv:2402.11198).
//! - **`fairness`** — min-participation quota: the `s` reachable clients
//!   with the fewest participations are chosen (random tie-break), which
//!   degenerates to round-robin under full availability. For FedBuff it
//!   admits an update only while the pusher is within one participation
//!   of the least-served reachable client.
//! - **`loss-poc`** — loss-proportional power-of-choice: sample a
//!   candidate set of `d = --select-candidates ≥ s` reachable clients,
//!   keep the `s` with the highest tracked local loss (never-observed
//!   clients rank highest, so the fleet is explored first). For FedBuff
//!   it admits updates whose tracked loss is at or above the reachable
//!   median.
//!
//! The coordinator owns one boxed policy per run (next to `transport` and
//! `availability` in [`crate::coordinator::FlRun`]); algorithms select
//! through [`crate::coordinator::FlRun::select_clients`] and record
//! outcomes into the tracker, so policies always see current history.

pub mod policy;
pub mod tracker;

pub use policy::{
    Fairness, LossPropPowerOfChoice, SelectionPolicy, SelectionView,
    StalenessAware, Uniform,
};
pub use tracker::ParticipationTracker;

use crate::util::cli::Args;

/// Default hard staleness cap (`--select staleness` without
/// `--select-cap`): about twice the n/s ≈ 10 expected uniform staleness
/// at the paper's n=300/s=30 fleet scale.
pub const DEFAULT_STALENESS_CAP: u64 = 20;

/// Which selection policy a run uses (`--select`).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum SelectionKind {
    /// uniform over reachable clients — the exact pre-subsystem path
    #[default]
    Uniform,
    /// oldest-snapshot-first with a hard staleness cap (`--select-cap`)
    StalenessAware { cap: u64 },
    /// min-participation quota / round-robin over reachable clients
    Fairness,
    /// power-of-choice over `--select-candidates` (None = 2·s) candidates,
    /// keeping the highest-loss `s`
    LossPoc { candidates: Option<usize> },
}

impl SelectionKind {
    /// CLI keys this subsystem owns (merged into the run/sweep key sets).
    pub const CLI_KEYS: &'static [&'static str] =
        &["select", "select-cap", "select-candidates"];

    pub fn name(&self) -> &'static str {
        match self {
            SelectionKind::Uniform => "uniform",
            SelectionKind::StalenessAware { .. } => "staleness",
            SelectionKind::Fairness => "fairness",
            SelectionKind::LossPoc { .. } => "loss-poc",
        }
    }

    pub fn is_uniform(&self) -> bool {
        *self == SelectionKind::Uniform
    }

    /// Build from CLI args (`--select NAME`, `--select-cap N`,
    /// `--select-candidates D`). Sub-keys are rejected when they do not
    /// apply to the chosen policy, so a silently-ignored knob cannot
    /// masquerade as a configured one.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        // Every selection key takes a value; a bare flag would otherwise
        // pass the typo guard and silently keep the Uniform default.
        for key in Self::CLI_KEYS {
            if args.flag(key) {
                return Err(format!("--{key} requires a value"));
            }
        }
        let name = args.get("select").unwrap_or("uniform");
        let cap = match args.get("select-cap") {
            Some(v) => Some(
                v.parse::<u64>()
                    .map_err(|_| format!("--select-cap: bad integer {v:?}"))?,
            ),
            None => None,
        };
        let candidates = match args.get("select-candidates") {
            Some(v) => Some(v.parse::<usize>().map_err(|_| {
                format!("--select-candidates: bad integer {v:?}")
            })?),
            None => None,
        };
        let kind = match name {
            "uniform" => SelectionKind::Uniform,
            "staleness" | "staleness-aware" => SelectionKind::StalenessAware {
                cap: cap.unwrap_or(DEFAULT_STALENESS_CAP),
            },
            "fairness" | "fair" => SelectionKind::Fairness,
            "loss-poc" | "power-of-choice" | "poc" => {
                SelectionKind::LossPoc { candidates }
            }
            other => {
                return Err(format!(
                    "unknown selection policy {other:?} \
                     (uniform | staleness | fairness | loss-poc)"
                ))
            }
        };
        if cap.is_some() && !matches!(kind, SelectionKind::StalenessAware { .. })
        {
            return Err(format!(
                "--select-cap only applies to --select staleness (got {name})"
            ));
        }
        if candidates.is_some()
            && !matches!(kind, SelectionKind::LossPoc { .. })
        {
            return Err(format!(
                "--select-candidates only applies to --select loss-poc \
                 (got {name})"
            ));
        }
        Ok(kind)
    }

    /// Validate against the run's sample size `s`.
    pub fn validate(&self, s: usize) -> Result<(), String> {
        match self {
            SelectionKind::Uniform | SelectionKind::Fairness => Ok(()),
            SelectionKind::StalenessAware { cap } => {
                if *cap == 0 {
                    return Err("--select-cap must be >= 1".into());
                }
                Ok(())
            }
            SelectionKind::LossPoc { candidates } => {
                if let Some(d) = candidates {
                    if *d < s {
                        return Err(format!(
                            "--select-candidates {d} must be >= s = {s}"
                        ));
                    }
                }
                Ok(())
            }
        }
    }

    /// Materialize the policy. `s` resolves the power-of-choice candidate
    /// default (d = 2·s).
    pub fn build(&self, s: usize) -> Box<dyn SelectionPolicy> {
        match self {
            SelectionKind::Uniform => Box::new(Uniform),
            SelectionKind::StalenessAware { cap } => {
                Box::new(StalenessAware::new(*cap))
            }
            SelectionKind::Fairness => Box::new(Fairness),
            SelectionKind::LossPoc { candidates } => Box::new(
                LossPropPowerOfChoice::new(candidates.unwrap_or(2 * s).max(s)),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn default_is_uniform() {
        assert!(SelectionKind::default().is_uniform());
        let a = cli::parse(&sv(&["run"]));
        assert_eq!(SelectionKind::from_args(&a).unwrap(), SelectionKind::Uniform);
    }

    #[test]
    fn from_args_full_surface() {
        let a = cli::parse(&sv(&["run", "--select", "staleness", "--select-cap", "7"]));
        assert_eq!(
            SelectionKind::from_args(&a).unwrap(),
            SelectionKind::StalenessAware { cap: 7 }
        );
        let a = cli::parse(&sv(&["run", "--select", "staleness"]));
        assert_eq!(
            SelectionKind::from_args(&a).unwrap(),
            SelectionKind::StalenessAware { cap: DEFAULT_STALENESS_CAP }
        );
        let a = cli::parse(&sv(&["run", "--select", "fairness"]));
        assert_eq!(SelectionKind::from_args(&a).unwrap(), SelectionKind::Fairness);
        let a = cli::parse(&sv(&[
            "run", "--select", "loss-poc", "--select-candidates", "16",
        ]));
        assert_eq!(
            SelectionKind::from_args(&a).unwrap(),
            SelectionKind::LossPoc { candidates: Some(16) }
        );
    }

    #[test]
    fn from_args_rejects_misapplied_knobs_and_garbage() {
        let a = cli::parse(&sv(&["run", "--select", "roulette"]));
        assert!(SelectionKind::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--select", "fairness", "--select-cap", "3"]));
        assert!(SelectionKind::from_args(&a).is_err());
        let a = cli::parse(&sv(&[
            "run", "--select", "uniform", "--select-candidates", "8",
        ]));
        assert!(SelectionKind::from_args(&a).is_err());
        // A forgotten value must error, not silently stay Uniform.
        let a = cli::parse(&sv(&["run", "--select"]));
        assert!(SelectionKind::from_args(&a).is_err());
    }

    #[test]
    fn validate_checks_cap_and_candidates() {
        assert!(SelectionKind::Uniform.validate(5).is_ok());
        assert!(SelectionKind::StalenessAware { cap: 0 }.validate(5).is_err());
        assert!(SelectionKind::StalenessAware { cap: 1 }.validate(5).is_ok());
        assert!(SelectionKind::LossPoc { candidates: Some(4) }
            .validate(5)
            .is_err());
        assert!(SelectionKind::LossPoc { candidates: Some(5) }
            .validate(5)
            .is_ok());
        assert!(SelectionKind::LossPoc { candidates: None }.validate(5).is_ok());
    }

    #[test]
    fn build_resolves_poc_candidate_default() {
        let p = SelectionKind::LossPoc { candidates: None }.build(6);
        assert_eq!(p.name(), "loss-poc");
        let p = SelectionKind::Uniform.build(6);
        assert_eq!(p.name(), "uniform");
        let p = SelectionKind::StalenessAware { cap: 3 }.build(6);
        assert_eq!(p.name(), "staleness");
        let p = SelectionKind::Fairness.build(6);
        assert_eq!(p.name(), "fairness");
    }
}

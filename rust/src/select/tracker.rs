//! Server-side participation bookkeeping feeding the selection policies
//! and the fairness/staleness metrics columns.
//!
//! The tracker is pure bookkeeping: recording never consumes randomness
//! and never touches a float the trajectory depends on, so carrying it in
//! every run keeps the default `Uniform` policy bit-exact while making
//! the history available the moment a non-uniform policy asks for it.
//!
//! "Round" is the server's interaction counter: QuAFL/FedAvg advance it
//! once per server round, FedBuff once per buffer aggregation. A client's
//! *staleness* is `round - snapshot_round[i]`, where `snapshot_round[i]`
//! is the round at which its current model snapshot was installed (0 =
//! the shared init) — the same quantity the fleet store derives from its
//! per-client snapshot epochs ([`crate::fleet::ClientModelStore`]'s
//! `snapshot_epoch`), kept here so policies can rank clients without a
//! handle on the store. The two derivations stay equal by construction:
//! the algorithms stamp snapshots in both at the same program points and
//! advance both counters together (a `debug_assert` in QuAFL/FedBuff
//! checks the lockstep on every round of every debug-build test run).
//!
//! ## Incremental aggregates
//!
//! The Gini/staleness metrics used to be O(n) scans per eval point (sort
//! + sum), which at n=10⁶ dominates a round. They are now maintained
//! incrementally — O(log max_count) on `record_participation`, O(1)
//! amortized on `note_snapshot`/`advance_round`:
//!
//! - **Gini** via the pairwise half-sum `S2 = Σ_{i<j} |c_i − c_j|`
//!   (`i128`). When `c_i` goes `a → a+1`, `ΔS2 = 2·le − n − 1` where
//!   `le = #{j : c_j ≤ a}` (including `i` itself), answered by a Fenwick
//!   tree over count *values* ([`crate::util::fenwick`], capacity-doubled
//!   as counts grow). The sorted-scan numerator
//!   `Σ_i (2(i+1) − n − 1)·c_(i)` equals `S2` by the standard identity,
//!   so `G = S2 / (n·total)` is the same statistic.
//! - **Mean staleness** from the running `Σ snapshot_round`:
//!   `mean = (n·round − snap_sum) / n`, integer-exact before the single
//!   final division.
//! - **Max staleness** as `round − min(snapshot_round)`, with the min
//!   maintained by a frequency-by-round table and a monotone pointer
//!   (snapshot rounds only ever increase, so the pointer never rewinds).
//!
//! The old full scans are retained as `*_scan` oracles; property tests
//! (here and in rust/tests/scale_parity.rs) check the incremental values
//! stay **bitwise** equal to them under arbitrary interleavings of
//! `record_participation`/`note_snapshot`/`advance_round`.

use crate::util::fenwick::Fenwick;

/// Per-client participation history (see the module docs).
#[derive(Clone, Debug)]
pub struct ParticipationTracker {
    round: u64,
    counts: Vec<u64>,
    last_served: Vec<f64>,
    snapshot_round: Vec<u64>,
    last_loss: Vec<Option<f64>>,
    /// Σ counts
    total: u64,
    /// Σ counts² — the χ²-vs-uniform numerator (telemetry selection-bias
    /// gauge); u128 so n=10⁷ runs cannot overflow
    count_sumsq: u128,
    /// Σ_{i<j} |c_i − c_j| — the Gini numerator
    pair_abs_sum: i128,
    /// count value → #clients holding it (mirror of `cnt_index`)
    cnt_freq: Vec<i64>,
    /// Fenwick over `cnt_freq`: prefix(v+1) = #{j : c_j ≤ v}
    cnt_index: Fenwick,
    /// Σ snapshot_round
    snap_sum: u128,
    /// round value → #clients whose snapshot is from that round
    snap_freq: Vec<u64>,
    /// min(snapshot_round) — only ever increases
    min_snap: u64,
}

impl ParticipationTracker {
    pub fn new(n: usize) -> Self {
        let cnt_freq = vec![n as i64, 0];
        let cnt_index = Fenwick::from_values(&cnt_freq);
        ParticipationTracker {
            round: 0,
            counts: vec![0; n],
            last_served: vec![f64::NEG_INFINITY; n],
            snapshot_round: vec![0; n],
            last_loss: vec![None; n],
            total: 0,
            count_sumsq: 0,
            pair_abs_sum: 0,
            cnt_freq,
            cnt_index,
            snap_sum: 0,
            snap_freq: vec![n as u64],
            min_snap: 0,
        }
    }

    /// Fleet size n.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Current server round / aggregation index.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Advance the server's interaction counter (once per QuAFL/FedAvg
    /// round, once per FedBuff aggregation — including idle rounds, which
    /// age everyone's snapshot).
    pub fn advance_round(&mut self) {
        self.round += 1;
    }

    /// Client `i` participated (contributed to the model) at `now`.
    pub fn record_participation(&mut self, i: usize, now: f64) {
        let a = self.counts[i];
        // Δ(Σc²) for c_i: a → a+1 is (a+1)² − a² = 2a+1.
        self.count_sumsq += (2 * a + 1) as u128;
        // ΔS2 for c_i: a → a+1, with le counting i itself (c_i = a ≤ a).
        let le = self.cnt_index.prefix(a as usize + 1) as i128;
        self.pair_abs_sum += 2 * le - self.counts.len() as i128 - 1;
        let new = a as usize + 1;
        if new >= self.cnt_freq.len() {
            // Counts only grow; double the value range and rebuild (O(n)
            // amortized over the doublings).
            self.cnt_freq.resize((new + 1).next_power_of_two(), 0);
            self.cnt_index = Fenwick::from_values(&self.cnt_freq);
        }
        self.cnt_freq[a as usize] -= 1;
        self.cnt_freq[new] += 1;
        self.cnt_index.add(a as usize, -1);
        self.cnt_index.add(new, 1);
        self.counts[i] = a + 1;
        self.total += 1;
        self.last_served[i] = now;
    }

    /// Client `i` (re)installed a model snapshot this round — a QuAFL
    /// post-round update or a FedBuff pull, admitted or not.
    pub fn note_snapshot(&mut self, i: usize) {
        let old = self.snapshot_round[i];
        if old == self.round {
            return;
        }
        self.snap_sum += (self.round - old) as u128;
        self.snap_freq[old as usize] -= 1;
        if self.snap_freq.len() <= self.round as usize {
            self.snap_freq.resize(self.round as usize + 1, 0);
        }
        self.snap_freq[self.round as usize] += 1;
        self.snapshot_round[i] = self.round;
        // The vacated minimum can only move up — chase it eagerly; some
        // client always holds a round >= min_snap, so this terminates.
        while self.snap_freq[self.min_snap as usize] == 0 {
            self.min_snap += 1;
        }
    }

    /// Record client `i`'s last observed mean local loss (non-finite
    /// observations are dropped rather than poisoning the ranking).
    pub fn note_loss(&mut self, i: usize, loss: f64) {
        if loss.is_finite() {
            self.last_loss[i] = Some(loss);
        }
    }

    pub fn count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Last simulated time client `i` was served (−∞ if never).
    pub fn last_served(&self, i: usize) -> f64 {
        self.last_served[i]
    }

    /// Rounds since client `i`'s current snapshot was installed.
    pub fn staleness(&self, i: usize) -> u64 {
        self.round - self.snapshot_round[i]
    }

    /// Last observed mean local loss, if the server ever saw one.
    pub fn loss(&self, i: usize) -> Option<f64> {
        self.last_loss[i]
    }

    /// Total operations served by the tracker's internal Fenwick index
    /// (passive trace counter; see [`crate::util::fenwick::Fenwick::ops`]).
    /// Note the index is rebuilt on capacity doublings, which resets the
    /// construction-time baseline — the counter is a rate signal, not an
    /// exact lifetime tally.
    pub fn fenwick_ops(&self) -> u64 {
        self.cnt_index.ops()
    }

    /// Gini coefficient of the participation counts (0 = perfectly
    /// equal; → 1 as participation concentrates on few clients). O(1)
    /// from the incrementally maintained pairwise sum.
    pub fn participation_gini(&self) -> f64 {
        let n = self.counts.len();
        if n == 0 || self.total == 0 {
            return 0.0;
        }
        self.pair_abs_sum as f64 / (n as f64 * self.total as f64)
    }

    /// Full-scan Gini oracle — the pre-event-driven implementation with
    /// an integer-exact numerator, retained for the parity suite.
    pub fn participation_gini_scan(&self) -> f64 {
        let n = self.counts.len();
        let total: u64 = self.counts.iter().sum();
        if n == 0 || total == 0 {
            return 0.0;
        }
        let mut sorted = self.counts.clone();
        sorted.sort_unstable();
        // G = Σ_i (2(i+1) − n − 1)·c_(i) / (n·Σc) over ascending c_(i);
        // the numerator equals Σ_{i<j} |c_i − c_j|.
        let num: i128 = sorted
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                (2 * (i as i128 + 1) - n as i128 - 1) * c as i128
            })
            .sum();
        num as f64 / (n as f64 * total as f64)
    }

    /// Σ counts² — the incrementally maintained χ² numerator. O(1).
    pub fn participation_sumsq(&self) -> u128 {
        self.count_sumsq
    }

    /// Full-scan Σ counts² oracle, retained for the parity suite.
    pub fn participation_sumsq_scan(&self) -> u128 {
        self.counts.iter().map(|&c| (c as u128) * (c as u128)).sum()
    }

    /// Pearson χ² statistic of the participation counts against the
    /// uniform expectation `total/n`:
    /// `Σ (c_i − total/n)² / (total/n) = n·Σc²/total − total`.
    /// 0 means perfectly uniform service; grows with selection bias.
    /// O(1) from the incremental sum of squares (telemetry gauge
    /// `select_chi2`).
    pub fn selection_bias_chi2(&self) -> f64 {
        let n = self.counts.len();
        if n == 0 || self.total == 0 {
            return 0.0;
        }
        n as f64 * self.count_sumsq as f64 / self.total as f64
            - self.total as f64
    }

    /// Max snapshot staleness across the fleet. O(1).
    pub fn max_staleness(&self) -> u64 {
        if self.counts.is_empty() {
            return 0;
        }
        self.round - self.min_snap
    }

    /// Full-scan max-staleness oracle, retained for the parity suite.
    pub fn max_staleness_scan(&self) -> u64 {
        self.snapshot_round
            .iter()
            .map(|&r| self.round - r)
            .max()
            .unwrap_or(0)
    }

    /// Mean snapshot staleness across the fleet. O(1).
    pub fn mean_staleness(&self) -> f64 {
        let n = self.snapshot_round.len();
        if n == 0 {
            return 0.0;
        }
        let sum = n as u128 * self.round as u128 - self.snap_sum;
        sum as f64 / n as f64
    }

    /// Full-scan mean-staleness oracle, retained for the parity suite.
    pub fn mean_staleness_scan(&self) -> f64 {
        if self.snapshot_round.is_empty() {
            return 0.0;
        }
        let sum: u64 = self.snapshot_round.iter().map(|&r| self.round - r).sum();
        sum as f64 / self.snapshot_round.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fresh_tracker_is_all_zero() {
        let t = ParticipationTracker::new(5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.round(), 0);
        assert_eq!(t.participation_gini(), 0.0);
        assert_eq!(t.max_staleness(), 0);
        assert_eq!(t.mean_staleness(), 0.0);
        for i in 0..5 {
            assert_eq!(t.count(i), 0);
            assert_eq!(t.staleness(i), 0);
            assert!(t.loss(i).is_none());
            assert_eq!(t.last_served(i), f64::NEG_INFINITY);
        }
    }

    #[test]
    fn staleness_tracks_rounds_since_snapshot() {
        let mut t = ParticipationTracker::new(3);
        t.advance_round();
        t.advance_round();
        // Never-refreshed clients age with the round counter (the init
        // snapshot is round 0).
        assert_eq!(t.staleness(0), 2);
        t.note_snapshot(1);
        assert_eq!(t.staleness(1), 0);
        t.advance_round();
        assert_eq!(t.staleness(1), 1);
        assert_eq!(t.staleness(0), 3);
        assert_eq!(t.max_staleness(), 3);
        assert!((t.mean_staleness() - (3.0 + 1.0 + 3.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn gini_of_equal_counts_is_zero() {
        let mut t = ParticipationTracker::new(4);
        for i in 0..4 {
            t.record_participation(i, 1.0);
            t.record_participation(i, 2.0);
        }
        assert!(t.participation_gini().abs() < 1e-12);
    }

    #[test]
    fn gini_of_concentrated_counts_is_large() {
        let mut t = ParticipationTracker::new(4);
        for _ in 0..100 {
            t.record_participation(0, 1.0);
        }
        // One client holds all mass: G = (n-1)/n = 0.75.
        assert!((t.participation_gini() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn gini_known_small_case() {
        // counts [0, 1, 3]: sorted, num = (2-4)*0 + (4-4)*1 + (6-4)*3 = 6;
        // G = 6 / (3*4) = 0.5.
        let mut t = ParticipationTracker::new(3);
        t.record_participation(1, 1.0);
        for _ in 0..3 {
            t.record_participation(2, 1.0);
        }
        assert!((t.participation_gini() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn losses_ignore_non_finite_observations() {
        let mut t = ParticipationTracker::new(2);
        t.note_loss(0, 1.5);
        t.note_loss(0, f64::NAN);
        assert_eq!(t.loss(0), Some(1.5));
        t.note_loss(0, 0.5);
        assert_eq!(t.loss(0), Some(0.5));
    }

    #[test]
    fn incremental_aggregates_match_scans_under_random_interleavings() {
        // Satellite 3: any divergence between the incremental aggregates
        // and the retained full scans is a bug in the incremental path —
        // equality must be *bitwise*, not approximate.
        for seed in [1u64, 17, 303] {
            let mut rng = Rng::new(seed);
            let n = 1 + rng.gen_range(30);
            let mut t = ParticipationTracker::new(n);
            for step in 0..2000 {
                match rng.gen_range(4) {
                    0 => t.advance_round(),
                    1 => {
                        let i = rng.gen_range(n);
                        t.record_participation(i, step as f64);
                    }
                    _ => t.note_snapshot(rng.gen_range(n)),
                }
                assert_eq!(
                    t.participation_gini().to_bits(),
                    t.participation_gini_scan().to_bits(),
                    "gini diverged at step {step} (seed {seed}, n {n})"
                );
                assert_eq!(
                    t.max_staleness(),
                    t.max_staleness_scan(),
                    "max staleness diverged at step {step} (seed {seed})"
                );
                assert_eq!(
                    t.mean_staleness().to_bits(),
                    t.mean_staleness_scan().to_bits(),
                    "mean staleness diverged at step {step} (seed {seed})"
                );
                // Integer equality of the sums of squares makes the χ²
                // gauge bitwise-deterministic too.
                assert_eq!(
                    t.participation_sumsq(),
                    t.participation_sumsq_scan(),
                    "count sumsq diverged at step {step} (seed {seed})"
                );
            }
        }
    }

    #[test]
    fn fenwick_ops_grow_with_participation_bookkeeping() {
        let mut t = ParticipationTracker::new(4);
        assert_eq!(t.fenwick_ops(), 0);
        t.record_participation(0, 1.0);
        let after_one = t.fenwick_ops();
        assert!(after_one > 0, "participation must exercise the index");
        t.record_participation(1, 2.0);
        assert!(t.fenwick_ops() > after_one);
    }

    #[test]
    fn empty_tracker_aggregates_are_zero() {
        let mut t = ParticipationTracker::new(0);
        t.advance_round();
        assert_eq!(t.participation_gini(), 0.0);
        assert_eq!(t.max_staleness(), 0);
        assert_eq!(t.mean_staleness(), 0.0);
        assert_eq!(t.max_staleness_scan(), 0);
        assert_eq!(t.mean_staleness_scan(), 0.0);
        assert_eq!(t.selection_bias_chi2(), 0.0);
    }

    #[test]
    fn chi2_is_zero_for_uniform_and_grows_with_concentration() {
        let mut t = ParticipationTracker::new(4);
        assert_eq!(t.selection_bias_chi2(), 0.0);
        for i in 0..4 {
            t.record_participation(i, 1.0);
        }
        // Uniform counts [1,1,1,1]: χ² = 4·4/4 − 4 = 0.
        assert_eq!(t.selection_bias_chi2(), 0.0);
        for _ in 0..4 {
            t.record_participation(0, 2.0);
        }
        // Counts [5,1,1,1]: χ² = 4·28/8 − 8 = 6.
        assert!((t.selection_bias_chi2() - 6.0).abs() < 1e-12);
    }
}

//! Server-side participation bookkeeping feeding the selection policies
//! and the fairness/staleness metrics columns.
//!
//! The tracker is pure bookkeeping: recording never consumes randomness
//! and never touches a float the trajectory depends on, so carrying it in
//! every run keeps the default `Uniform` policy bit-exact while making
//! the history available the moment a non-uniform policy asks for it.
//!
//! "Round" is the server's interaction counter: QuAFL/FedAvg advance it
//! once per server round, FedBuff once per buffer aggregation. A client's
//! *staleness* is `round - snapshot_round[i]`, where `snapshot_round[i]`
//! is the round at which its current model snapshot was installed (0 =
//! the shared init) — the same quantity the fleet store derives from its
//! per-client snapshot epochs ([`crate::fleet::ClientModelStore`]'s
//! `snapshot_epoch`), kept here so policies can rank clients without a
//! handle on the store. The two derivations stay equal by construction:
//! the algorithms stamp snapshots in both at the same program points and
//! advance both counters together (a `debug_assert` in QuAFL/FedBuff
//! checks the lockstep on every round of every debug-build test run).

/// Per-client participation history (see the module docs).
#[derive(Clone, Debug)]
pub struct ParticipationTracker {
    round: u64,
    counts: Vec<u64>,
    last_served: Vec<f64>,
    snapshot_round: Vec<u64>,
    last_loss: Vec<Option<f64>>,
}

impl ParticipationTracker {
    pub fn new(n: usize) -> Self {
        ParticipationTracker {
            round: 0,
            counts: vec![0; n],
            last_served: vec![f64::NEG_INFINITY; n],
            snapshot_round: vec![0; n],
            last_loss: vec![None; n],
        }
    }

    /// Fleet size n.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Current server round / aggregation index.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Advance the server's interaction counter (once per QuAFL/FedAvg
    /// round, once per FedBuff aggregation — including idle rounds, which
    /// age everyone's snapshot).
    pub fn advance_round(&mut self) {
        self.round += 1;
    }

    /// Client `i` participated (contributed to the model) at `now`.
    pub fn record_participation(&mut self, i: usize, now: f64) {
        self.counts[i] += 1;
        self.last_served[i] = now;
    }

    /// Client `i` (re)installed a model snapshot this round — a QuAFL
    /// post-round update or a FedBuff pull, admitted or not.
    pub fn note_snapshot(&mut self, i: usize) {
        self.snapshot_round[i] = self.round;
    }

    /// Record client `i`'s last observed mean local loss (non-finite
    /// observations are dropped rather than poisoning the ranking).
    pub fn note_loss(&mut self, i: usize, loss: f64) {
        if loss.is_finite() {
            self.last_loss[i] = Some(loss);
        }
    }

    pub fn count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Last simulated time client `i` was served (−∞ if never).
    pub fn last_served(&self, i: usize) -> f64 {
        self.last_served[i]
    }

    /// Rounds since client `i`'s current snapshot was installed.
    pub fn staleness(&self, i: usize) -> u64 {
        self.round - self.snapshot_round[i]
    }

    /// Last observed mean local loss, if the server ever saw one.
    pub fn loss(&self, i: usize) -> Option<f64> {
        self.last_loss[i]
    }

    /// Gini coefficient of the participation counts (0 = perfectly
    /// equal; → 1 as participation concentrates on few clients).
    pub fn participation_gini(&self) -> f64 {
        let n = self.counts.len();
        let total: u64 = self.counts.iter().sum();
        if n == 0 || total == 0 {
            return 0.0;
        }
        let mut sorted = self.counts.clone();
        sorted.sort_unstable();
        // G = Σ_i (2(i+1) − n − 1)·c_(i) / (n·Σc) over ascending c_(i).
        let num: f64 = sorted
            .iter()
            .enumerate()
            .map(|(i, &c)| (2.0 * (i as f64 + 1.0) - n as f64 - 1.0) * c as f64)
            .sum();
        num / (n as f64 * total as f64)
    }

    /// Max snapshot staleness across the fleet.
    pub fn max_staleness(&self) -> u64 {
        self.snapshot_round
            .iter()
            .map(|&r| self.round - r)
            .max()
            .unwrap_or(0)
    }

    /// Mean snapshot staleness across the fleet.
    pub fn mean_staleness(&self) -> f64 {
        if self.snapshot_round.is_empty() {
            return 0.0;
        }
        let sum: u64 = self.snapshot_round.iter().map(|&r| self.round - r).sum();
        sum as f64 / self.snapshot_round.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_tracker_is_all_zero() {
        let t = ParticipationTracker::new(5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.round(), 0);
        assert_eq!(t.participation_gini(), 0.0);
        assert_eq!(t.max_staleness(), 0);
        assert_eq!(t.mean_staleness(), 0.0);
        for i in 0..5 {
            assert_eq!(t.count(i), 0);
            assert_eq!(t.staleness(i), 0);
            assert!(t.loss(i).is_none());
            assert_eq!(t.last_served(i), f64::NEG_INFINITY);
        }
    }

    #[test]
    fn staleness_tracks_rounds_since_snapshot() {
        let mut t = ParticipationTracker::new(3);
        t.advance_round();
        t.advance_round();
        // Never-refreshed clients age with the round counter (the init
        // snapshot is round 0).
        assert_eq!(t.staleness(0), 2);
        t.note_snapshot(1);
        assert_eq!(t.staleness(1), 0);
        t.advance_round();
        assert_eq!(t.staleness(1), 1);
        assert_eq!(t.staleness(0), 3);
        assert_eq!(t.max_staleness(), 3);
        assert!((t.mean_staleness() - (3.0 + 1.0 + 3.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn gini_of_equal_counts_is_zero() {
        let mut t = ParticipationTracker::new(4);
        for i in 0..4 {
            t.record_participation(i, 1.0);
            t.record_participation(i, 2.0);
        }
        assert!(t.participation_gini().abs() < 1e-12);
    }

    #[test]
    fn gini_of_concentrated_counts_is_large() {
        let mut t = ParticipationTracker::new(4);
        for _ in 0..100 {
            t.record_participation(0, 1.0);
        }
        // One client holds all mass: G = (n-1)/n = 0.75.
        assert!((t.participation_gini() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn gini_known_small_case() {
        // counts [0, 1, 3]: sorted, num = (2-4)*0 + (4-4)*1 + (6-4)*3 = 6;
        // G = 6 / (3*4) = 0.5.
        let mut t = ParticipationTracker::new(3);
        t.record_participation(1, 1.0);
        for _ in 0..3 {
            t.record_participation(2, 1.0);
        }
        assert!((t.participation_gini() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn losses_ignore_non_finite_observations() {
        let mut t = ParticipationTracker::new(2);
        t.note_loss(0, 1.5);
        t.note_loss(0, f64::NAN);
        assert_eq!(t.loss(0), Some(1.5));
        t.note_loss(0, 0.5);
        assert_eq!(t.loss(0), Some(0.5));
    }
}

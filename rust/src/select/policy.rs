//! The selection policies and the view they observe.
//!
//! Contract shared by every policy (rust/tests/select_parity.rs):
//!
//! - `select` returns **distinct** client ids, every one reachable at
//!   `view.now`, at most `s` of them;
//! - when at most `s` clients are reachable it returns **all of them, in
//!   ascending id order, without consuming randomness** — exactly what
//!   [`crate::net::ClientAvailability::sample`] does for a short round,
//!   so every policy degenerates identically under heavy churn;
//! - all randomness comes from the passed [`Rng`] (the coordinator's
//!   server-side sampling stream), so runs replay bit for bit.
//!
//! [`Uniform`] additionally guarantees *stream parity*: it delegates to
//! [`crate::net::ClientAvailability::sample`] verbatim, consuming the
//! exact RNG sequence the pre-subsystem code consumed.
//!
//! Cost note: with the event-driven availability index,
//! `view.reachable()` costs O(u log n) in the number of *up* clients, so
//! the non-uniform policies (which rank the reachable set) scale with
//! reachability, not fleet size; `Uniform` never materialises the set at
//! all (O(s log n)). The non-uniform `admit` hooks still scan the
//! reachable set (and loss-poc sorts the observed losses) on every
//! FedBuff arrival — if a policy ever needs per-arrival admission with
//! u ≫ 10⁴ up clients, cache the reachable median per aggregation (the
//! tracker only changes at pops the server sees).

use std::cmp::Ordering;

use crate::net::ClientAvailability;
use crate::util::rng::Rng;

use super::tracker::ParticipationTracker;

/// What a policy may observe when selecting: reachability at the current
/// simulated time plus the server's participation history.
pub struct SelectionView<'a> {
    /// simulated time of this selection
    pub now: f64,
    /// fleet size n
    pub n: usize,
    /// the availability process (mutable: churn walks materialize lazily
    /// as time advances)
    pub availability: &'a mut ClientAvailability,
    /// per-client participation/staleness/loss history
    pub tracker: &'a ParticipationTracker,
}

impl SelectionView<'_> {
    /// Clients reachable at `now`, ascending id order. Delegates to
    /// [`ClientAvailability::reachable`]: the legacy mode walks all n
    /// clients, the event-driven mode enumerates the up-set by Fenwick
    /// rank in O(u log n) — identical output either way.
    pub fn reachable(&mut self) -> Vec<usize> {
        self.availability.reachable(self.n, self.now)
    }

    /// The exact pre-subsystem uniform draw: same RNG stream, same picks
    /// as [`ClientAvailability::sample`] — the `Uniform` fast path.
    pub fn sample_uniform(&mut self, rng: &mut Rng, s: usize) -> Vec<usize> {
        self.availability.sample(rng, self.n, s, self.now)
    }
}

/// A server-side client-selection rule (see the module docs for the
/// shared contract).
pub trait SelectionPolicy: Send {
    /// Pick up to `s` distinct reachable clients at `view.now`.
    fn select(
        &mut self,
        view: &mut SelectionView,
        rng: &mut Rng,
        s: usize,
    ) -> Vec<usize>;

    /// Event-driven admission (FedBuff): should client `client`'s
    /// arriving update enter the aggregation buffer? The default admits
    /// everything and consumes no randomness, so algorithms without a
    /// sampling step stay bit-exact under `Uniform`.
    fn admit(
        &mut self,
        view: &mut SelectionView,
        rng: &mut Rng,
        client: usize,
    ) -> bool {
        let _ = (view, rng, client);
        true
    }

    fn name(&self) -> &'static str;
}

/// Attach a random tie-break key to each candidate, drawing in the given
/// (ascending-id) order so the stream is deterministic.
fn keyed<T: Copy>(
    items: &[usize],
    rng: &mut Rng,
    mut score: impl FnMut(usize) -> T,
) -> Vec<(T, u64, usize)> {
    items
        .iter()
        .map(|&i| (score(i), rng.next_u64(), i))
        .collect()
}

/// Uniform over reachable clients — the default, and a bit-exact wrapper
/// over the pre-subsystem RNG path.
pub struct Uniform;

impl SelectionPolicy for Uniform {
    fn select(
        &mut self,
        view: &mut SelectionView,
        rng: &mut Rng,
        s: usize,
    ) -> Vec<usize> {
        view.sample_uniform(rng, s)
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Staleness-bounded participation: reachable clients whose snapshot is
/// at least `cap` rounds old are mandatory (oldest first, random
/// tie-break); remaining slots are a uniform draw over the rest. For
/// FedBuff, `admit` drops updates computed from a snapshot older than
/// `cap` aggregations (the rejected client still re-pulls, so its next
/// push is fresh — no livelock).
pub struct StalenessAware {
    cap: u64,
}

impl StalenessAware {
    pub fn new(cap: u64) -> Self {
        assert!(cap >= 1, "staleness cap must be >= 1");
        StalenessAware { cap }
    }

    pub fn cap(&self) -> u64 {
        self.cap
    }
}

impl SelectionPolicy for StalenessAware {
    fn select(
        &mut self,
        view: &mut SelectionView,
        rng: &mut Rng,
        s: usize,
    ) -> Vec<usize> {
        let reachable = view.reachable();
        if reachable.len() <= s {
            return reachable;
        }
        let over: Vec<usize> = reachable
            .iter()
            .copied()
            .filter(|&i| view.tracker.staleness(i) >= self.cap)
            .collect();
        let mut ranked = keyed(&over, rng, |i| view.tracker.staleness(i));
        // Oldest snapshots first; equal staleness in random order.
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut picked: Vec<usize> =
            ranked.into_iter().take(s).map(|(_, _, i)| i).collect();
        if picked.len() < s {
            // Below the cap the policy is unbiased: fill uniformly.
            let rest: Vec<usize> = reachable
                .iter()
                .copied()
                .filter(|i| !picked.contains(i))
                .collect();
            let fill = rng.sample_distinct(rest.len(), s - picked.len());
            picked.extend(fill.into_iter().map(|j| rest[j]));
        }
        picked
    }

    fn admit(
        &mut self,
        view: &mut SelectionView,
        _rng: &mut Rng,
        client: usize,
    ) -> bool {
        view.tracker.staleness(client) <= self.cap
    }

    fn name(&self) -> &'static str {
        "staleness"
    }
}

/// Min-participation quota: the `s` reachable clients with the fewest
/// participations (random tie-break) — round-robin under full
/// availability. For FedBuff, `admit` holds a pusher to within one
/// participation of the least-served reachable client.
pub struct Fairness;

impl SelectionPolicy for Fairness {
    fn select(
        &mut self,
        view: &mut SelectionView,
        rng: &mut Rng,
        s: usize,
    ) -> Vec<usize> {
        let reachable = view.reachable();
        if reachable.len() <= s {
            return reachable;
        }
        let mut ranked = keyed(&reachable, rng, |i| view.tracker.count(i));
        ranked.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        ranked.into_iter().take(s).map(|(_, _, i)| i).collect()
    }

    fn admit(
        &mut self,
        view: &mut SelectionView,
        _rng: &mut Rng,
        client: usize,
    ) -> bool {
        let reachable = view.reachable();
        let Some(min) = reachable.iter().map(|&i| view.tracker.count(i)).min()
        else {
            // Nobody reachable to compare against: admit rather than
            // stall the buffer.
            return true;
        };
        // Quota slack of one: the pusher may lead the least-served
        // reachable client by at most one participation.
        view.tracker.count(client) <= min + 1
    }

    fn name(&self) -> &'static str {
        "fairness"
    }
}

/// Loss-proportional power-of-choice: sample `d ≥ s` reachable
/// candidates uniformly, keep the `s` with the highest tracked local
/// loss. Clients the server has never observed rank highest (+∞), so the
/// fleet is explored before the bias kicks in. For FedBuff, `admit`
/// accepts updates whose tracked loss is at or above the reachable
/// median (unknown losses are admitted).
pub struct LossPropPowerOfChoice {
    d: usize,
}

impl LossPropPowerOfChoice {
    pub fn new(d: usize) -> Self {
        assert!(d >= 1, "candidate set must be non-empty");
        LossPropPowerOfChoice { d }
    }

    pub fn candidates(&self) -> usize {
        self.d
    }
}

impl SelectionPolicy for LossPropPowerOfChoice {
    fn select(
        &mut self,
        view: &mut SelectionView,
        rng: &mut Rng,
        s: usize,
    ) -> Vec<usize> {
        let reachable = view.reachable();
        if reachable.len() <= s {
            return reachable;
        }
        let cand: Vec<usize> = if reachable.len() <= self.d {
            reachable
        } else {
            rng.sample_distinct(reachable.len(), self.d)
                .into_iter()
                .map(|j| reachable[j])
                .collect()
        };
        let mut ranked = keyed(&cand, rng, |i| {
            view.tracker.loss(i).unwrap_or(f64::INFINITY)
        });
        ranked.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        ranked.into_iter().take(s).map(|(_, _, i)| i).collect()
    }

    fn admit(
        &mut self,
        view: &mut SelectionView,
        _rng: &mut Rng,
        client: usize,
    ) -> bool {
        let Some(loss) = view.tracker.loss(client) else {
            return true;
        };
        let reachable = view.reachable();
        let mut observed: Vec<f64> = reachable
            .iter()
            .filter_map(|&i| view.tracker.loss(i))
            .collect();
        if observed.len() < 2 {
            return true;
        }
        observed.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));
        let median = observed[observed.len() / 2];
        loss >= median
    }

    fn name(&self) -> &'static str {
        "loss-poc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::AvailabilityKind;

    fn always(n: usize) -> ClientAvailability {
        ClientAvailability::new(AvailabilityKind::Always, n, 1)
    }

    fn assert_valid(picked: &[usize], reachable: &[usize], s: usize) {
        assert!(picked.len() <= s);
        let mut sorted = picked.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), picked.len(), "distinct");
        for i in picked {
            assert!(reachable.contains(i), "client {i} not reachable");
        }
    }

    #[test]
    fn uniform_delegates_to_availability_sample() {
        let n = 12;
        let mut av = always(n);
        let mut av_ref = always(n);
        let tracker = ParticipationTracker::new(n);
        let mut rng = Rng::new(7);
        let mut rng_ref = Rng::new(7);
        let mut policy = Uniform;
        for t in 0..20 {
            let mut view = SelectionView {
                now: t as f64,
                n,
                availability: &mut av,
                tracker: &tracker,
            };
            let picked = policy.select(&mut view, &mut rng, 4);
            let expect = av_ref.sample(&mut rng_ref, n, 4, t as f64);
            assert_eq!(picked, expect, "t={t}");
        }
        // Identical residual streams: the wrapper consumed exactly the
        // raw path's randomness.
        assert_eq!(rng.next_u64(), rng_ref.next_u64());
    }

    #[test]
    fn fairness_picks_least_served() {
        let n = 8;
        let mut av = always(n);
        let mut tracker = ParticipationTracker::new(n);
        // counts: 0 → 5, 1 → 5, 2 → 1, 3 → 2, 4 → 2, 5..8 → 0.
        for _ in 0..5 {
            tracker.record_participation(0, 1.0);
            tracker.record_participation(1, 1.0);
        }
        tracker.record_participation(2, 1.0);
        for _ in 0..2 {
            tracker.record_participation(3, 1.0);
            tracker.record_participation(4, 1.0);
        }
        let mut rng = Rng::new(3);
        let mut policy = Fairness;
        let mut view =
            SelectionView { now: 0.0, n, availability: &mut av, tracker: &tracker };
        let picked = policy.select(&mut view, &mut rng, 5);
        assert_valid(&picked, &(0..n).collect::<Vec<_>>(), 5);
        // The three untouched clients and the once-served client 2 must
        // all be in; the five-time participants 0 and 1 must be out; the
        // last slot goes to one of the twice-served 3/4.
        for i in [5, 6, 7, 2] {
            assert!(picked.contains(&i), "{picked:?} missing {i}");
        }
        assert!(!picked.contains(&0) && !picked.contains(&1), "{picked:?}");
    }

    #[test]
    fn staleness_mandates_over_cap_clients_oldest_first() {
        let n = 10;
        let mut av = always(n);
        let mut tracker = ParticipationTracker::new(n);
        for _ in 0..6 {
            tracker.advance_round();
        }
        // Clients 0..7 refreshed now (staleness 0); 7, 8, 9 stay on the
        // init snapshot (staleness 6).
        for i in 0..7 {
            tracker.note_snapshot(i);
        }
        let mut rng = Rng::new(5);
        let mut policy = StalenessAware::new(4);
        let mut view =
            SelectionView { now: 0.0, n, availability: &mut av, tracker: &tracker };
        let picked = policy.select(&mut view, &mut rng, 4);
        assert_valid(&picked, &(0..n).collect::<Vec<_>>(), 4);
        for i in [7, 8, 9] {
            assert!(picked.contains(&i), "over-cap client {i} not selected");
        }
        // Admission: over-cap updates are dropped, fresh ones admitted.
        let mut view =
            SelectionView { now: 0.0, n, availability: &mut av, tracker: &tracker };
        assert!(!policy.admit(&mut view, &mut rng, 8));
        let mut view =
            SelectionView { now: 0.0, n, availability: &mut av, tracker: &tracker };
        assert!(policy.admit(&mut view, &mut rng, 0));
    }

    #[test]
    fn loss_poc_keeps_highest_loss_and_explores_unknowns() {
        let n = 8;
        let mut av = always(n);
        let mut tracker = ParticipationTracker::new(n);
        for i in 0..6 {
            tracker.note_loss(i, i as f64 * 0.1);
        }
        // 6 and 7 never observed → rank highest.
        let mut rng = Rng::new(9);
        let mut policy = LossPropPowerOfChoice::new(n);
        let mut view =
            SelectionView { now: 0.0, n, availability: &mut av, tracker: &tracker };
        let picked = policy.select(&mut view, &mut rng, 4);
        assert_valid(&picked, &(0..n).collect::<Vec<_>>(), 4);
        assert!(picked.contains(&6) && picked.contains(&7), "{picked:?}");
        // The two remaining slots go to the highest observed losses.
        assert!(picked.contains(&5) && picked.contains(&4), "{picked:?}");
    }

    #[test]
    fn short_round_returns_reachable_in_order_without_randomness() {
        // Under a tight duty cycle most instants leave fewer than s
        // clients reachable; every policy must then return all of them,
        // ascending, consuming no randomness (the raw short-round path).
        let n = 10;
        let s = 4;
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.3 };
        let tracker = ParticipationTracker::new(n);
        let policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(Uniform),
            Box::new(StalenessAware::new(2)),
            Box::new(Fairness),
            Box::new(LossPropPowerOfChoice::new(8)),
        ];
        for mut p in policies {
            let mut av = ClientAvailability::new(kind.clone(), n, 21);
            let mut twin = ClientAvailability::new(kind.clone(), n, 21);
            let mut rng = Rng::new(11);
            let mut short_rounds = 0;
            for step in 0..40 {
                let t = step as f64 * 0.7;
                let reachable: Vec<usize> =
                    (0..n).filter(|&i| twin.is_up(i, t)).collect();
                if reachable.is_empty() || reachable.len() > s {
                    continue;
                }
                short_rounds += 1;
                let mut view = SelectionView {
                    now: t,
                    n,
                    availability: &mut av,
                    tracker: &tracker,
                };
                let picked = p.select(&mut view, &mut rng, s);
                assert_eq!(picked, reachable, "{} t={t}", p.name());
            }
            assert!(short_rounds > 0, "{}: duty cycle never short", p.name());
            // No randomness consumed on any short path.
            assert_eq!(rng.next_u64(), Rng::new(11).next_u64(), "{}", p.name());
        }
    }

    #[test]
    fn default_admit_accepts_everything() {
        let n = 4;
        let mut av = always(n);
        let tracker = ParticipationTracker::new(n);
        let mut rng = Rng::new(1);
        let mut view =
            SelectionView { now: 0.0, n, availability: &mut av, tracker: &tracker };
        assert!(Uniform.admit(&mut view, &mut rng, 2));
        assert_eq!(rng.next_u64(), Rng::new(1).next_u64());
    }
}

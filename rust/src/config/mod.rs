//! Experiment configuration: one struct wiring every axis of the paper's
//! evaluation (algorithm, model, dataset family, partition, quantizer,
//! timing, schedule). Built from CLI args (util::cli) or programmatically
//! by the figure harness.

use crate::data::{PartitionKind, SynthFamily};
use crate::engine::KernelKind;
use crate::fault::FaultConfig;
use crate::net::NetworkConfig;
use crate::select::SelectionKind;
use crate::trace::Level;
use crate::util::cli::Args;

/// Which protocol to run (paper §4 comparisons).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    QuAFL,
    FedAvg,
    FedBuff,
    /// single (slow) sequential SGD node — the paper's "Baseline"
    Baseline,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "quafl" => Ok(Algorithm::QuAFL),
            "fedavg" => Ok(Algorithm::FedAvg),
            "fedbuff" => Ok(Algorithm::FedBuff),
            "baseline" | "sgd" => Ok(Algorithm::Baseline),
            other => Err(format!("unknown algorithm {other:?}")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::QuAFL => "quafl",
            Algorithm::FedAvg => "fedavg",
            Algorithm::FedBuff => "fedbuff",
            Algorithm::Baseline => "baseline",
        }
    }
}

/// Quantizer selection (paper Figures 2/5/6/16).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuantizerKind {
    /// position-aware lattice quantizer with b bits/coordinate
    Lattice { bits: u8 },
    /// QSGD with b bits/coordinate
    Qsgd { bits: u8 },
    /// full precision (b = 32)
    None,
}

impl QuantizerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "none" || s == "32" {
            return Ok(QuantizerKind::None);
        }
        if let Some(rest) = s.strip_prefix("lattice:") {
            return rest
                .parse::<u8>()
                .map(|bits| QuantizerKind::Lattice { bits })
                .map_err(|_| format!("bad lattice bits {s:?}"));
        }
        if let Some(rest) = s.strip_prefix("qsgd:") {
            return rest
                .parse::<u8>()
                .map(|bits| QuantizerKind::Qsgd { bits })
                .map_err(|_| format!("bad qsgd bits {s:?}"));
        }
        Err(format!(
            "unknown quantizer {s:?} (none | lattice:BITS | qsgd:BITS)"
        ))
    }

    pub fn bits(&self) -> u8 {
        match self {
            QuantizerKind::Lattice { bits } | QuantizerKind::Qsgd { bits } => *bits,
            QuantizerKind::None => 32,
        }
    }
}

/// Which nodes average which messages — the Figure 4 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AveragingMode {
    /// paper default: both server and clients average with weight 1/(s+1)
    Both,
    /// only the server averages; clients adopt the server model
    ServerOnly,
    /// only clients average; server adopts the mean of client replies
    ClientOnly,
}

impl AveragingMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "both" => Ok(AveragingMode::Both),
            "server-only" | "server" => Ok(AveragingMode::ServerOnly),
            "client-only" | "client" => Ok(AveragingMode::ClientOnly),
            other => Err(format!("unknown averaging mode {other:?}")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AveragingMode::Both => "both",
            AveragingMode::ServerOnly => "server-only",
            AveragingMode::ClientOnly => "client-only",
        }
    }
}

/// Client speed classes (paper Appendix A.2 timing model): step duration
/// ~ Exp(lambda), lambda = 1/2 for fast and 1/8 for slow clients.
#[derive(Clone, Debug)]
pub struct TimingConfig {
    pub fast_lambda: f64,
    pub slow_lambda: f64,
    /// fraction of clients that are slow (paper uses 0.25–0.30)
    pub slow_fraction: f64,
    /// server waiting time between calls (swt)
    pub swt: f64,
    /// server interaction time per round (sit)
    pub sit: f64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        TimingConfig {
            fast_lambda: 0.5,
            slow_lambda: 0.125,
            slow_fraction: 0.25,
            swt: 10.0,
            sit: 1.0,
        }
    }
}

/// Everything an experiment run needs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub algorithm: Algorithm,
    /// number of clients n
    pub n: usize,
    /// sampled clients per round s
    pub s: usize,
    /// max local steps K
    pub k: usize,
    /// learning rate η
    pub lr: f32,
    /// server rounds T
    pub rounds: usize,
    pub model: String,
    pub family: SynthFamily,
    pub train_samples: usize,
    pub val_samples: usize,
    pub partition: PartitionKind,
    pub quantizer: QuantizerKind,
    pub averaging: AveragingMode,
    /// QuAFL speed weighting η_i = H_min/H_i (paper's "weighted" variant)
    pub weighted: bool,
    pub timing: TimingConfig,
    /// FedBuff buffer size Z
    pub fedbuff_buffer: usize,
    /// FedBuff server lr η_g
    pub fedbuff_server_lr: f32,
    /// evaluate every this many rounds
    pub eval_every: usize,
    pub batch: usize,
    pub seed: u64,
    /// use the XLA engine (artifacts) instead of the native engine
    pub use_xla: bool,
    /// native-engine GEMM backend (`--engine-kernel scalar|blocked|simd`;
    /// default `blocked`). `scalar` and `blocked` are bit-identical
    /// (rust/tests/kernel_parity.rs), so this is purely a wall-clock knob
    /// on every default-feature build; `simd` requires `--features simd`
    /// and changes rounding (FMA). Ignored when `use_xla` is set.
    pub engine_kernel: KernelKind,
    /// override γ for the lattice quantizer (otherwise derived from lr/K)
    pub lattice_gamma: Option<f32>,
    /// record the paper's potential Φ_t each round (Lemma 3.4 diagnostic;
    /// `--track-potential`, off by default). Maintained incrementally
    /// from fleet-store write deltas in O(touched·d) per round
    /// ([`crate::telemetry::probe::DivergenceProbe`]); set
    /// `dense_potential` to fold the full fleet instead.
    pub track_potential: bool,
    /// compute Φ_t with the reference O(n·d) dense fold over
    /// [`crate::fleet`]'s client-order view instead of the incremental
    /// probe (`--dense-potential`; the oracle side of
    /// rust/tests/telemetry_parity.rs). Only meaningful with
    /// `track_potential`.
    pub dense_potential: bool,
    /// stream convergence/fleet metrics as `metric` trace events
    /// (`--telemetry true|false`, default on). Telemetry only arms when
    /// a trace sink is attached (`--trace`), so the default costs
    /// nothing on untraced runs and is bit-exact on traced ones
    /// (rust/tests/telemetry_parity.rs).
    pub telemetry: bool,
    /// worker threads for the parallel client-execution subsystem
    /// ([`crate::exec`]); 0 = available parallelism. Trajectories are
    /// bit-identical for every value (deterministic fan-out + ordered
    /// reduction), so this is purely a wall-clock knob.
    pub workers: usize,
    /// simulated network: link-pricing profile + availability process
    /// ([`crate::net`]). The default (`Ideal` + `Always`) is a bit-exact
    /// no-op on every trajectory.
    pub net: NetworkConfig,
    /// price the t=0 broadcast of the init model to all n clients
    /// (`--price-init-broadcast`). Off by default, so every trajectory
    /// and bit tally matches the paper's free-init setup exactly.
    /// QuAFL/FedBuff charge n full-precision downlinks (and, on a priced
    /// network, delay each client's first burst by its own downlink);
    /// FedAvg already prices every round's downlink and the baseline
    /// never communicates, so both ignore the flag.
    pub price_init_broadcast: bool,
    /// fully materialize every client model up front (`--dense-fleet`)
    /// instead of the CoW fleet store ([`crate::fleet`]) — the reference
    /// O(n·d) layout. Trajectories are bit-identical either way
    /// (rust/tests/fleet_parity.rs); only `peak_model_bytes` differs.
    pub dense_fleet: bool,
    /// server-side client-selection policy ([`crate::select`]; `--select`,
    /// `--select-cap`, `--select-candidates`). The default `Uniform` is a
    /// bit-exact wrapper over the pre-subsystem sampling path
    /// (rust/tests/select_parity.rs).
    pub select: SelectionKind,
    /// price FedAvg's per-round model broadcast as one transmission on a
    /// shared downlink medium — every sampled client receives at the
    /// slowest sampled link's time and the payload is charged once —
    /// instead of s independent unicasts (`--broadcast-downlink`; off by
    /// default = bit-exact unicast pricing). QuAFL/FedBuff downlinks are
    /// genuinely per-client (each round's recipients differ mid-flight),
    /// so only FedAvg's synchronized broadcast honors the flag.
    pub broadcast_downlink: bool,
    /// record each round's selected client set in
    /// [`crate::metrics::RunMetrics::selections`] (test/diagnostic hook;
    /// costs O(s) memory per round, off by default, no CLI surface)
    pub track_selection: bool,
    /// serve availability/selection queries from the event-driven index
    /// (churn event queue + Fenwick up-set, O(s log n) per round) instead
    /// of the legacy O(n) per-client walk (`--event-driven true|false`,
    /// default on). Trajectories are bit-identical either way
    /// (rust/tests/scale_parity.rs); the legacy path is the test oracle.
    pub event_driven: bool,
    /// structured-trace output path (`--trace out.jsonl`). `None` (the
    /// default) keeps the [`crate::trace::Tracer`] disarmed — every hook
    /// is a near no-op and trajectories are bit-identical either way
    /// (rust/tests/trace_parity.rs). The sink appends, so the runs of one
    /// `figures`/`sweep` invocation share a single trace file.
    pub trace: Option<String>,
    /// trace/diagnostic verbosity (`--trace-level off|error|info|debug`;
    /// default `info`). Gates both the structured event stream and the
    /// [`crate::log!`] stderr diagnostics.
    pub trace_level: Level,
    /// fault-injection & failure-handling plan ([`crate::fault`];
    /// `--fault-crash/--fault-drop/--fault-corrupt/--fault-straggle`,
    /// `--round-deadline`/`--fault-quorum`, retry/backoff knobs). The
    /// default is fully disabled — no engine is constructed and every
    /// trajectory is bit-exact legacy (rust/tests/fault_parity.rs).
    pub fault: FaultConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            algorithm: Algorithm::QuAFL,
            n: 20,
            s: 5,
            k: 10,
            lr: 0.1,
            rounds: 100,
            model: "mlp".into(),
            family: SynthFamily::Mnist,
            train_samples: 4000,
            val_samples: 1024,
            partition: PartitionKind::Iid,
            quantizer: QuantizerKind::Lattice { bits: 10 },
            averaging: AveragingMode::Both,
            weighted: false,
            timing: TimingConfig::default(),
            fedbuff_buffer: 5,
            fedbuff_server_lr: 1.0,
            eval_every: 10,
            batch: 32,
            seed: 1,
            use_xla: false,
            engine_kernel: KernelKind::default(),
            lattice_gamma: None,
            track_potential: false,
            dense_potential: false,
            telemetry: true,
            workers: 0,
            net: NetworkConfig::default(),
            price_init_broadcast: false,
            dense_fleet: false,
            select: SelectionKind::Uniform,
            broadcast_downlink: false,
            track_selection: false,
            event_driven: true,
            trace: None,
            trace_level: Level::Info,
            fault: FaultConfig::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.s == 0 || self.s > self.n {
            return Err(format!("need 1 <= s <= n, got s={} n={}", self.s, self.n));
        }
        if self.k == 0 {
            return Err("K must be >= 1".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be >= 1".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be positive".into());
        }
        if self.train_samples < self.n {
            return Err("need at least one training sample per client".into());
        }
        if self.algorithm == Algorithm::FedBuff && self.fedbuff_buffer == 0 {
            return Err("fedbuff buffer must be >= 1".into());
        }
        if !self.engine_kernel.available() {
            return Err(format!(
                "engine kernel `{}` requires building with `--features simd`",
                self.engine_kernel.name()
            ));
        }
        self.net.validate()?;
        self.select.validate(self.s)?;
        self.fault.validate()?;
        // Cross-subsystem fault combos the fault parser can't see alone.
        if self.fault.enabled() {
            if self.fault.quorum > self.s {
                return Err(format!(
                    "--fault-quorum {} exceeds the sample size s={} — the \
                     round could never reach quorum",
                    self.fault.quorum, self.s
                ));
            }
            // A deadline only ever binds on communication or straggler
            // slowdowns; with a zero-cost ideal transport and no
            // stragglers it silently never fires.
            if self.fault.round_deadline > 0.0
                && self.net.profile.is_ideal()
                && self.fault.straggle == 0.0
                && self.fault.drop == 0.0
                && self.fault.corrupt == 0.0
            {
                return Err(
                    "--round-deadline has nothing to bind on: the ideal \
                     transport prices every exchange at zero and no \
                     straggle/drop/corrupt faults are armed; pick a priced \
                     --net or add a fault rate"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// Known CLI keys for the `run` subcommand, excluding the network
    /// keys — use [`ExperimentConfig::cli_keys`] for the full set.
    pub const CLI_KEYS: &'static [&'static str] = &[
        "algorithm", "n", "s", "k", "lr", "rounds", "model", "family",
        "train-samples", "val-samples", "partition", "quantizer",
        "averaging", "weighted", "swt", "sit", "slow-fraction",
        "fast-lambda", "slow-lambda",
        "fedbuff-buffer", "fedbuff-server-lr", "eval-every", "batch",
        "seed", "xla", "engine-kernel", "gamma", "out", "workers",
        "price-init-broadcast", "dense-fleet", "broadcast-downlink",
        "event-driven", "trace", "trace-level", "track-potential",
        "dense-potential", "telemetry",
    ];

    /// The full `run` key set: [`ExperimentConfig::CLI_KEYS`] plus the
    /// network keys owned by [`NetworkConfig::CLI_KEYS`], the selection
    /// keys owned by [`SelectionKind::CLI_KEYS`], and the fault keys
    /// owned by [`FaultConfig::CLI_KEYS`] (single source — a flag added
    /// to one parser cannot drift out of the typo guard).
    pub fn cli_keys() -> Vec<&'static str> {
        let mut keys = Self::CLI_KEYS.to_vec();
        keys.extend_from_slice(NetworkConfig::CLI_KEYS);
        keys.extend_from_slice(SelectionKind::CLI_KEYS);
        keys.extend_from_slice(FaultConfig::CLI_KEYS);
        keys
    }

    pub fn from_args(args: &Args) -> Result<Self, String> {
        let mut c = ExperimentConfig::default();
        if let Some(a) = args.get("algorithm") {
            c.algorithm = Algorithm::parse(a)?;
        }
        c.n = args.get_usize("n", c.n);
        c.s = args.get_usize("s", c.s);
        c.k = args.get_usize("k", c.k);
        c.lr = args.get_f64("lr", c.lr as f64) as f32;
        c.rounds = args.get_usize("rounds", c.rounds);
        c.model = args.get_str("model", &c.model);
        if let Some(f) = args.get("family") {
            c.family = match f {
                "mnist" => SynthFamily::Mnist,
                "hard" => SynthFamily::Hard,
                "celeb" => SynthFamily::Celeb,
                "tiny" => SynthFamily::Tiny,
                other => return Err(format!("unknown family {other:?}")),
            };
        }
        c.train_samples = args.get_usize("train-samples", c.train_samples);
        c.val_samples = args.get_usize("val-samples", c.val_samples);
        if let Some(p) = args.get("partition") {
            c.partition = PartitionKind::parse(p)?;
        }
        if let Some(q) = args.get("quantizer") {
            c.quantizer = QuantizerKind::parse(q)?;
        }
        if let Some(a) = args.get("averaging") {
            c.averaging = AveragingMode::parse(a)?;
        }
        c.weighted = args.bool("weighted");
        c.timing.swt = args.get_f64("swt", c.timing.swt);
        c.timing.sit = args.get_f64("sit", c.timing.sit);
        c.timing.slow_fraction =
            args.get_f64("slow-fraction", c.timing.slow_fraction);
        c.timing.fast_lambda = args.get_f64("fast-lambda", c.timing.fast_lambda);
        c.timing.slow_lambda = args.get_f64("slow-lambda", c.timing.slow_lambda);
        c.fedbuff_buffer = args.get_usize("fedbuff-buffer", c.fedbuff_buffer);
        c.fedbuff_server_lr =
            args.get_f64("fedbuff-server-lr", c.fedbuff_server_lr as f64) as f32;
        c.eval_every = args.get_usize("eval-every", c.eval_every);
        c.batch = args.get_usize("batch", c.batch);
        c.seed = args.get_u64("seed", c.seed);
        c.use_xla = args.bool("xla");
        if let Some(k) = args.get("engine-kernel") {
            c.engine_kernel = KernelKind::parse(k)?;
        }
        if let Some(g) = args.get("gamma") {
            c.lattice_gamma =
                Some(g.parse().map_err(|_| format!("bad gamma {g:?}"))?);
        }
        c.workers = args.get_usize("workers", c.workers);
        c.price_init_broadcast = args.bool("price-init-broadcast");
        c.dense_fleet = args.bool("dense-fleet");
        c.broadcast_downlink = args.bool("broadcast-downlink");
        // Default-on boolean: only an explicit value overrides (the bare
        // flag `--event-driven` is a no-op restatement of the default).
        if let Some(v) = args.get("event-driven") {
            c.event_driven = match v {
                "true" => true,
                "false" => false,
                other => {
                    return Err(format!(
                        "--event-driven expects true|false, got {other:?}"
                    ))
                }
            };
        }
        c.track_potential = args.bool("track-potential") || c.track_potential;
        c.dense_potential = args.bool("dense-potential") || c.dense_potential;
        // Default-on boolean, same contract as --event-driven.
        if let Some(v) = args.get("telemetry") {
            c.telemetry = match v {
                "true" => true,
                "false" => false,
                other => {
                    return Err(format!(
                        "--telemetry expects true|false, got {other:?}"
                    ))
                }
            };
        }
        if let Some(p) = args.get("trace") {
            c.trace = Some(p.to_string());
        }
        if let Some(l) = args.get("trace-level") {
            c.trace_level = Level::parse(l)?;
        }
        c.net = NetworkConfig::from_args(args)?;
        c.select = SelectionKind::from_args(args)?;
        c.fault = FaultConfig::from_args(args)?;
        c.validate()?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn default_is_valid() {
        assert!(ExperimentConfig::default().validate().is_ok());
    }

    #[test]
    fn from_args_overrides() {
        let a = cli::parse(&sv(&[
            "run", "--algorithm", "fedavg", "--n", "40", "--s", "8",
            "--quantizer", "qsgd:8", "--partition", "by-class", "--weighted",
            "--workers", "4",
        ]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(c.algorithm, Algorithm::FedAvg);
        assert_eq!(c.n, 40);
        assert_eq!(c.s, 8);
        assert_eq!(c.quantizer, QuantizerKind::Qsgd { bits: 8 });
        assert_eq!(c.partition, PartitionKind::ByClass);
        assert!(c.weighted);
        assert_eq!(c.workers, 4);
    }

    #[test]
    fn invalid_configs_rejected() {
        let base = ExperimentConfig::default();
        let c = ExperimentConfig { s: 0, ..base.clone() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig { s: base.n + 1, ..base.clone() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig { k: 0, ..base.clone() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig { lr: -1.0, ..base };
        assert!(c.validate().is_err());
    }

    #[test]
    fn engine_kernel_defaults_blocked_and_parses() {
        assert_eq!(ExperimentConfig::default().engine_kernel, KernelKind::Blocked);
        let a = cli::parse(&sv(&["run", "--engine-kernel", "scalar"]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(c.engine_kernel, KernelKind::Scalar);
        let a = cli::parse(&sv(&["run", "--engine-kernel", "blocked"]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(c.engine_kernel, KernelKind::Blocked);
        let a = cli::parse(&sv(&["run", "--engine-kernel", "warp"]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        assert!(ExperimentConfig::cli_keys().contains(&"engine-kernel"));
    }

    #[test]
    fn engine_kernel_simd_gated_by_feature() {
        let a = cli::parse(&sv(&["run", "--engine-kernel", "simd"]));
        let r = ExperimentConfig::from_args(&a);
        if cfg!(feature = "simd") {
            assert_eq!(r.unwrap().engine_kernel, KernelKind::Simd);
        } else {
            // Parses as a known kind, but validation rejects it when the
            // backend isn't compiled in.
            assert!(r.unwrap_err().contains("--features simd"));
        }
    }

    #[test]
    fn net_flags_parse_into_config() {
        let a = cli::parse(&sv(&["run", "--net", "mobile", "--churn", "100/20"]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert!(!c.net.profile.is_ideal());
        assert!(matches!(
            c.net.availability,
            crate::net::AvailabilityKind::Churn { .. }
        ));
        // Defaults stay the bit-exact no-op.
        assert!(ExperimentConfig::default().net.profile.is_ideal());
        // The typo guard covers every network key without hand-copying.
        let keys = ExperimentConfig::cli_keys();
        for k in NetworkConfig::CLI_KEYS {
            assert!(keys.contains(k), "missing net key {k}");
        }
    }

    #[test]
    fn fleet_flags_parse_and_default_off() {
        let d = ExperimentConfig::default();
        assert!(!d.price_init_broadcast);
        assert!(!d.dense_fleet);
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--price-init-broadcast", "--dense-fleet"]),
            &["price-init-broadcast", "dense-fleet"],
        );
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert!(c.price_init_broadcast);
        assert!(c.dense_fleet);
    }

    #[test]
    fn event_driven_defaults_on_and_parses_explicit_values() {
        assert!(ExperimentConfig::default().event_driven);
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--event-driven", "false"]),
            &["event-driven"],
        );
        assert!(!ExperimentConfig::from_args(&a).unwrap().event_driven);
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--event-driven", "true"]),
            &["event-driven"],
        );
        assert!(ExperimentConfig::from_args(&a).unwrap().event_driven);
        // Bare flag restates the default.
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--event-driven"]),
            &["event-driven"],
        );
        assert!(ExperimentConfig::from_args(&a).unwrap().event_driven);
        let a = cli::parse(&sv(&["run", "--event-driven", "junk"]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        assert!(ExperimentConfig::cli_keys().contains(&"event-driven"));
    }

    #[test]
    fn telemetry_flags_parse_with_expected_defaults() {
        let d = ExperimentConfig::default();
        assert!(d.telemetry);
        assert!(!d.track_potential);
        assert!(!d.dense_potential);
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--track-potential", "--dense-potential"]),
            &["track-potential", "dense-potential"],
        );
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert!(c.track_potential);
        assert!(c.dense_potential);
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--telemetry", "false"]),
            &["telemetry"],
        );
        assert!(!ExperimentConfig::from_args(&a).unwrap().telemetry);
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--telemetry", "true"]),
            &["telemetry"],
        );
        assert!(ExperimentConfig::from_args(&a).unwrap().telemetry);
        // Bare flag restates the default.
        let a = cli::parse_with_bool_flags(&sv(&["run", "--telemetry"]), &["telemetry"]);
        assert!(ExperimentConfig::from_args(&a).unwrap().telemetry);
        let a = cli::parse(&sv(&["run", "--telemetry", "junk"]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        let keys = ExperimentConfig::cli_keys();
        for k in ["telemetry", "track-potential", "dense-potential"] {
            assert!(keys.contains(&k), "missing telemetry key {k}");
        }
    }

    #[test]
    fn tiny_family_parses_for_million_client_runs() {
        let a = cli::parse(&sv(&["run", "--family", "tiny", "--model", "mlp_tiny"]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(c.family, SynthFamily::Tiny);
        assert_eq!(c.model, "mlp_tiny");
    }

    #[test]
    fn select_flags_parse_into_config() {
        let d = ExperimentConfig::default();
        assert!(d.select.is_uniform());
        assert!(!d.broadcast_downlink);
        let a = cli::parse(&sv(&[
            "run", "--select", "loss-poc", "--select-candidates", "12",
        ]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(c.select, SelectionKind::LossPoc { candidates: Some(12) });
        // --select-candidates below s must be rejected at validation.
        let a = cli::parse(&sv(&[
            "run", "--s", "10", "--n", "40", "--select", "loss-poc",
            "--select-candidates", "4",
        ]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        // The typo guard covers every selection key without hand-copying.
        let keys = ExperimentConfig::cli_keys();
        for k in SelectionKind::CLI_KEYS {
            assert!(keys.contains(k), "missing select key {k}");
        }
        assert!(keys.contains(&"broadcast-downlink"));
    }

    #[test]
    fn broadcast_downlink_flag_parses() {
        let a = cli::parse_with_bool_flags(
            &sv(&["run", "--algorithm", "fedavg", "--broadcast-downlink"]),
            &["broadcast-downlink"],
        );
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert!(c.broadcast_downlink);
    }

    #[test]
    fn trace_flags_parse_and_default_off() {
        let d = ExperimentConfig::default();
        assert!(d.trace.is_none());
        assert_eq!(d.trace_level, Level::Info);
        let a = cli::parse(&sv(&[
            "run", "--trace", "out.jsonl", "--trace-level", "debug",
        ]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(c.trace.as_deref(), Some("out.jsonl"));
        assert_eq!(c.trace_level, Level::Debug);
        let a = cli::parse(&sv(&["run", "--trace-level", "loud"]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        let keys = ExperimentConfig::cli_keys();
        assert!(keys.contains(&"trace") && keys.contains(&"trace-level"));
    }

    #[test]
    fn fault_flags_parse_into_config() {
        let d = ExperimentConfig::default();
        assert!(!d.fault.enabled(), "faults default off");
        let a = cli::parse(&sv(&[
            "run", "--net", "mobile", "--fault-crash", "0.1", "--fault-drop",
            "0.2", "--fault-corrupt", "0.05", "--fault-straggle", "0.25:4",
            "--round-deadline", "30", "--fault-quorum", "2",
        ]));
        let c = ExperimentConfig::from_args(&a).unwrap();
        assert!(c.fault.enabled());
        assert_eq!(c.fault.crash, 0.1);
        assert_eq!(c.fault.straggle_mult, 4.0);
        assert_eq!(c.fault.quorum, 2);
        // The typo guard covers every fault key without hand-copying.
        let keys = ExperimentConfig::cli_keys();
        for k in FaultConfig::CLI_KEYS {
            assert!(keys.contains(k), "missing fault key {k}");
        }
    }

    #[test]
    fn fault_combos_rejected_at_validation() {
        // Quorum larger than the sample could never be reached.
        let a = cli::parse(&sv(&[
            "run", "--s", "3", "--n", "20", "--net", "mobile",
            "--round-deadline", "30", "--fault-quorum", "5",
        ]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        // A deadline with the zero-cost ideal transport and no fault rate
        // silently never fires — rejected as a footgun.
        let a = cli::parse(&sv(&["run", "--round-deadline", "30"]));
        assert!(ExperimentConfig::from_args(&a).is_err());
        // Same deadline becomes meaningful on a priced net…
        let a = cli::parse(&sv(&[
            "run", "--net", "mobile", "--round-deadline", "30",
        ]));
        assert!(ExperimentConfig::from_args(&a).is_ok());
        // …or with a fault model that inflates delivery time.
        let a = cli::parse(&sv(&[
            "run", "--round-deadline", "30", "--fault-straggle", "0.2:8",
        ]));
        assert!(ExperimentConfig::from_args(&a).is_ok());
    }

    #[test]
    fn quantizer_parse() {
        assert_eq!(
            QuantizerKind::parse("lattice:14").unwrap(),
            QuantizerKind::Lattice { bits: 14 }
        );
        assert_eq!(QuantizerKind::parse("none").unwrap(), QuantizerKind::None);
        assert!(QuantizerKind::parse("lattice:x").is_err());
        assert_eq!(QuantizerKind::parse("qsgd:8").unwrap().bits(), 8);
        assert_eq!(QuantizerKind::None.bits(), 32);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in [
            Algorithm::QuAFL,
            Algorithm::FedAvg,
            Algorithm::FedBuff,
            Algorithm::Baseline,
        ] {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
    }
}

//! Fixed-memory distribution summaries for million-client streams.
//!
//! Two primitives, both O(1) amortized per update and bounded memory
//! regardless of stream length, both deterministic (each owns its RNG,
//! seeded at construction — updating a sketch never touches the
//! simulation's RNG streams):
//!
//! - [`QuantileSketch`]: an MRL/KLL-style compactor cascade. Level `l`
//!   holds items of weight `2^l`; when a level overflows its capacity
//!   `k` it is sorted and every other item (random offset) is promoted
//!   to level `l+1`. Memory is O(k·log(n/k)). Each compaction at level
//!   `l` perturbs any rank by at most `2^l`, and level `l` compacts at
//!   most `n/(k·2^l)` times, so the worst-case rank error after `n`
//!   updates is bounded by `L·n/k` with `L = levels` — the bound the
//!   property tests assert (see `docs/TELEMETRY.md`; observed error is
//!   far smaller because offsets are random). While `n <= k` the sketch
//!   is *exact*: quantiles equal nearest-rank order statistics.
//! - [`Reservoir`]: classic fixed-capacity uniform reservoir sample,
//!   for arbitrary downstream statistics (mean/std over an unbiased
//!   subsample) where quantiles are not enough.
//!
//! Both are mergeable so per-client or per-shard summaries can be
//! combined; merge is level-wise for the sketch (error bounds add) and
//! stream-concatenation for the reservoir (approximate; documented).

use crate::util::rng::Rng;
use crate::util::stats::Welford;

/// Per-level capacity. 256 keeps the cascade exact for every per-round
/// stream the algorithms produce today and the rank-error bound under
/// 3% at n = 10^6 (L <= 12 levels: 12/256 ≈ 0.047 worst case, ~1% observed).
pub const DEFAULT_K: usize = 256;

/// Streaming quantile sketch (compactor cascade). NaN updates are
/// dropped; quantiles of an empty sketch return 0.0.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    k: usize,
    levels: Vec<Vec<f64>>,
    count: u64,
    min: f64,
    max: f64,
    rng: Rng,
}

impl QuantileSketch {
    pub fn new(seed: u64) -> QuantileSketch {
        QuantileSketch::with_k(DEFAULT_K, seed)
    }

    /// `k` is the per-level capacity (>= 2); smaller k = less memory,
    /// larger rank error.
    pub fn with_k(k: usize, seed: u64) -> QuantileSketch {
        QuantileSketch {
            k: k.max(2),
            levels: vec![Vec::new()],
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: Rng::new(seed),
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Number of compactor levels currently allocated (the `L` in the
    /// `L·n/k` rank-error bound).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Resident items across all levels (memory bound: <= k·levels + k).
    pub fn resident(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    pub fn update(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.levels[0].push(v);
        let mut lvl = 0;
        while self.levels[lvl].len() >= self.k {
            self.compact(lvl);
            lvl += 1;
        }
    }

    /// Sort level `lvl`, promote every other survivor (random phase) to
    /// `lvl + 1`. Each survivor's weight doubles, preserving total mass
    /// up to the k/2 items dropped — the source of the rank-error bound.
    fn compact(&mut self, lvl: usize) {
        if self.levels.len() == lvl + 1 {
            self.levels.push(Vec::new());
        }
        let mut items = std::mem::take(&mut self.levels[lvl]);
        items.sort_by(|a, b| a.partial_cmp(b).expect("NaN filtered at update"));
        let offset = (self.rng.next_u32() & 1) as usize;
        let survivors: Vec<f64> = items.iter().skip(offset).step_by(2).copied().collect();
        self.levels[lvl + 1].extend_from_slice(&survivors);
        // The drained level stays empty; reuse its allocation.
        items.clear();
        self.levels[lvl] = items;
    }

    /// Nearest-rank quantile estimate: the weighted order statistic at
    /// rank `round(q·(count-1))`. Exact while no compaction has run.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mut items: Vec<(f64, u64)> = Vec::with_capacity(self.resident());
        for (lvl, level) in self.levels.iter().enumerate() {
            let w = 1u64 << lvl;
            items.extend(level.iter().map(|&v| (v, w)));
        }
        items.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN filtered at update"));
        let total: u64 = items.iter().map(|&(_, w)| w).sum();
        let target = (q.clamp(0.0, 1.0) * (total.saturating_sub(1)) as f64).round() as u64;
        let mut cum = 0u64;
        for &(v, w) in &items {
            cum += w;
            if cum > target {
                return v;
            }
        }
        self.max
    }

    /// Equal-width histogram over `[min, max]` from the weighted items.
    /// Exact while no compaction has run. Returns `(min, max, counts)`;
    /// `None` when empty.
    pub fn histogram(&self, bins: usize) -> Option<(f64, f64, Vec<u64>)> {
        if self.count == 0 || bins == 0 {
            return None;
        }
        let (lo, hi) = (self.min, self.max);
        let width = (hi - lo).max(f64::MIN_POSITIVE);
        let mut counts = vec![0u64; bins];
        for (lvl, level) in self.levels.iter().enumerate() {
            let w = 1u64 << lvl;
            for &v in level {
                let idx = (((v - lo) / width) * bins as f64) as usize;
                counts[idx.min(bins - 1)] += w;
            }
        }
        Some((lo, hi, counts))
    }

    /// Level-wise merge. Error bounds add; the merged sketch summarizes
    /// the concatenation of both streams.
    pub fn merge(&mut self, other: &QuantileSketch) {
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (lvl, level) in other.levels.iter().enumerate() {
            while self.levels.len() <= lvl {
                self.levels.push(Vec::new());
            }
            self.levels[lvl].extend_from_slice(level);
        }
        let mut lvl = 0;
        while lvl < self.levels.len() {
            while self.levels[lvl].len() >= self.k {
                self.compact(lvl);
            }
            lvl += 1;
        }
    }
}

/// Fixed-capacity uniform reservoir sample (Vitter's algorithm R).
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    items: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            items: Vec::new(),
            rng: Rng::new(seed),
        }
    }

    /// Stream length observed so far (not the resident count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn items(&self) -> &[f64] {
        &self.items
    }

    pub fn update(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.seen += 1;
        if self.items.len() < self.cap {
            self.items.push(v);
        } else {
            let j = self.rng.gen_range(self.seen as usize);
            if j < self.cap {
                self.items[j] = v;
            }
        }
    }

    /// Mean and standard deviation over the resident subsample.
    pub fn mean_std(&self) -> (f64, f64) {
        let mut w = Welford::new();
        for &v in &self.items {
            w.push(v);
        }
        (w.mean(), w.std())
    }

    /// Stream-concatenation merge: replays the other reservoir's
    /// resident items through [`Reservoir::update`] and credits its
    /// unseen mass. Deterministic; uniformity is approximate (exact
    /// mergeable reservoirs need per-item weights).
    pub fn merge(&mut self, other: &Reservoir) {
        let resident = other.items.len() as u64;
        for &v in &other.items {
            self.update(v);
        }
        self.seen += other.seen - resident;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, PropConfig};
    use crate::util::rng::derive_seed;

    fn exact_nearest_rank(sorted: &[f64], q: f64) -> f64 {
        let idx = (q * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx]
    }

    /// Estimated rank of `v` in the exact sorted stream: count of
    /// elements strictly below, which brackets any nearest-rank index
    /// of an equal value.
    fn rank_of(sorted: &[f64], v: f64) -> f64 {
        sorted.iter().take_while(|&&x| x < v).count() as f64
    }

    #[test]
    fn exact_below_capacity() {
        let mut sk = QuantileSketch::with_k(64, 7);
        let vals: Vec<f64> = (0..63).map(|i| (i * 37 % 63) as f64).collect();
        for &v in &vals {
            sk.update(v);
        }
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 1.0] {
            assert_eq!(
                sk.quantile(q),
                exact_nearest_rank(&sorted, q),
                "q={q} must be exact below capacity"
            );
        }
        assert_eq!(sk.min(), 0.0);
        assert_eq!(sk.max(), 62.0);
        assert_eq!(sk.count(), 63);
    }

    #[test]
    fn rank_error_bound_random_streams() {
        // Worst-case analytic bound: depth·n/k (see module docs).
        check(
            "sketch_rank_error_random",
            PropConfig { cases: 24, seed: 0x5EEDC, max_size: 8192 },
            |rng, size| {
                let n = size.max(8);
                let k = 128;
                let mut sk = QuantileSketch::with_k(k, rng.next_u64());
                let mut vals = Vec::with_capacity(n);
                for _ in 0..n {
                    let v = rng.normal() * 100.0;
                    sk.update(v);
                    vals.push(v);
                }
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let bound = (sk.depth() as f64) * (n as f64) / (k as f64) + 1.0;
                for q in [0.01, 0.1, 0.5, 0.9, 0.99] {
                    let est = sk.quantile(q);
                    let err = (rank_of(&vals, est) - q * (n - 1) as f64).abs();
                    crate::prop_assert!(
                        err <= bound,
                        "q={q} n={n}: rank error {err} > bound {bound}"
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rank_error_bound_adversarial_streams() {
        let n = 6000usize;
        let k = 128usize;
        let streams: Vec<(&str, Vec<f64>)> = vec![
            ("sorted_asc", (0..n).map(|i| i as f64).collect()),
            ("sorted_desc", (0..n).rev().map(|i| i as f64).collect()),
            ("constant", vec![42.0; n]),
            ("sawtooth", (0..n).map(|i| (i % 17) as f64).collect()),
        ];
        for (name, vals) in streams {
            let mut sk = QuantileSketch::with_k(k, derive_seed(0xADE5, 1));
            for &v in &vals {
                sk.update(v);
            }
            let mut sorted = vals.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let bound = (sk.depth() as f64) * (n as f64) / (k as f64) + 1.0;
            for q in [0.05, 0.5, 0.95] {
                let est = sk.quantile(q);
                let err = (rank_of(&sorted, est) - q * (n - 1) as f64).abs();
                assert!(
                    err <= bound,
                    "{name} q={q}: rank error {err} > bound {bound}"
                );
            }
        }
    }

    #[test]
    fn memory_stays_bounded() {
        let k = 64usize;
        let mut sk = QuantileSketch::with_k(k, 3);
        for i in 0..200_000u64 {
            sk.update((i % 1000) as f64);
        }
        // Cascade: every level strictly below capacity after update.
        assert!(sk.resident() <= k * sk.depth());
        assert!(sk.depth() <= 16, "depth {} too deep for n=2e5", sk.depth());
        assert_eq!(sk.count(), 200_000);
    }

    #[test]
    fn merge_summarizes_both_streams() {
        let mut a = QuantileSketch::with_k(64, 11);
        let mut b = QuantileSketch::with_k(64, 12);
        for i in 0..3000 {
            a.update(i as f64); // [0, 3000)
            b.update(3000.0 + i as f64); // [3000, 6000)
        }
        a.merge(&b);
        assert_eq!(a.count(), 6000);
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 5999.0);
        let med = a.quantile(0.5);
        assert!(
            (med - 3000.0).abs() < 600.0,
            "merged median {med} far from 3000"
        );
        assert!(a.resident() <= 64 * a.depth());
    }

    #[test]
    fn histogram_covers_range_and_mass() {
        let mut sk = QuantileSketch::with_k(256, 5);
        for i in 0..100 {
            sk.update(i as f64);
        }
        let (lo, hi, counts) = sk.histogram(8).unwrap();
        assert_eq!(lo, 0.0);
        assert_eq!(hi, 99.0);
        assert_eq!(counts.len(), 8);
        assert_eq!(counts.iter().sum::<u64>(), 100);
        assert!(counts.iter().all(|&c| c > 0));
        assert!(QuantileSketch::new(1).histogram(8).is_none());
    }

    #[test]
    fn nan_dropped_empty_is_zero() {
        let mut sk = QuantileSketch::new(9);
        sk.update(f64::NAN);
        assert!(sk.is_empty());
        assert_eq!(sk.quantile(0.5), 0.0);
        sk.update(7.0);
        assert_eq!(sk.quantile(0.5), 7.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let feed = |seed| {
            let mut sk = QuantileSketch::with_k(32, seed);
            let mut r = Rng::new(99);
            for _ in 0..5000 {
                sk.update(r.next_f64());
            }
            (sk.quantile(0.5), sk.quantile(0.95))
        };
        assert_eq!(feed(1234), feed(1234));
    }

    #[test]
    fn reservoir_uniformity_and_merge() {
        let mut r = Reservoir::new(100, 21);
        for i in 0..10_000 {
            r.update(i as f64);
        }
        assert_eq!(r.seen(), 10_000);
        assert_eq!(r.items().len(), 100);
        let (mean, std) = r.mean_std();
        // Uniform [0, 1e4): mean ~5000 ± ~3*std/sqrt(100) ≈ ±870.
        assert!((mean - 5000.0).abs() < 1200.0, "reservoir mean {mean}");
        assert!(std > 1000.0, "reservoir std {std} too small for uniform");

        let mut other = Reservoir::new(100, 22);
        for i in 0..500 {
            other.update(i as f64);
        }
        r.merge(&other);
        assert_eq!(r.seen(), 10_500);
        assert_eq!(r.items().len(), 100);
    }
}

//! Incremental convergence probes: Φ_t and server–client discrepancy in
//! O(touched·d) per round instead of the O(n·d) dense folds.
//!
//! The paper's potential (Section 3.3, Lemma 3.4)
//!
//! ```text
//! Φ_t = ‖X_t − μ_t‖² + Σᵢ‖Xⁱ − μ_t‖²,   μ_t = (X_t + Σᵢ Xⁱ)/(n+1)
//! ```
//!
//! needs two fleet aggregates: `Σᵢ Xⁱ` and `Σᵢ‖Xⁱ‖²`-type mass. Both
//! are maintainable incrementally because a round only rewrites the
//! *touched* clients (the same CoW-divergence observation that makes the
//! fleet store O(touched·d)). To keep the update cancellation-safe the
//! probe centers every vector at the shared init `X₀` (all clients start
//! there, so deviations stay small relative to the weights themselves):
//!
//! - `sum_dev  = Σᵢ (Xⁱ − X₀)`  (f64, updated per touched coordinate)
//! - `sumsq_dev = Σᵢ ‖Xⁱ − X₀‖²` (f64 scalar)
//!
//! With `v = X_t − X₀` and `m = μ_t − X₀ = (v + sum_dev)/(n+1)`:
//!
//! ```text
//! Φ_t = ‖v − m‖² + sumsq_dev − 2⟨m, sum_dev⟩ + n‖m‖²
//! discrepancy = ‖X_t − (Σᵢ Xⁱ)/n‖ = ‖v − sum_dev/n‖
//! ```
//!
//! Each client write costs O(d) (`note_write`), each query O(d) — the
//! per-round total is O(touched·d), independent of n. The dense folds
//! ([`crate::algorithms::quafl::potential_view`],
//! [`server_client_discrepancy_view`]) are retained as the parity
//! oracles; `rust/tests/telemetry_parity.rs` proves agreement within the
//! documented fp-fold tolerance (the oracle accumulates μ in f32, the
//! probe in f64 — the folds are different, so agreement is relative, not
//! bitwise; see docs/TELEMETRY.md §Probes).
//!
//! [`server_client_discrepancy_view`]: crate::algorithms::quafl::server_client_discrepancy_view

/// Incremental Φ_t / discrepancy state for one fleet.
#[derive(Debug, Clone)]
pub struct DivergenceProbe {
    /// the common init X₀ every model started from (centering point)
    base: Vec<f32>,
    n: usize,
    sum_dev: Vec<f64>,
    sumsq_dev: f64,
    writes: u64,
}

impl DivergenceProbe {
    /// `base` is the shared initial model (all n clients start there, so
    /// every deviation is initially zero).
    pub fn new(base: Vec<f32>, n: usize) -> DivergenceProbe {
        let d = base.len();
        DivergenceProbe {
            base,
            n,
            sum_dev: vec![0.0; d],
            sumsq_dev: 0.0,
            writes: 0,
        }
    }

    /// Record one client-model overwrite `old → new` (call immediately
    /// before the fleet-store `set`/`set_shared`). O(d).
    pub fn note_write(&mut self, old: &[f32], new: &[f32]) {
        debug_assert_eq!(old.len(), self.base.len());
        debug_assert_eq!(new.len(), self.base.len());
        let mut dsq = 0.0f64;
        for j in 0..self.base.len() {
            let b = self.base[j] as f64;
            let od = old[j] as f64 - b;
            let nd = new[j] as f64 - b;
            self.sum_dev[j] += nd - od;
            dsq += nd * nd - od * od;
        }
        self.sumsq_dev += dsq;
        self.writes += 1;
    }

    /// Total `note_write` calls (diagnostic: per-round cost is
    /// `writes·d`, not `n·d`).
    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Φ_t given the current server model. O(d).
    pub fn potential(&self, x_server: &[f32]) -> f64 {
        let n1 = (self.n + 1) as f64;
        let mut server_term = 0.0f64; // ‖v − m‖²
        let mut cross = 0.0f64; // ⟨m, sum_dev⟩
        let mut m_sq = 0.0f64; // ‖m‖²
        for j in 0..self.base.len() {
            let v = x_server[j] as f64 - self.base[j] as f64;
            let m = (v + self.sum_dev[j]) / n1;
            let sv = v - m;
            server_term += sv * sv;
            cross += m * self.sum_dev[j];
            m_sq += m * m;
        }
        // Σᵢ‖Xⁱ − μ‖² = sumsq_dev − 2⟨m, sum_dev⟩ + n‖m‖² can round to a
        // tiny negative when every deviation is ~0; clamp keeps Φ ≥ 0.
        server_term + (self.sumsq_dev - 2.0 * cross + self.n as f64 * m_sq).max(0.0)
    }

    /// ‖X_t − (Σᵢ Xⁱ)/n‖ given the current server model. O(d).
    pub fn discrepancy(&self, x_server: &[f32]) -> f64 {
        let n = self.n as f64;
        let mut acc = 0.0f64;
        for j in 0..self.base.len() {
            let v = x_server[j] as f64 - self.base[j] as f64;
            let diff = v - self.sum_dev[j] / n;
            acc += diff * diff;
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::quafl::{potential, server_client_discrepancy};
    use crate::testing::{check, close, PropConfig};

    #[test]
    fn zero_state_matches_oracles() {
        let d = 8;
        let base = vec![0.5f32; d];
        let probe = DivergenceProbe::new(base.clone(), 4);
        let clients = vec![base.clone(); 4];
        // All clients at X₀, server at X₀: Φ = 0, discrepancy = 0.
        assert!(probe.potential(&base) < 1e-12);
        assert!(probe.discrepancy(&base) < 1e-12);
        assert!(potential(&base, &clients) < 1e-12);
        // Server moves, clients stay: both track the oracle.
        let x: Vec<f32> = base.iter().map(|v| v + 1.0).collect();
        assert!(close(probe.potential(&x), potential(&x, &clients), 1e-9));
        assert!(close(
            probe.discrepancy(&x),
            server_client_discrepancy(&x, &clients),
            1e-9
        ));
    }

    #[test]
    fn random_write_sequences_match_dense_oracles() {
        // The oracle folds μ in f32; the probe accumulates in f64.
        // Agreement is therefore relative (documented fp-fold tolerance),
        // not bitwise — 1e-4 is ~30x the worst drift seen at these sizes.
        check(
            "probe_vs_dense_oracles",
            PropConfig { cases: 32, seed: 0xD17E, max_size: 24 },
            |rng, size| {
                let n = 1 + size % 12;
                let d = 1 + size;
                let base: Vec<f32> =
                    (0..d).map(|_| rng.normal() as f32).collect();
                let mut clients = vec![base.clone(); n];
                let mut probe = DivergenceProbe::new(base.clone(), n);
                let mut x_server = base.clone();
                for _ in 0..3 * n {
                    let i = rng.gen_range(n);
                    let newv: Vec<f32> = (0..d)
                        .map(|j| clients[i][j] + rng.normal() as f32 * 0.3)
                        .collect();
                    probe.note_write(&clients[i], &newv);
                    clients[i] = newv;
                    for v in x_server.iter_mut() {
                        *v += rng.normal() as f32 * 0.05;
                    }
                    let (got_phi, want_phi) =
                        (probe.potential(&x_server), potential(&x_server, &clients));
                    crate::prop_assert!(
                        close(got_phi, want_phi, 1e-4),
                        "phi probe {got_phi} vs dense {want_phi} (n={n} d={d})"
                    );
                    let (got_dsc, want_dsc) = (
                        probe.discrepancy(&x_server),
                        server_client_discrepancy(&x_server, &clients),
                    );
                    crate::prop_assert!(
                        close(got_dsc, want_dsc, 1e-4),
                        "discrepancy probe {got_dsc} vs dense {want_dsc}"
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cost_is_touched_not_fleet_size() {
        // A million-client probe must only pay for the writes it sees.
        let d = 16;
        let n = 1_000_000;
        let base = vec![0.0f32; d];
        let mut probe = DivergenceProbe::new(base.clone(), n);
        let old = base.clone();
        let new: Vec<f32> = (0..d).map(|j| j as f32 * 0.01).collect();
        for _ in 0..10 {
            probe.note_write(&old, &new);
            probe.note_write(&new, &old);
        }
        probe.note_write(&old, &new);
        assert_eq!(probe.writes(), 21);
        let x = vec![0.0f32; d];
        // Exactly one client deviates; Φ = ‖m‖²·(n+1-term algebra) > 0.
        assert!(probe.potential(&x) > 0.0);
        assert!(probe.discrepancy(&x) > 0.0);
    }
}

//! Fleet-health aggregation: `quafl health-report FILE.jsonl`.
//!
//! The sibling of `quafl trace-report`: where trace-report renders
//! phase timings from `span`/`counter`/`sample` events, health-report
//! renders the *convergence diagnostics* from `metric` events (the
//! [`super::Telemetry`] flush stream) — per-round convergence curves
//! (Φ_t, discrepancy), distribution quantiles per sketch-backed metric,
//! and the selection bias/Gini summary — and writes `BENCH_health.json`
//! in the canonical `{bench, rows}` shape shared with the other BENCH
//! artifacts. Unknown event kinds are skipped, never fatal (same
//! forward-compat contract as trace-report).

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

/// Sketch-summary suffixes the registry flush composes (see
/// [`super::Telemetry::flush`]); health-report folds `name_p50` etc.
/// back into one distribution row per stem.
const DIST_SUFFIXES: &[&str] = &["_p50", "_p95", "_max", "_n", "_rmean", "_rstd"];

/// Metrics rendered as convergence curves, in display order.
const CURVE_ORDER: &[&str] = &["phi", "discrepancy", "client_loss_rmean"];

/// Metrics rendered in the bias summary.
const BIAS_ORDER: &[&str] = &["select_chi2", "gini"];

/// Trace-counter prefix of the fault-recovery family ([`crate::fault`]);
/// these ride the `counter` stream but belong on the health dashboard.
const FAULT_PREFIX: &str = "fault_";

/// One metric's per-round series, in event order.
#[derive(Debug, Default, Clone)]
pub struct Series {
    /// (round, value) per flush, in stream order
    pub points: Vec<(u64, f64)>,
}

impl Series {
    pub fn first(&self) -> f64 {
        self.points.first().map(|p| p.1).unwrap_or(0.0)
    }

    pub fn last(&self) -> f64 {
        self.points.last().map(|p| p.1).unwrap_or(0.0)
    }

    pub fn min(&self) -> f64 {
        self.points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.1)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Aggregated fleet-health view of one trace file.
#[derive(Debug, Default)]
pub struct HealthReport {
    pub events: usize,
    pub metric_points: usize,
    /// `algorithm` field of every `meta` header seen (one per run).
    pub runs: Vec<String>,
    pub series: BTreeMap<String, Series>,
    pub skipped: usize,
}

/// Fold a parsed event stream into a health report. `meta` and `metric`
/// kinds contribute, plus `counter` events in the `fault_*` family
/// (chaos outcomes belong on the health dashboard; other counters stay
/// with trace-report's phase view); everything else is counted as
/// skipped.
pub fn aggregate(events: &[Json]) -> HealthReport {
    let mut r = HealthReport::default();
    for e in events {
        r.events += 1;
        match e.get("kind").and_then(|k| k.as_str()) {
            Some("meta") => r.runs.push(
                e.get("algorithm")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string(),
            ),
            Some("metric") => {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let round = e.get("round").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                r.metric_points += 1;
                r.series.entry(name).or_default().points.push((round, value));
            }
            Some("counter")
                if e.get("name")
                    .and_then(|v| v.as_str())
                    .is_some_and(|n| n.starts_with(FAULT_PREFIX)) =>
            {
                let name = e
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string();
                let round = e.get("round").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let value = e.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                r.metric_points += 1;
                r.series.entry(name).or_default().points.push((round, value));
            }
            _ => r.skipped += 1,
        }
    }
    r
}

/// Downsampled ASCII sparkline of a series, normalized to its own
/// min..max (constant series render flat).
fn sparkline(points: &[(u64, f64)], width: usize) -> String {
    const RAMP: &[u8] = b" .:-=+*#";
    if points.is_empty() {
        return String::new();
    }
    let lo = points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let hi = points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(f64::MIN_POSITIVE);
    let w = width.min(points.len()).max(1);
    let mut out = String::with_capacity(w);
    for c in 0..w {
        // Mean of the chunk of points covering this column.
        let start = c * points.len() / w;
        let end = ((c + 1) * points.len() / w).max(start + 1);
        let mean = points[start..end].iter().map(|p| p.1).sum::<f64>()
            / (end - start) as f64;
        let idx = (((mean - lo) / span) * (RAMP.len() - 1) as f64).round() as usize;
        out.push(RAMP[idx.min(RAMP.len() - 1)] as char);
    }
    out
}

impl HealthReport {
    /// Distribution stems: metric names that arrived as sketch/reservoir
    /// summaries (`qerr_p50`, ...), folded back to their stem (`qerr`).
    fn dist_stems(&self) -> Vec<String> {
        let mut stems: Vec<String> = Vec::new();
        for name in self.series.keys() {
            for suf in DIST_SUFFIXES {
                if let Some(stem) = name.strip_suffix(suf) {
                    if !stem.is_empty() && !stems.iter().any(|s| s == stem) {
                        stems.push(stem.to_string());
                    }
                }
            }
        }
        stems
    }

    fn stat(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// The fleet-health dashboard (what `quafl health-report` prints).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "health: {} metric points across {} metrics ({} run(s): {})\n",
            self.metric_points,
            self.series.len(),
            self.runs.len(),
            if self.runs.is_empty() {
                "no meta header".to_string()
            } else {
                self.runs.join(", ")
            },
        ));
        if self.metric_points == 0 {
            s.push_str(
                "no metric events: run with --trace FILE.jsonl (telemetry \
                 rides the trace sink; see docs/TELEMETRY.md)\n",
            );
            return s;
        }

        // Convergence curves: the quantities the paper's analysis bounds.
        let curves: Vec<&str> = CURVE_ORDER
            .iter()
            .copied()
            .filter(|n| self.series.contains_key(*n))
            .collect();
        if !curves.is_empty() {
            s.push_str(&format!(
                "\n{:<18} {:>7} {:>12} {:>12} {:>12} {:>12}  trend\n",
                "convergence", "points", "first", "last", "min", "max"
            ));
            for name in curves {
                let sr = &self.series[name];
                s.push_str(&format!(
                    "{:<18} {:>7} {:>12.5} {:>12.5} {:>12.5} {:>12.5}  [{}]\n",
                    name,
                    sr.points.len(),
                    sr.first(),
                    sr.last(),
                    sr.min(),
                    sr.max(),
                    sparkline(&sr.points, 32),
                ));
            }
        }

        // Distribution quantiles per sketch-backed metric (last flush =
        // the full-run distribution).
        let stems = self.dist_stems();
        if !stems.is_empty() {
            s.push_str(&format!(
                "\n{:<14} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}\n",
                "distribution", "n", "p50", "p95", "max", "rmean", "rstd"
            ));
            for stem in &stems {
                let last = |suf: &str| -> String {
                    self.stat(&format!("{stem}{suf}"))
                        .map(|sr| format!("{:.5}", sr.last()))
                        .unwrap_or_else(|| "-".to_string())
                };
                let n = self
                    .stat(&format!("{stem}_n"))
                    .map(|sr| format!("{:.0}", sr.last()))
                    .unwrap_or_else(|| "-".to_string());
                s.push_str(&format!(
                    "{:<14} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}\n",
                    stem,
                    n,
                    last("_p50"),
                    last("_p95"),
                    last("_max"),
                    last("_rmean"),
                    last("_rstd"),
                ));
            }
        }

        // Selection bias: chi-square vs. uniform and the Gini coefficient.
        let bias: Vec<&str> = BIAS_ORDER
            .iter()
            .copied()
            .filter(|n| self.series.contains_key(*n))
            .collect();
        if !bias.is_empty() {
            s.push_str(&format!(
                "\n{:<18} {:>12} {:>12}  (0 = uniform service)\n",
                "bias", "last", "max"
            ));
            for name in bias {
                let sr = &self.series[name];
                s.push_str(&format!(
                    "{:<18} {:>12.5} {:>12.5}\n",
                    name,
                    sr.last(),
                    sr.max()
                ));
            }
        }

        // Fault-recovery counters (cumulative — `last` is the run total).
        let faults: Vec<&String> = self
            .series
            .keys()
            .filter(|n| n.starts_with(FAULT_PREFIX))
            .collect();
        if !faults.is_empty() {
            s.push_str(&format!(
                "\n{:<22} {:>7} {:>12}  (cumulative; last = run total)\n",
                "faults", "points", "last"
            ));
            for name in &faults {
                let sr = &self.series[name.as_str()];
                s.push_str(&format!(
                    "{:<22} {:>7} {:>12.5}\n",
                    name,
                    sr.points.len(),
                    sr.last()
                ));
            }
        }

        // Anything not already shown above.
        let mut covered: Vec<String> = CURVE_ORDER
            .iter()
            .chain(BIAS_ORDER.iter())
            .map(|s| s.to_string())
            .collect();
        for stem in &stems {
            for suf in DIST_SUFFIXES {
                covered.push(format!("{stem}{suf}"));
            }
        }
        let other: Vec<&String> = self
            .series
            .keys()
            .filter(|n| !covered.contains(n) && !n.starts_with(FAULT_PREFIX))
            .collect();
        if !other.is_empty() {
            s.push_str(&format!(
                "\n{:<18} {:>7} {:>12}\n",
                "other", "points", "last"
            ));
            for name in other {
                let sr = &self.series[name];
                s.push_str(&format!(
                    "{:<18} {:>7} {:>12.5}\n",
                    name,
                    sr.points.len(),
                    sr.last()
                ));
            }
        }
        s
    }

    /// The canonical `BENCH_health.json` document: one row per metric
    /// series, `{bench: "fleet_health", rows}` — same shape as
    /// `BENCH_phase.json` and friends.
    pub fn bench_json(&self) -> Json {
        let mut rows = Vec::new();
        for (name, sr) in &self.series {
            let mut row = BTreeMap::new();
            row.insert("kind".into(), Json::Str("metric".into()));
            row.insert("name".into(), Json::Str(name.clone()));
            row.insert("points".into(), Json::Num(sr.points.len() as f64));
            row.insert("first".into(), Json::Num(sr.first()));
            row.insert("last".into(), Json::Num(sr.last()));
            row.insert("min".into(), Json::Num(sr.min()));
            row.insert("max".into(), Json::Num(sr.max()));
            row.insert(
                "round_last".into(),
                Json::Num(sr.points.last().map(|p| p.0).unwrap_or(0) as f64),
            );
            rows.push(Json::Obj(row));
        }
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("fleet_health".into()));
        doc.insert("rows".into(), Json::Arr(rows));
        Json::Obj(doc)
    }

    /// Write `BENCH_health.json` under `out_dir`; returns the path.
    pub fn write_bench(&self, out_dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(out_dir)?;
        let path = format!("{out_dir}/BENCH_health.json");
        std::fs::write(&path, json::to_string(&self.bench_json()) + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Event;

    fn metric(name: &str, round: u64, value: f64) -> Json {
        Event::Metric {
            name: name.to_string(),
            round,
            value,
            sim_now: round as f64,
        }
        .to_json()
    }

    fn meta(algorithm: &str) -> Json {
        Event::Meta {
            fields: vec![("algorithm", Json::Str(algorithm.to_string()))],
        }
        .to_json()
    }

    #[test]
    fn aggregates_series_and_skips_other_kinds() {
        let events = vec![
            meta("QuAFL"),
            metric("phi", 0, 8.0),
            metric("phi", 1, 4.0),
            metric("qerr_p50", 1, 0.5),
            metric("qerr_p95", 1, 0.9),
            metric("qerr_n", 1, 40.0),
            metric("select_chi2", 1, 1.25),
            Event::Sample {
                name: "delay",
                round: 0,
                value: 0.1,
            }
            .to_json(),
        ];
        let r = aggregate(&events);
        assert_eq!(r.runs, vec!["QuAFL".to_string()]);
        assert_eq!(r.metric_points, 6);
        assert_eq!(r.skipped, 1);
        let phi = &r.series["phi"];
        assert_eq!(phi.points, vec![(0, 8.0), (1, 4.0)]);
        assert_eq!(phi.first(), 8.0);
        assert_eq!(phi.last(), 4.0);
        assert_eq!(r.dist_stems(), vec!["qerr".to_string()]);
    }

    #[test]
    fn render_has_all_sections() {
        let mut events = vec![meta("QuAFL")];
        for t in 0..12u64 {
            events.push(metric("phi", t, 10.0 / (t + 1) as f64));
            events.push(metric("discrepancy", t, 1.0 / (t + 1) as f64));
            events.push(metric("qerr_p50", t, 0.5));
            events.push(metric("qerr_p95", t, 0.9));
            events.push(metric("qerr_max", t, 1.1));
            events.push(metric("qerr_n", t, (t + 1) as f64 * 4.0));
            events.push(metric("select_chi2", t, 0.3));
            events.push(metric("gini", t, 0.12));
            events.push(metric("custom_counter", t, t as f64));
        }
        let r = aggregate(&events);
        let text = r.render();
        assert!(text.contains("convergence"), "{text}");
        assert!(text.contains("phi"), "{text}");
        assert!(text.contains("discrepancy"), "{text}");
        assert!(text.contains("distribution"), "{text}");
        assert!(text.contains("qerr"), "{text}");
        assert!(text.contains("bias"), "{text}");
        assert!(text.contains("select_chi2"), "{text}");
        assert!(text.contains("gini"), "{text}");
        assert!(text.contains("custom_counter"), "{text}");
        assert!(text.contains("QuAFL"), "{text}");
    }

    #[test]
    fn fault_counters_fold_into_dedicated_section() {
        let counter = |name: &'static str, round: u64, value: f64| {
            Event::Counter {
                name,
                round,
                value,
                sim_now: round as f64,
            }
            .to_json()
        };
        let events = vec![
            meta("QuAFL"),
            metric("phi", 0, 2.0),
            counter("fault_retries", 0, 3.0),
            counter("fault_retries", 1, 7.0),
            counter("fault_evictions", 1, 1.0),
            // Non-fault counters stay with trace-report.
            counter("interactions", 1, 40.0),
        ];
        let r = aggregate(&events);
        assert_eq!(r.skipped, 1, "non-fault counter must be skipped");
        assert_eq!(r.series["fault_retries"].last(), 7.0);
        assert_eq!(r.series["fault_evictions"].points.len(), 1);
        let text = r.render();
        assert!(text.contains("faults"), "{text}");
        assert!(text.contains("fault_retries"), "{text}");
        // Fault series must not repeat in the `other` bucket.
        assert_eq!(text.matches("fault_retries").count(), 1, "{text}");
        assert!(!text.contains("interactions"), "{text}");
    }

    #[test]
    fn empty_stream_renders_hint() {
        let r = aggregate(&[]);
        let text = r.render();
        assert!(text.contains("no metric events"), "{text}");
    }

    #[test]
    fn bench_json_is_canonical() {
        let events = vec![
            metric("phi", 0, 4.0),
            metric("phi", 3, 1.0),
            metric("gini", 3, 0.2),
        ];
        let r = aggregate(&events);
        let doc = r.bench_json();
        assert_eq!(
            doc.get("bench").and_then(|v| v.as_str()),
            Some("fleet_health")
        );
        let rows = doc.get("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 2);
        let phi = rows
            .iter()
            .find(|r| r.get("name").and_then(|n| n.as_str()) == Some("phi"))
            .unwrap();
        assert_eq!(phi.get("first").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(phi.get("last").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(phi.get("round_last").and_then(|v| v.as_f64()), Some(3.0));
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn sparkline_is_monotone_for_decay() {
        let points: Vec<(u64, f64)> = (0..64).map(|t| (t, 64.0 - t as f64)).collect();
        let line = sparkline(&points, 16);
        assert_eq!(line.len(), 16);
        assert!(line.starts_with('#'));
        assert!(line.ends_with(' '));
        assert_eq!(sparkline(&[], 16), "");
        // Constant series: flat, no panic on zero span.
        let flat = sparkline(&[(0, 1.0), (1, 1.0)], 8);
        assert_eq!(flat.len(), 2);
    }
}

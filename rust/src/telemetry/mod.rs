//! Fleet telemetry & convergence diagnostics (L3-telemetry).
//!
//! A typed streaming-metrics registry riding the trace layer: counters,
//! gauges, and fixed-memory distribution sketches
//! ([`sketch::QuantileSketch`], [`sketch::Reservoir`]) that flush at
//! round boundaries as `metric` events on the armed [`crate::trace`]
//! sink. The convergence probes ([`probe::DivergenceProbe`]) maintain
//! the paper's potential Φ_t and the server–client discrepancy
//! incrementally from fleet-store write deltas in O(touched·d) per
//! round — the dense folds in [`crate::algorithms::quafl`] remain the
//! parity oracles.
//!
//! Design rules, inherited from the trace layer and enforced by
//! rust/tests/telemetry_parity.rs:
//!
//! - **Bit-exact when armed.** No telemetry path draws from a
//!   simulation RNG stream or reorders a trajectory float fold; the
//!   sketches own their RNGs. Arming telemetry changes bytes on the
//!   sink, never a trajectory value.
//! - **Zero overhead when off.** Every registry mutator starts with a
//!   branch on the `armed` bool; a disarmed registry allocates nothing
//!   and the probes are simply not constructed (except when
//!   `--track-potential` asks for Φ_t in the run metrics, where the
//!   probe runs identically with or without a sink).
//! - **Fixed memory.** Distribution state is O(k·log n) per metric
//!   regardless of stream length, so per-interaction observations stay
//!   affordable at n = 10⁶.
//!
//! Metric catalog, per-algorithm coverage, and sketch error bounds:
//! `docs/TELEMETRY.md`. Aggregation (`quafl health-report`,
//! `BENCH_health.json`) lives in [`health`].

pub mod health;
pub mod probe;
pub mod sketch;

use crate::trace::Tracer;
use crate::util::rng::derive_seed;
use sketch::{QuantileSketch, Reservoir};

/// Canonical metric names (the stable identifiers in the `metric` event
/// stream — see docs/TELEMETRY.md before renaming anything here).
pub mod names {
    /// incremental potential Φ_t (QuAFL, FedBuff)
    pub const PHI: &str = "phi";
    /// ‖X_t − mean(Xⁱ)‖ server–client discrepancy (QuAFL, FedBuff)
    pub const DISCREPANCY: &str = "discrepancy";
    /// per-exchange quantization-error norm ‖y − Dec(Enc(y))‖ (sketch)
    pub const QERR: &str = "qerr";
    /// per-interaction mean local training loss (sketch + reservoir)
    pub const CLIENT_LOSS: &str = "client_loss";
    /// per-interaction downlink+uplink delay seconds (sketch)
    pub const DELAY: &str = "delay";
    /// model-version lag of admitted FedBuff updates (sketch)
    pub const STALENESS: &str = "staleness";
    /// chi-square statistic of participation counts vs. uniform
    pub const SELECT_CHI2: &str = "select_chi2";
    /// participation Gini coefficient (0 = perfectly uniform service)
    pub const GINI: &str = "gini";
}

/// Reservoir capacity for per-client observation subsamples.
const RESERVOIR_CAP: usize = 256;

/// The streaming-metrics registry threaded through the algorithms. One
/// instance per run; all lookups are linear scans over a handful of
/// entries (the catalog is small and static).
pub struct Telemetry {
    armed: bool,
    seed: u64,
    counters: Vec<(&'static str, f64)>,
    gauges: Vec<(&'static str, f64)>,
    sketches: Vec<(&'static str, QuantileSketch)>,
    reservoirs: Vec<(&'static str, Reservoir)>,
}

impl Telemetry {
    /// `armed` gates every mutator; `seed` derives the private RNG
    /// stream of each sketch (never the simulation's streams).
    pub fn new(armed: bool, seed: u64) -> Telemetry {
        Telemetry {
            armed,
            seed: derive_seed(seed, 0x7E1E),
            counters: Vec::new(),
            gauges: Vec::new(),
            sketches: Vec::new(),
            reservoirs: Vec::new(),
        }
    }

    #[inline]
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Add to a cumulative counter (created on first touch).
    pub fn counter_add(&mut self, name: &'static str, delta: f64) {
        if !self.armed {
            return;
        }
        match self.counters.iter_mut().find(|(n, _)| *n == name) {
            Some((_, v)) => *v += delta,
            None => self.counters.push((name, delta)),
        }
    }

    /// Set a point-in-time gauge (flushed as its latest value).
    pub fn gauge_set(&mut self, name: &'static str, value: f64) {
        if !self.armed {
            return;
        }
        match self.gauges.iter_mut().find(|(n, _)| *n == name) {
            Some((_, v)) => *v = value,
            None => self.gauges.push((name, value)),
        }
    }

    /// Feed one observation into the named quantile sketch.
    pub fn observe(&mut self, name: &'static str, value: f64) {
        if !self.armed {
            return;
        }
        if let Some((_, sk)) = self.sketches.iter_mut().find(|(n, _)| *n == name) {
            sk.update(value);
            return;
        }
        let sk_seed = derive_seed(self.seed, self.sketches.len() as u64);
        let mut sk = QuantileSketch::new(sk_seed);
        sk.update(value);
        self.sketches.push((name, sk));
    }

    /// Feed one observation into the named reservoir subsample.
    pub fn observe_sampled(&mut self, name: &'static str, value: f64) {
        if !self.armed {
            return;
        }
        if let Some((_, r)) = self.reservoirs.iter_mut().find(|(n, _)| *n == name) {
            r.update(value);
            return;
        }
        let r_seed = derive_seed(self.seed, 0x4E5 ^ self.reservoirs.len() as u64);
        let mut r = Reservoir::new(RESERVOIR_CAP, r_seed);
        r.update(value);
        self.reservoirs.push((name, r));
    }

    /// Direct access for tests and the report layer.
    pub fn sketch(&self, name: &str) -> Option<&QuantileSketch> {
        self.sketches.iter().find(|(n, _)| *n == name).map(|(_, s)| s)
    }

    /// Flush the registry as `metric` events at a round boundary.
    /// Counters/gauges emit their current value; each sketch emits its
    /// cumulative `_p50`/`_p95`/`_max`/`_n` summary (the distribution of
    /// *all* observations so far — the last flush is the full-run one);
    /// each reservoir emits `_rmean`/`_rstd` over its subsample.
    pub fn flush(&self, tracer: &Tracer, round: u64, sim_now: f64) {
        if !self.armed {
            return;
        }
        for (name, v) in &self.counters {
            tracer.metric(name, round, *v, sim_now);
        }
        for (name, v) in &self.gauges {
            tracer.metric(name, round, *v, sim_now);
        }
        for (name, sk) in &self.sketches {
            if sk.is_empty() {
                continue;
            }
            tracer.metric(&format!("{name}_p50"), round, sk.quantile(0.5), sim_now);
            tracer.metric(&format!("{name}_p95"), round, sk.quantile(0.95), sim_now);
            tracer.metric(&format!("{name}_max"), round, sk.max(), sim_now);
            tracer.metric(&format!("{name}_n"), round, sk.count() as f64, sim_now);
        }
        for (name, r) in &self.reservoirs {
            if r.seen() == 0 {
                continue;
            }
            let (mean, std) = r.mean_std();
            tracer.metric(&format!("{name}_rmean"), round, mean, sim_now);
            tracer.metric(&format!("{name}_rstd"), round, std, sim_now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Event, Level, RingSink, Tracer};
    use std::sync::Arc;

    #[test]
    fn disarmed_registry_is_inert() {
        let mut tel = Telemetry::new(false, 42);
        tel.counter_add("c", 1.0);
        tel.gauge_set("g", 2.0);
        tel.observe("s", 3.0);
        tel.observe_sampled("r", 4.0);
        assert!(tel.counters.is_empty());
        assert!(tel.gauges.is_empty());
        assert!(tel.sketches.is_empty());
        assert!(tel.reservoirs.is_empty());
        let ring = Arc::new(RingSink::new());
        tel.flush(&Tracer::new(ring.clone(), Level::Info), 0, 0.0);
        assert!(ring.is_empty());
    }

    #[test]
    fn armed_registry_flushes_metric_events() {
        let mut tel = Telemetry::new(true, 42);
        tel.counter_add("bits", 10.0);
        tel.counter_add("bits", 5.0);
        tel.gauge_set(names::PHI, 1.25);
        tel.gauge_set(names::PHI, 0.75);
        for i in 0..100 {
            tel.observe(names::QERR, i as f64);
            tel.observe_sampled(names::CLIENT_LOSS, i as f64);
        }
        let ring = Arc::new(RingSink::new());
        tel.flush(&Tracer::new(ring.clone(), Level::Info), 7, 3.5);
        let evs = ring.events();
        let get = |want: &str| -> f64 {
            evs.iter()
                .find_map(|e| match e {
                    Event::Metric { name, round, value, .. }
                        if name == want && *round == 7 =>
                    {
                        Some(*value)
                    }
                    _ => None,
                })
                .unwrap_or_else(|| panic!("metric {want} not flushed"))
        };
        assert_eq!(get("bits"), 15.0);
        assert_eq!(get("phi"), 0.75);
        assert_eq!(get("qerr_n"), 100.0);
        assert_eq!(get("qerr_max"), 99.0);
        assert_eq!(get("qerr_p50"), 50.0); // exact below sketch capacity
        assert_eq!(get("client_loss_rmean"), 49.5);
        assert!(get("client_loss_rstd") > 0.0);
    }

    #[test]
    fn sketch_lookup_and_determinism() {
        let mk = || {
            let mut tel = Telemetry::new(true, 7);
            for i in 0..2000 {
                tel.observe(names::DELAY, (i % 37) as f64);
            }
            tel.sketch(names::DELAY).unwrap().quantile(0.9)
        };
        assert_eq!(mk(), mk());
        let tel = Telemetry::new(true, 7);
        assert!(tel.sketch("nope").is_none());
    }
}

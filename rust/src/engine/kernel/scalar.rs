//! The scalar reference kernel: byte-for-byte the loops `engine/native.rs`
//! ran before the kernel subsystem existed (ikj order, per-element
//! zero-skip branches, plain `a*b + c` rounding). Every other backend is
//! validated against this one — bit-exactly for `blocked`, within a
//! relative-error bound for `simd` (rust/tests/kernel_parity.rs).

use super::MatmulKernel;

pub struct ScalarKernel;

impl MatmulKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn forward(
        &self,
        inp: &[f32],
        w: &[f32],
        bias: &[f32],
        out: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        // out = inp @ w + bias  (row-major, ikj loop order)
        for r in 0..b {
            let orow = &mut out[r * fan_out..(r + 1) * fan_out];
            orow.copy_from_slice(bias);
            let irow = &inp[r * fan_in..(r + 1) * fan_in];
            for (i, &iv) in irow.iter().enumerate() {
                if iv == 0.0 {
                    continue;
                }
                let wrow = &w[i * fan_out..(i + 1) * fan_out];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += iv * wv;
                }
            }
        }
    }

    fn backward_data(
        &self,
        d: &[f32],
        w: &[f32],
        act: &[f32],
        dprev: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        for r in 0..b {
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            let prow = &mut dprev[r * fan_in..(r + 1) * fan_in];
            for (i, pv) in prow.iter_mut().enumerate() {
                // relu mask: gradient flows only where act > 0
                if act[r * fan_in + i] <= 0.0 {
                    *pv = 0.0;
                    continue;
                }
                let wrow = &w[i * fan_out..(i + 1) * fan_out];
                let mut acc = 0f32;
                for (dv, wv) in drow.iter().zip(wrow) {
                    acc += dv * wv;
                }
                *pv = acc;
            }
        }
    }

    fn update(
        &self,
        a: &[f32],
        d: &[f32],
        w: &mut [f32],
        bias: &mut [f32],
        lr: f32,
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        // W -= lr * A^T d ; bias -= lr * sum_rows(d)
        for r in 0..b {
            let arow = &a[r * fan_in..(r + 1) * fan_in];
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let scale = lr * av;
                let wrow = &mut w[i * fan_out..(i + 1) * fan_out];
                for (wv, &dv) in wrow.iter_mut().zip(drow) {
                    *wv -= scale * dv;
                }
            }
        }
        for r in 0..b {
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            for (bv, &dv) in bias.iter_mut().zip(drow) {
                *bv -= lr * dv;
            }
        }
    }
}

//! GEMM kernel subsystem for the native engine hot path.
//!
//! Every figure sweep bottoms out in three dense products per MLP layer:
//! the forward affine map, the backward data gradient, and the SGD weight
//! update. [`MatmulKernel`] abstracts exactly those three shapes so
//! backends can slot in per-op (the way dfdx structures its conv kernels
//! behind per-backend impls), and [`crate::engine::NativeEngine`] is
//! written against the trait:
//!
//! - [`scalar::ScalarKernel`] — byte-for-byte the pre-subsystem loops
//!   (`engine/native.rs` as of PR 7). Retained as the test oracle.
//! - [`blocked::BlockedKernel`] — the default: cache-blocked,
//!   register-tiled panels (4-row × 8-column accumulator tiles) that are
//!   **bit-identical** to the scalar kernel. See the module docs for why
//!   the tiling is bit-free; docs/KERNELS.md states the contract.
//! - [`simd::SimdKernel`] — `std::simd` + FMA behind the non-default
//!   `simd` cargo feature (nightly-only `portable_simd`). FMA changes
//!   rounding, so this backend is gated by approximate-parity tests
//!   (rel-err bound vs. scalar in rust/tests/kernel_parity.rs), not the
//!   bit-exact suite.
//!
//! ## The bit-exactness contract
//!
//! A kernel advertising bit-identity to `scalar` must preserve, for every
//! output element, the scalar kernel's exact accumulation chain:
//!
//! 1. **Ordered k-accumulation.** Each output element reduces over exactly
//!    one dimension (forward/backward: the fan dimension; update: the
//!    batch rows). That reduction must visit terms in the scalar order,
//!    into a single accumulator — no partial-sum splitting, no reordering.
//!    Blocking over the *other* (per-element independent) dimensions is
//!    free.
//! 2. **Same rounding.** Plain `acc + a * b` (two roundings) on the
//!    default path — `mul_add`/FMA fuses them and is only allowed behind
//!    the `simd` feature.
//! 3. **Preserved skip branches.** The scalar loops skip `iv == 0.0`
//!    inputs (forward/update) and zero masked rows before accumulating
//!    (backward). These branches are semantic, not just fast paths:
//!    `x + 0.0 * w` is not a no-op when `x` is `-0.0` or `w` is
//!    non-finite, so a "simplified" kernel that drops them diverges on
//!    exactly the inputs ReLU produces in half the activations.
//!
//! rust/tests/kernel_parity.rs enforces the contract with random-shape
//! property tests (including ragged sizes that don't divide the tiles)
//! and whole-run trajectory identity for QuAFL/FedAvg/FedBuff.
//!
//! ## Flop/byte accounting
//!
//! Kernels stay pure; [`crate::engine::NativeEngine`] computes analytic
//! flop/byte counts per layer call from the shapes and adds them to a
//! shared [`KernelStats`] (two relaxed `fetch_add`s per train step —
//! noise next to the ~MFLOP of work they describe). The trace layer polls
//! the totals at round boundaries as the `kernel_flops`/`kernel_bytes`
//! counters (docs/TRACE_SCHEMA.md).

use std::sync::atomic::{AtomicU64, Ordering};

pub mod blocked;
pub mod scalar;
#[cfg(feature = "simd")]
pub mod simd;

/// The three GEMM shapes one MLP layer needs. `b` rows of `fan_in` inputs
/// against a row-major `(fan_in, fan_out)` weight matrix; all slices may
/// be larger than the active region (scratch buffers are sized for the
/// engine's max batch) — kernels touch rows `0..b` only.
pub trait MatmulKernel: Send + Sync {
    fn name(&self) -> &'static str;

    /// `out[r] = inp[r] · W + bias` for `r in 0..b` (no activation —
    /// the engine applies ReLU afterwards on hidden layers).
    #[allow(clippy::too_many_arguments)]
    fn forward(
        &self,
        inp: &[f32],
        w: &[f32],
        bias: &[f32],
        out: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    );

    /// `dprev[r] = d[r] · Wᵀ`, masked by ReLU: `dprev[r][i] = 0` where
    /// `act[r][i] <= 0` (act is the *post*-ReLU input activation).
    #[allow(clippy::too_many_arguments)]
    fn backward_data(
        &self,
        d: &[f32],
        w: &[f32],
        act: &[f32],
        dprev: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    );

    /// SGD update in place: `W -= lr · Aᵀ d` (skipping `a == 0.0` terms)
    /// then `bias -= lr · Σ_r d[r]`, both in batch-row order.
    #[allow(clippy::too_many_arguments)]
    fn update(
        &self,
        a: &[f32],
        d: &[f32],
        w: &mut [f32],
        bias: &mut [f32],
        lr: f32,
        b: usize,
        fan_in: usize,
        fan_out: usize,
    );
}

/// Kernel selection (`--engine-kernel`, `ExperimentConfig::engine_kernel`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// the pre-subsystem loops, byte for byte — the test oracle
    Scalar,
    /// cache-blocked register tiling, bit-identical to `scalar` (default)
    #[default]
    Blocked,
    /// `std::simd` + FMA; approximate parity only; needs `--features simd`
    Simd,
}

impl KernelKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelKind::Scalar),
            "blocked" => Ok(KernelKind::Blocked),
            "simd" => Ok(KernelKind::Simd),
            other => Err(format!(
                "unknown engine kernel {other:?} (scalar | blocked | simd)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked => "blocked",
            KernelKind::Simd => "simd",
        }
    }

    /// Whether this build can instantiate the kind (`simd` needs the
    /// nightly-only `simd` cargo feature compiled in).
    pub fn available(self) -> bool {
        match self {
            KernelKind::Simd => cfg!(feature = "simd"),
            _ => true,
        }
    }

    pub fn instantiate(self) -> Result<Box<dyn MatmulKernel>, String> {
        match self {
            KernelKind::Scalar => Ok(Box::new(scalar::ScalarKernel)),
            KernelKind::Blocked => Ok(Box::new(blocked::BlockedKernel)),
            KernelKind::Simd => instantiate_simd(),
        }
    }
}

#[cfg(feature = "simd")]
fn instantiate_simd() -> Result<Box<dyn MatmulKernel>, String> {
    Ok(Box::new(simd::SimdKernel))
}

#[cfg(not(feature = "simd"))]
fn instantiate_simd() -> Result<Box<dyn MatmulKernel>, String> {
    Err("engine kernel `simd` requires building with `--features simd` \
         (nightly toolchain: portable_simd)"
        .to_string())
}

/// Passive flop/byte counters shared (via `Arc`) across every engine a
/// factory builds — primary and pool workers alike — so the trace layer
/// reads fleet-wide totals from one place. Relaxed atomics: these are
/// observability gauges, not synchronization.
#[derive(Debug, Default)]
pub struct KernelStats {
    flops: AtomicU64,
    bytes: AtomicU64,
}

impl KernelStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, flops: u64, bytes: u64) {
        self.flops.fetch_add(flops, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Cumulative floating-point operations (2·b·k·n per GEMM, analytic).
    pub fn flops(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    /// Cumulative bytes the kernels touched (operand reads + result
    /// writes, analytic — not a cache-traffic measurement).
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Analytic flop count of one `(b, k) × (k, n)` GEMM: one multiply + one
/// add per inner-product term. The zero-skip branches make the *executed*
/// count data-dependent; the analytic figure is the stable denominator
/// every roofline uses.
pub fn gemm_flops(b: usize, k: usize, n: usize) -> u64 {
    2 * b as u64 * k as u64 * n as u64
}

/// Analytic bytes for [`MatmulKernel::forward`]: read inp + W + bias,
/// write out.
pub fn forward_bytes(b: usize, fan_in: usize, fan_out: usize) -> u64 {
    4 * (b * fan_in + fan_in * fan_out + fan_out + b * fan_out) as u64
}

/// Analytic bytes for [`MatmulKernel::backward_data`]: read d + W + act,
/// write dprev.
pub fn backward_data_bytes(b: usize, fan_in: usize, fan_out: usize) -> u64 {
    4 * (b * fan_out + fan_in * fan_out + b * fan_in + b * fan_in) as u64
}

/// Analytic bytes for [`MatmulKernel::update`]: read a + d, read+write W
/// and bias.
pub fn update_bytes(b: usize, fan_in: usize, fan_out: usize) -> u64 {
    4 * (b * fan_in + b * fan_out + 2 * fan_in * fan_out + 2 * fan_out) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip_and_default() {
        for k in [KernelKind::Scalar, KernelKind::Blocked, KernelKind::Simd] {
            assert_eq!(KernelKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(KernelKind::default(), KernelKind::Blocked);
        assert!(KernelKind::parse("fast").is_err());
    }

    #[test]
    fn scalar_and_blocked_always_available() {
        assert!(KernelKind::Scalar.available());
        assert!(KernelKind::Blocked.available());
        assert!(KernelKind::Scalar.instantiate().is_ok());
        assert_eq!(KernelKind::Blocked.instantiate().unwrap().name(), "blocked");
    }

    #[test]
    fn simd_availability_tracks_feature() {
        assert_eq!(KernelKind::Simd.available(), cfg!(feature = "simd"));
        assert_eq!(
            KernelKind::Simd.instantiate().is_ok(),
            cfg!(feature = "simd")
        );
    }

    #[test]
    fn stats_accumulate() {
        let s = KernelStats::new();
        assert_eq!((s.flops(), s.bytes()), (0, 0));
        s.add(100, 40);
        s.add(23, 2);
        assert_eq!((s.flops(), s.bytes()), (123, 42));
    }

    #[test]
    fn analytic_counts_match_hand_computation() {
        // (b=2, k=3, n=5): 2*2*3*5 = 60 flops.
        assert_eq!(gemm_flops(2, 3, 5), 60);
        // forward: inp 2*3 + w 3*5 + bias 5 + out 2*5 = 36 floats.
        assert_eq!(forward_bytes(2, 3, 5), 4 * 36);
        // backward: d 2*5 + w 3*5 + act 2*3 + dprev 2*3 = 37 floats.
        assert_eq!(backward_data_bytes(2, 3, 5), 4 * 37);
        // update: a 2*3 + d 2*5 + 2*w 3*5 + 2*bias 5 = 56 floats.
        assert_eq!(update_bytes(2, 3, 5), 4 * 56);
    }
}

//! `std::simd` kernel (feature `simd`, nightly-only `portable_simd`).
//!
//! Vectorizes the `fan_out` dimension in 8-lane `f32` vectors and fuses
//! multiply-add via [`std::simd::StdFloat::mul_add`]. FMA rounds once
//! where the scalar path rounds twice, and the backward dot product folds
//! 8 partial sums before a horizontal reduce — so this backend is **not**
//! bit-identical to `scalar`/`blocked`. It is gated by approximate-parity
//! tests (relative-error bound, rust/tests/kernel_parity.rs) and rejected
//! at config validation when the feature isn't compiled in.
//!
//! The zero-skip and ReLU-mask branches are kept per element, matching
//! the scalar structure (they are semantic: see the module docs in
//! [`super`]), and the remainder lanes (`fan_out % 8`) use scalar
//! `f32::mul_add` so the whole row shares one rounding discipline.

use std::simd::prelude::*;
use std::simd::StdFloat;

use super::MatmulKernel;

const LANES: usize = 8;
type V = Simd<f32, LANES>;

pub struct SimdKernel;

impl MatmulKernel for SimdKernel {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn forward(
        &self,
        inp: &[f32],
        w: &[f32],
        bias: &[f32],
        out: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        for r in 0..b {
            let orow = &mut out[r * fan_out..(r + 1) * fan_out];
            orow.copy_from_slice(bias);
            let irow = &inp[r * fan_in..(r + 1) * fan_in];
            for (i, &iv) in irow.iter().enumerate() {
                if iv == 0.0 {
                    continue;
                }
                let wrow = &w[i * fan_out..(i + 1) * fan_out];
                let vi = V::splat(iv);
                let mut oc = orow.chunks_exact_mut(LANES);
                let mut wc = wrow.chunks_exact(LANES);
                for (o8, w8) in oc.by_ref().zip(wc.by_ref()) {
                    V::from_slice(w8).mul_add(vi, V::from_slice(o8)).copy_to_slice(o8);
                }
                for (o, &wv) in oc.into_remainder().iter_mut().zip(wc.remainder()) {
                    *o = wv.mul_add(iv, *o);
                }
            }
        }
    }

    fn backward_data(
        &self,
        d: &[f32],
        w: &[f32],
        act: &[f32],
        dprev: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        for r in 0..b {
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            let arow = &act[r * fan_in..(r + 1) * fan_in];
            let prow = &mut dprev[r * fan_in..(r + 1) * fan_in];
            for (i, pv) in prow.iter_mut().enumerate() {
                if arow[i] <= 0.0 {
                    *pv = 0.0;
                    continue;
                }
                let wrow = &w[i * fan_out..(i + 1) * fan_out];
                let mut accv = V::splat(0.0);
                let mut dc = drow.chunks_exact(LANES);
                let mut wc = wrow.chunks_exact(LANES);
                for (d8, w8) in dc.by_ref().zip(wc.by_ref()) {
                    accv = V::from_slice(d8).mul_add(V::from_slice(w8), accv);
                }
                let mut acc = accv.reduce_sum();
                for (&dv, &wv) in dc.remainder().iter().zip(wc.remainder()) {
                    acc = dv.mul_add(wv, acc);
                }
                *pv = acc;
            }
        }
    }

    fn update(
        &self,
        a: &[f32],
        d: &[f32],
        w: &mut [f32],
        bias: &mut [f32],
        lr: f32,
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        for r in 0..b {
            let arow = &a[r * fan_in..(r + 1) * fan_in];
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let scale = lr * av;
                let vneg = V::splat(-scale);
                let wrow = &mut w[i * fan_out..(i + 1) * fan_out];
                let mut wc = wrow.chunks_exact_mut(LANES);
                let mut dc = drow.chunks_exact(LANES);
                for (w8, d8) in wc.by_ref().zip(dc.by_ref()) {
                    V::from_slice(d8).mul_add(vneg, V::from_slice(w8)).copy_to_slice(w8);
                }
                for (wv, &dv) in wc.into_remainder().iter_mut().zip(dc.remainder()) {
                    *wv = dv.mul_add(-scale, *wv);
                }
            }
        }
        for r in 0..b {
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            for (bv, &dv) in bias.iter_mut().zip(drow) {
                *bv = dv.mul_add(-lr, *bv);
            }
        }
    }
}

//! Cache-blocked, register-tiled GEMM kernel — the default backend.
//!
//! ## Tile layout
//!
//! - `MR = 4`: rows processed together (batch rows in forward, `fan_in`
//!   rows in backward/update).
//! - `NR = 8`: `fan_out` panel width; a full tile holds a 4×8 `f32`
//!   accumulator block in registers.
//!
//! **Forward** walks `fan_out` in `NR`-wide panels (`chunks_exact` over
//! the bias) and `b` in `MR`-row tiles: the 4×8 accumulator block is
//! seeded from the bias once and the entire k-loop (`fan_in`) runs with
//! the tile live in registers — the weight panel `w[i][o0..o0+8]` is
//! loaded once and reused by all four rows, so weight traffic per output
//! drops 4× and the panel's 8 accumulator chains give the CPU independent
//! FP adds to overlap.
//!
//! **Backward-data** unrolls four independent `fan_in` chains per batch
//! row, sharing each `d[r][o]` load across the four weight rows: a single
//! chain is latency-bound on the FP add (each `acc += dv*wv` waits on the
//! previous), four interleaved chains are not.
//!
//! **Update** keeps a 4×8 block of `W` in registers across the whole
//! batch-row reduction, turning `b` read-modify-write passes over the
//! weight matrix into one load and one store per element.
//!
//! ## Why this is bit-identical to [`super::scalar::ScalarKernel`]
//!
//! Every output element's value is one ordered reduction over a single
//! "k" dimension (forward/backward: the fan dimension; update: batch
//! rows). The tiling here reorders only *across* elements — each
//! element's own chain keeps the scalar term order, a single
//! accumulator, plain `acc + a*b` rounding (no FMA), and the scalar
//! zero-skip/mask branches (semantic: `x + 0.0` flips `-0.0`, and
//! `0.0 * inf = NaN`). Remainder rows/columns (sizes not divisible by
//! `MR`/`NR`) fall back to the scalar per-element chains, which are
//! bit-identical by the same argument. The contract is enforced by
//! rust/tests/kernel_parity.rs.

use super::MatmulKernel;

/// Row-tile height (see module docs).
const MR: usize = 4;
/// `fan_out` panel width.
const NR: usize = 8;

pub struct BlockedKernel;

impl MatmulKernel for BlockedKernel {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn forward(
        &self,
        inp: &[f32],
        w: &[f32],
        bias: &[f32],
        out: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        let mut r0 = 0;
        while r0 + MR <= b {
            forward_tile4(inp, w, bias, out, r0, fan_in, fan_out);
            r0 += MR;
        }
        for r in r0..b {
            forward_row(inp, w, bias, out, r, 0, fan_in, fan_out);
        }
    }

    fn backward_data(
        &self,
        d: &[f32],
        w: &[f32],
        act: &[f32],
        dprev: &mut [f32],
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        for r in 0..b {
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            let arow = &act[r * fan_in..(r + 1) * fan_in];
            let prow = &mut dprev[r * fan_in..(r + 1) * fan_in];
            let mut i0 = 0;
            while i0 + MR <= fan_in {
                // Whole tile masked (common under ReLU): skip the dot
                // products entirely — outputs are 0.0 either way.
                if arow[i0..i0 + MR].iter().all(|&v| v <= 0.0) {
                    prow[i0..i0 + MR].fill(0.0);
                    i0 += MR;
                    continue;
                }
                // Four independent accumulator chains sharing each d load.
                // Per chain the o-order and rounding are exactly scalar's.
                let w0 = &w[i0 * fan_out..(i0 + 1) * fan_out];
                let w1 = &w[(i0 + 1) * fan_out..(i0 + 2) * fan_out];
                let w2 = &w[(i0 + 2) * fan_out..(i0 + 3) * fan_out];
                let w3 = &w[(i0 + 3) * fan_out..(i0 + 4) * fan_out];
                let mut acc = [0f32; MR];
                let it = drow
                    .iter()
                    .zip(w0.iter())
                    .zip(w1.iter())
                    .zip(w2.iter())
                    .zip(w3.iter());
                for ((((&dv, &x0), &x1), &x2), &x3) in it {
                    acc[0] += dv * x0;
                    acc[1] += dv * x1;
                    acc[2] += dv * x2;
                    acc[3] += dv * x3;
                }
                for (t, &a) in acc.iter().enumerate() {
                    prow[i0 + t] = if arow[i0 + t] <= 0.0 { 0.0 } else { a };
                }
                i0 += MR;
            }
            for i in i0..fan_in {
                if arow[i] <= 0.0 {
                    prow[i] = 0.0;
                    continue;
                }
                let wrow = &w[i * fan_out..(i + 1) * fan_out];
                let mut acc = 0f32;
                for (dv, wv) in drow.iter().zip(wrow) {
                    acc += dv * wv;
                }
                prow[i] = acc;
            }
        }
    }

    fn update(
        &self,
        a: &[f32],
        d: &[f32],
        w: &mut [f32],
        bias: &mut [f32],
        lr: f32,
        b: usize,
        fan_in: usize,
        fan_out: usize,
    ) {
        let mut i0 = 0;
        while i0 + MR <= fan_in {
            update_rows4(a, d, w, lr, b, i0, fan_in, fan_out);
            i0 += MR;
        }
        for i in i0..fan_in {
            update_row(a, d, w, lr, b, i, 0, fan_in, fan_out);
        }
        // Bias update: identical to scalar (r-ascending, o-ascending).
        for r in 0..b {
            let drow = &d[r * fan_out..(r + 1) * fan_out];
            for (bv, &dv) in bias.iter_mut().zip(drow) {
                *bv -= lr * dv;
            }
        }
    }
}

/// Forward for a full `MR`-row tile: one `NR`-wide accumulator block per
/// `fan_out` panel, seeded from the bias, k-loop over `fan_in` with the
/// weight panel shared across the four rows.
fn forward_tile4(
    inp: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    r0: usize,
    fan_in: usize,
    fan_out: usize,
) {
    for (p, bpan) in bias.chunks_exact(NR).enumerate() {
        let o0 = p * NR;
        let mut acc = [[0f32; NR]; MR];
        for tile in acc.iter_mut() {
            tile.copy_from_slice(bpan);
        }
        for i in 0..fan_in {
            let woff = i * fan_out + o0;
            let wpan = &w[woff..woff + NR];
            for (t, tile) in acc.iter_mut().enumerate() {
                let iv = inp[(r0 + t) * fan_in + i];
                // Same semantic skip as scalar, per (row, i).
                if iv == 0.0 {
                    continue;
                }
                for (av, &wv) in tile.iter_mut().zip(wpan) {
                    *av += iv * wv;
                }
            }
        }
        for (t, tile) in acc.iter().enumerate() {
            let ooff = (r0 + t) * fan_out + o0;
            out[ooff..ooff + NR].copy_from_slice(tile);
        }
    }
    // Column remainder (fan_out % NR): scalar per-element chains.
    let o_rem = (fan_out / NR) * NR;
    if o_rem < fan_out {
        for t in 0..MR {
            forward_row(inp, w, bias, out, r0 + t, o_rem, fan_in, fan_out);
        }
    }
}

/// Scalar forward for one row over columns `o_lo..fan_out` (used for row
/// and column remainders) — exactly the scalar kernel's per-element chain.
#[allow(clippy::too_many_arguments)]
fn forward_row(
    inp: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    r: usize,
    o_lo: usize,
    fan_in: usize,
    fan_out: usize,
) {
    let orow = &mut out[r * fan_out + o_lo..(r + 1) * fan_out];
    orow.copy_from_slice(&bias[o_lo..]);
    let irow = &inp[r * fan_in..(r + 1) * fan_in];
    for (i, &iv) in irow.iter().enumerate() {
        if iv == 0.0 {
            continue;
        }
        let wrow = &w[i * fan_out + o_lo..(i + 1) * fan_out];
        for (o, &wv) in orow.iter_mut().zip(wrow) {
            *o += iv * wv;
        }
    }
}

/// Update for a full `MR`-row block of `W`: a 4×8 register tile of
/// weights accumulates the whole batch-row reduction before one store.
#[allow(clippy::too_many_arguments)]
fn update_rows4(
    a: &[f32],
    d: &[f32],
    w: &mut [f32],
    lr: f32,
    b: usize,
    i0: usize,
    fan_in: usize,
    fan_out: usize,
) {
    let panels = fan_out / NR;
    for p in 0..panels {
        let o0 = p * NR;
        let mut acc = [[0f32; NR]; MR];
        for (t, tile) in acc.iter_mut().enumerate() {
            let woff = (i0 + t) * fan_out + o0;
            tile.copy_from_slice(&w[woff..woff + NR]);
        }
        for r in 0..b {
            let doff = r * fan_out + o0;
            let dpan = &d[doff..doff + NR];
            for (t, tile) in acc.iter_mut().enumerate() {
                let av = a[r * fan_in + i0 + t];
                if av == 0.0 {
                    continue;
                }
                let scale = lr * av;
                for (wv, &dv) in tile.iter_mut().zip(dpan) {
                    *wv -= scale * dv;
                }
            }
        }
        for (t, tile) in acc.iter().enumerate() {
            let woff = (i0 + t) * fan_out + o0;
            w[woff..woff + NR].copy_from_slice(tile);
        }
    }
    // Column remainder: scalar per-element chains.
    let o_rem = panels * NR;
    if o_rem < fan_out {
        for t in 0..MR {
            update_row(a, d, w, lr, b, i0 + t, o_rem, fan_in, fan_out);
        }
    }
}

/// Scalar update for one `W` row over columns `o_lo..fan_out` (row and
/// column remainders) — per element the exact scalar chain: r-ascending,
/// `scale = lr * a[r][i]` rounding, `a == 0.0` skip.
#[allow(clippy::too_many_arguments)]
fn update_row(
    a: &[f32],
    d: &[f32],
    w: &mut [f32],
    lr: f32,
    b: usize,
    i: usize,
    o_lo: usize,
    fan_in: usize,
    fan_out: usize,
) {
    let wrow = &mut w[i * fan_out + o_lo..(i + 1) * fan_out];
    for r in 0..b {
        let av = a[r * fan_in + i];
        if av == 0.0 {
            continue;
        }
        let scale = lr * av;
        let drow = &d[r * fan_out + o_lo..(r + 1) * fan_out];
        for (wv, &dv) in wrow.iter_mut().zip(drow) {
            *wv -= scale * dv;
        }
    }
}

//! Training engines: the compute clients run for their local SGD steps.
//!
//! [`XlaEngine`] executes the AOT artifacts (L2/L1 JAX+Pallas lowered to
//! HLO) on the PJRT CPU client — the production path proving the three
//! layers compose. [`NativeEngine`] implements the same math in pure Rust;
//! it cross-validates the XLA path (rust/tests/engine_parity.rs), runs the
//! large figure sweeps fast, and keeps tests artifact-free.
//!
//! Both implement [`TrainEngine`] over *flat* parameter vectors — the
//! representation the FL protocol averages and quantizes.
//!
//! The native engine's three per-layer GEMMs are pluggable
//! ([`kernel::MatmulKernel`]): `--engine-kernel` selects the scalar
//! oracle, the cache-blocked default, or the feature-gated SIMD backend.

pub mod kernel;
pub mod native;
pub mod xla;

pub use kernel::{KernelKind, KernelStats, MatmulKernel};
pub use native::NativeEngine;
pub use xla::XlaEngine;

use std::sync::Arc;

use crate::data::{Batch, Dataset};
use crate::model::ModelSpec;

/// Abstract SGD engine over flat parameters.
///
/// `Send` is a supertrait so [`crate::exec::EnginePool`] can hand one
/// engine instance to each worker thread of the parallel client-execution
/// subsystem. Engines need not be `Sync`: a worker owns its engine
/// exclusively for the duration of a fan-out, so interior scratch buffers
/// (see [`NativeEngine`]) remain safe.
pub trait TrainEngine: Send {
    fn spec(&self) -> &ModelSpec;

    /// One SGD step (fwd + bwd + update) in place; returns the batch loss.
    /// `batch.batch` must equal [`TrainEngine::train_batch`].
    fn train_step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32>;

    /// A burst of consecutive SGD steps (one per batch), in place; returns
    /// the summed loss. Engines override this to amortize per-call
    /// overhead (the XLA engine dispatches ONE fused K-step module —
    /// §Perf L2); the default just loops `train_step`.
    fn train_steps(
        &mut self,
        params: &mut [f32],
        batches: &[Batch],
        lr: f32,
    ) -> anyhow::Result<f32> {
        let mut loss_sum = 0.0;
        for b in batches {
            loss_sum += self.train_step(params, b, lr)?;
        }
        Ok(loss_sum)
    }

    /// Evaluate rows `lo..hi` of `data` in [`TrainEngine::eval_batch`]-
    /// sized chunks (chunk boundaries are global: `lo` must sit on a
    /// chunk boundary), returning one `(summed-loss contribution,
    /// correct count)` pair per chunk.
    ///
    /// This is the primitive parallel evaluation builds on:
    /// [`TrainEngine::evaluate`] is *definitionally* the in-order fold of
    /// these pairs, so sharding a dataset across engines at chunk
    /// boundaries and folding the concatenated chunk lists in global
    /// order reproduces the unsharded result bit for bit
    /// (`crate::exec::EnginePool::evaluate_sharded`).
    fn evaluate_span(
        &mut self,
        params: &[f32],
        data: &Dataset,
        lo: usize,
        hi: usize,
    ) -> anyhow::Result<Vec<(f64, f64)>>;

    /// Mean loss and accuracy over a dataset: the in-order fold of
    /// [`TrainEngine::evaluate_span`] over the whole set.
    fn evaluate(&mut self, params: &[f32], data: &Dataset) -> anyhow::Result<(f64, f64)> {
        anyhow::ensure!(!data.is_empty());
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for (l, c) in self.evaluate_span(params, data, 0, data.len())? {
            loss_sum += l;
            correct += c;
        }
        Ok((loss_sum / data.len() as f64, correct / data.len() as f64))
    }

    /// Fixed train batch size (XLA artifacts are shape-specialized).
    fn train_batch(&self) -> usize;

    /// Chunk size [`TrainEngine::evaluate`] walks a dataset with (the
    /// artifact eval batch for XLA; the train batch for native).
    fn eval_batch(&self) -> usize {
        self.train_batch()
    }

    fn name(&self) -> &'static str;
}

/// Build the engine selected by the config. XLA needs `artifacts/`
/// (`make artifacts`); native works anywhere. `kernel` selects the native
/// GEMM backend (ignored by XLA — its kernels are baked into the
/// artifact); `stats` is the shared flop/byte tally every engine built
/// from the same factory adds to.
pub fn build_engine(
    model: &str,
    use_xla: bool,
    artifacts_dir: &str,
    batch: usize,
    kernel: KernelKind,
    stats: Arc<KernelStats>,
) -> anyhow::Result<Box<dyn TrainEngine>> {
    let spec = ModelSpec::by_name(model).map_err(anyhow::Error::msg)?;
    if use_xla {
        Ok(Box::new(XlaEngine::new(artifacts_dir, &spec)?))
    } else {
        Ok(Box::new(NativeEngine::with_kernel(spec, batch, kernel, stats)?))
    }
}

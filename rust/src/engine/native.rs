//! Pure-Rust MLP engine — same math as python/compile/model.py
//! (dense → relu → … → dense → mean softmax cross-entropy, plain SGD).
//!
//! Exists to (a) cross-validate the XLA artifact path step-for-step,
//! (b) run large figure sweeps quickly, (c) keep unit tests hermetic.
//! Scratch buffers are reused across steps (zero allocation in the hot
//! loop after warmup — see EXPERIMENTS.md §Perf); this includes the
//! evaluation path, whose index list and gathered batch live in the
//! engine and are refilled in place per chunk.
//!
//! The three per-layer GEMMs are delegated to a [`MatmulKernel`]
//! ([`crate::engine::kernel`]): `scalar` is the historical loop nest
//! kept as the oracle, `blocked` (default) is the cache-blocked
//! register-tiled version proven bit-identical, and `simd` (feature-
//! gated) trades bit-exactness for FMA throughput. Selection flows from
//! `--engine-kernel` through [`crate::engine::build_engine`].

use std::sync::Arc;

use super::kernel::{
    backward_data_bytes, forward_bytes, gemm_flops, update_bytes, KernelKind,
    KernelStats, MatmulKernel,
};
use super::TrainEngine;
use crate::data::{Batch, Dataset};
use crate::model::ModelSpec;

pub struct NativeEngine {
    spec: ModelSpec,
    batch: usize,
    kernel: Box<dyn MatmulKernel>,
    /// shared flop/byte tally (see [`KernelStats`]); the engine adds
    /// analytic per-layer counts so the kernels themselves stay pure
    stats: Arc<KernelStats>,
    /// per-layer activations: acts[0] = input, acts[l+1] = output of layer l
    acts: Vec<Vec<f32>>,
    /// per-layer pre-activation gradients (delta), same shapes as acts[1..]
    deltas: Vec<Vec<f32>>,
    /// softmax probabilities buffer
    probs: Vec<f32>,
    /// reusable chunk-index scratch for [`TrainEngine::evaluate_span`]
    eval_idx: Vec<usize>,
    /// reusable gathered-batch scratch for [`TrainEngine::evaluate_span`]
    eval_scratch: Batch,
}

impl NativeEngine {
    /// Engine with the default kernel ([`KernelKind::Blocked`]) and a
    /// private stats tally.
    pub fn new(spec: ModelSpec, batch: usize) -> Self {
        Self::with_kernel(
            spec,
            batch,
            KernelKind::default(),
            Arc::new(KernelStats::new()),
        )
        .expect("default kernel is always available")
    }

    /// Engine with an explicit kernel and a shared stats tally (the
    /// [`crate::exec::EngineFactory`] path: every worker's engine adds to
    /// the same counters). Errors if `kind` isn't compiled in (`simd`
    /// without `--features simd`).
    pub fn with_kernel(
        spec: ModelSpec,
        batch: usize,
        kind: KernelKind,
        stats: Arc<KernelStats>,
    ) -> anyhow::Result<Self> {
        assert!(batch >= 1);
        let kernel = kind.instantiate().map_err(anyhow::Error::msg)?;
        let acts = std::iter::once(batch * spec.sizes[0])
            .chain((1..spec.sizes.len()).map(|i| batch * spec.sizes[i]))
            .map(|n| vec![0f32; n])
            .collect();
        let deltas = (1..spec.sizes.len())
            .map(|i| vec![0f32; batch * spec.sizes[i]])
            .collect();
        let probs = vec![0f32; batch * spec.num_classes()];
        Ok(NativeEngine {
            spec,
            batch,
            kernel,
            stats,
            acts,
            deltas,
            probs,
            eval_idx: Vec::new(),
            eval_scratch: Batch::empty(),
        })
    }

    /// The active kernel's name (`scalar`/`blocked`/`simd`).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// logits = forward(params, x); fills self.acts. `b` = rows used.
    fn forward(&mut self, params: &[f32], x: &[f32], b: usize) {
        let sizes = &self.spec.sizes;
        self.acts[0][..b * sizes[0]].copy_from_slice(&x[..b * sizes[0]]);
        let segs = self.spec.segments();
        let n_layers = self.spec.num_layers();
        let mut flops = 0u64;
        let mut bytes = 0u64;
        for l in 0..n_layers {
            let (w_off, w_shape) = &segs[2 * l];
            let (b_off, _) = &segs[2 * l + 1];
            let (fan_in, fan_out) = (w_shape[0], w_shape[1]);
            let w = &params[*w_off..*w_off + fan_in * fan_out];
            let bias = &params[*b_off..*b_off + fan_out];
            let (inp, out) = {
                // split_at_mut around layer l
                let (lo, hi) = self.acts.split_at_mut(l + 1);
                (&lo[l][..], &mut hi[0][..])
            };
            self.kernel.forward(inp, w, bias, out, b, fan_in, fan_out);
            if l < n_layers - 1 {
                for v in out[..b * fan_out].iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            flops += gemm_flops(b, fan_in, fan_out);
            bytes += forward_bytes(b, fan_in, fan_out);
        }
        self.stats.add(flops, bytes);
    }

    /// Softmax + mean xent on acts.last(); fills self.probs; returns loss.
    fn loss_and_probs(&mut self, y: &[f32], b: usize) -> f32 {
        let c = self.spec.num_classes();
        let logits = self.acts.last().unwrap();
        let mut loss = 0f64;
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let yrow = &y[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut s = 0f64;
            for (j, &v) in row.iter().enumerate() {
                let e = ((v - m) as f64).exp();
                self.probs[r * c + j] = e as f32;
                s += e;
            }
            let ls = s.ln() as f32;
            for j in 0..c {
                self.probs[r * c + j] = (self.probs[r * c + j] as f64 / s) as f32;
                // xent contribution: -y * logp
                if yrow[j] != 0.0 {
                    loss += (yrow[j] * (m + ls - row[j])) as f64;
                }
            }
        }
        (loss / b as f64) as f32
    }

    /// Backward + SGD update. Requires forward + loss_and_probs done.
    fn backward_update(&mut self, params: &mut [f32], y: &[f32], lr: f32, b: usize) {
        let segs = self.spec.segments();
        let n_layers = self.spec.num_layers();
        let c = self.spec.num_classes();
        // delta_last = (probs - y)/b
        {
            let d = &mut self.deltas[n_layers - 1];
            let inv_b = 1.0 / b as f32;
            for i in 0..b * c {
                d[i] = (self.probs[i] - y[i]) * inv_b;
            }
        }
        let mut flops = 0u64;
        let mut bytes = 0u64;
        // Walk layers backwards.
        for l in (0..n_layers).rev() {
            let (w_off, w_shape) = segs[2 * l].clone();
            let (b_off, _) = segs[2 * l + 1].clone();
            let (fan_in, fan_out) = (w_shape[0], w_shape[1]);
            // delta for previous layer (before relu mask): d_prev = d @ W^T
            if l > 0 {
                let (dprev, d) = {
                    let (lo, hi) = self.deltas.split_at_mut(l);
                    (&mut lo[l - 1][..], &hi[0][..])
                };
                let w = &params[w_off..w_off + fan_in * fan_out];
                let prev_act = &self.acts[l][..];
                self.kernel
                    .backward_data(d, w, prev_act, dprev, b, fan_in, fan_out);
                flops += gemm_flops(b, fan_in, fan_out);
                bytes += backward_data_bytes(b, fan_in, fan_out);
            }
            // SGD update: W -= lr * A^T d ; bias -= lr * sum_rows(d).
            // Weights and bias are adjacent segments of the flat vector
            // (segments() lays them out w_l, b_l, ...), so split at the
            // bias offset to borrow both mutably.
            let d = &self.deltas[l][..];
            let a = &self.acts[l][..];
            let (head, rest) = params.split_at_mut(b_off);
            let w = &mut head[w_off..];
            let bias = &mut rest[..fan_out];
            self.kernel.update(a, d, w, bias, lr, b, fan_in, fan_out);
            flops += gemm_flops(b, fan_in, fan_out) + 2 * (b * fan_out) as u64;
            bytes += update_bytes(b, fan_in, fan_out);
        }
        self.stats.add(flops, bytes);
    }
}

impl TrainEngine for NativeEngine {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(
            batch.batch == self.batch,
            "native engine built for batch {}, got {}",
            self.batch,
            batch.batch
        );
        anyhow::ensure!(params.len() == self.spec.num_params());
        let b = batch.batch;
        self.forward(params, &batch.x, b);
        let loss = self.loss_and_probs(&batch.y, b);
        self.backward_update(params, &batch.y, lr, b);
        Ok(loss)
    }

    fn evaluate_span(
        &mut self,
        params: &[f32],
        data: &Dataset,
        lo: usize,
        hi: usize,
    ) -> anyhow::Result<Vec<(f64, f64)>> {
        anyhow::ensure!(hi <= data.len() && lo <= hi);
        let c = self.spec.num_classes();
        let chunk = self.batch;
        let mut out = Vec::with_capacity((hi - lo).div_ceil(chunk.max(1)));
        // Move the scratch out of self for the loop (borrowck: forward
        // takes &mut self while reading the gathered rows) and restore it
        // after — capacity persists across chunks AND across calls, so
        // the hot loop allocates nothing after the first chunk.
        let mut idx = std::mem::take(&mut self.eval_idx);
        let mut scratch = std::mem::replace(&mut self.eval_scratch, Batch::empty());
        let mut i = lo;
        while i < hi {
            let end = (i + chunk).min(hi);
            idx.clear();
            idx.extend(i..end);
            data.gather_batch_into(&idx, &mut scratch);
            let b = scratch.batch;
            self.forward(params, &scratch.x, b);
            let loss = self.loss_and_probs(&scratch.y, b) as f64 * b as f64;
            let logits = self.acts.last().unwrap();
            let mut correct = 0usize;
            for r in 0..b {
                let row = &logits[r * c..(r + 1) * c];
                // NaN-safe argmax: total-order fold keeping the FIRST
                // maximum. `v > best` is false for NaN, so a NaN logit
                // can never win (an all-NaN row predicts class 0) — the
                // previous `partial_cmp().unwrap()` panicked instead.
                let mut pred = 0usize;
                let mut best = f32::NEG_INFINITY;
                for (j, &v) in row.iter().enumerate() {
                    if v > best {
                        best = v;
                        pred = j;
                    }
                }
                if pred as u32 == data.labels[i + r] {
                    correct += 1;
                }
            }
            out.push((loss, correct as f64));
            i = end;
        }
        self.eval_idx = idx;
        self.eval_scratch = scratch;
        Ok(out)
    }

    fn train_batch(&self) -> usize {
        self.batch
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthFamily, SynthSpec};

    fn setup() -> (NativeEngine, Vec<f32>, crate::data::Dataset) {
        let spec = ModelSpec::by_name("mlp").unwrap();
        let params = spec.init_params(7);
        let engine = NativeEngine::new(spec, 32);
        let (train, _) = SynthSpec::family(SynthFamily::Mnist, 256, 64, 3).generate();
        (engine, params, train)
    }

    #[test]
    fn loss_starts_near_log_c() {
        let (mut e, params, data) = setup();
        let (loss, acc) = e.evaluate(&params, &data).unwrap();
        // He-uniform init gives logits of O(1) std, so the initial loss
        // sits near (but above) ln(10) ≈ 2.30.
        assert!(loss > 1.8 && loss < 4.5, "loss={loss}");
        assert!(acc < 0.35, "random init should be near chance, acc={acc}");
    }

    #[test]
    fn sgd_reduces_loss_and_improves_accuracy() {
        let (mut e, mut params, data) = setup();
        let (loss0, _) = e.evaluate(&params, &data).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..60 {
            let idx: Vec<usize> = (0..32).map(|_| rng.gen_range(data.len())).collect();
            let batch = data.gather_batch(&idx);
            e.train_step(&mut params, &batch, 0.1).unwrap();
        }
        let (loss1, acc1) = e.evaluate(&params, &data).unwrap();
        assert!(loss1 < loss0 * 0.7, "loss {loss0} -> {loss1}");
        assert!(acc1 > 0.5, "acc after training = {acc1}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // Spot-check d loss/d param via central differences on a tiny model.
        let spec = ModelSpec::new("tiny", vec![6, 4, 3]);
        let mut params = spec.init_params(11);
        let (data, _) = SynthSpec {
            dim: 6,
            classes: 3,
            train: 8,
            val: 1,
            margin: 1.0,
            noise: 0.5,
            style_rank: 1,
            style_scale: 0.1,
            label_noise: 0.0,
            seed: 2,
        }
        .generate();
        let idx: Vec<usize> = (0..8).collect();
        let batch = data.gather_batch(&idx);
        let mut engine = NativeEngine::new(spec.clone(), 8);
        // Analytic gradient = (params - params_after)/lr with tiny lr.
        let lr = 1e-3f32;
        let mut stepped = params.clone();
        engine.train_step(&mut stepped, &batch, lr).unwrap();
        let eval_loss = |p: &[f32], engine: &mut NativeEngine| -> f64 {
            engine.forward(p, &batch.x, 8);
            engine.loss_and_probs(&batch.y, 8) as f64
        };
        let eps = 1e-2f32;
        for &pi in &[0usize, 5, 24, 27, 30, params.len() - 1] {
            let analytic = (params[pi] - stepped[pi]) / lr;
            let orig = params[pi];
            params[pi] = orig + eps;
            let lp = eval_loss(&params, &mut engine);
            params[pi] = orig - eps;
            let lm = eval_loss(&params, &mut engine);
            params[pi] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (analytic - numeric).abs() < 2e-2 + 0.05 * numeric.abs(),
                "param {pi}: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn train_step_rejects_wrong_batch() {
        let (mut e, mut params, data) = setup();
        let idx: Vec<usize> = (0..16).collect();
        let batch = data.gather_batch(&idx);
        assert!(e.train_step(&mut params, &batch, 0.1).is_err());
    }

    #[test]
    fn deterministic_across_instances() {
        let (mut e1, mut p1, data) = setup();
        let spec = ModelSpec::by_name("mlp").unwrap();
        let mut e2 = NativeEngine::new(spec, 32);
        let mut p2 = p1.clone();
        let idx: Vec<usize> = (0..32).collect();
        let batch = data.gather_batch(&idx);
        let l1 = e1.train_step(&mut p1, &batch, 0.05).unwrap();
        let l2 = e2.train_step(&mut p2, &batch, 0.05).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(p1, p2);
    }

    #[test]
    fn default_kernel_is_blocked_and_explicit_kinds_build() {
        let spec = ModelSpec::by_name("mlp").unwrap();
        let e = NativeEngine::new(spec.clone(), 8);
        assert_eq!(e.kernel_name(), "blocked");
        let e = NativeEngine::with_kernel(
            spec,
            8,
            KernelKind::Scalar,
            Arc::new(KernelStats::new()),
        )
        .unwrap();
        assert_eq!(e.kernel_name(), "scalar");
    }

    #[test]
    fn evaluate_survives_nan_logits() {
        // Regression: the argmax used `partial_cmp().unwrap()` and
        // panicked on the first NaN logit. Poisoning every parameter
        // makes every logit NaN; evaluation must complete (predicting
        // class 0 per row) and surface NaN through the loss only.
        let (mut e, mut params, data) = setup();
        for v in params.iter_mut() {
            *v = f32::NAN;
        }
        let (loss, acc) = e.evaluate(&params, &data).unwrap();
        assert!(loss.is_nan(), "NaN params must surface a NaN loss");
        // All rows predict class 0, so accuracy equals label-0 frequency.
        let zero_frac = data.labels.iter().filter(|&&l| l == 0).count() as f64
            / data.len() as f64;
        assert_eq!(acc, zero_frac);
    }

    #[test]
    fn eval_scratch_reuses_capacity_across_calls() {
        let (mut e, params, data) = setup();
        e.evaluate(&params, &data).unwrap();
        let cap_x = e.eval_scratch.x.capacity();
        let cap_idx = e.eval_idx.capacity();
        assert!(cap_x > 0 && cap_idx > 0, "first eval must warm the scratch");
        e.evaluate(&params, &data).unwrap();
        // Same shapes on the second pass: the buffers must not regrow.
        assert_eq!(e.eval_scratch.x.capacity(), cap_x);
        assert_eq!(e.eval_idx.capacity(), cap_idx);
    }

    #[test]
    fn flop_byte_stats_accumulate_analytically() {
        let spec = ModelSpec::by_name("mlp").unwrap(); // 784 -> 32 -> 10
        let stats = Arc::new(KernelStats::new());
        let mut e = NativeEngine::with_kernel(
            spec,
            32,
            KernelKind::Blocked,
            Arc::clone(&stats),
        )
        .unwrap();
        let (train, _) = SynthSpec::family(SynthFamily::Mnist, 64, 16, 3).generate();
        let idx: Vec<usize> = (0..32).collect();
        let batch = train.gather_batch(&idx);
        let mut params = e.spec().init_params(3);
        e.train_step(&mut params, &batch, 0.1).unwrap();
        // forward: both layers; backward_data: layer 1 only; update: both
        // layers + bias terms.
        let fwd = gemm_flops(32, 784, 32) + gemm_flops(32, 32, 10);
        let bwd = gemm_flops(32, 32, 10);
        let upd = fwd + 2 * (32 * 32) as u64 + 2 * (32 * 10) as u64;
        assert_eq!(stats.flops(), fwd + bwd + upd);
        assert!(stats.bytes() > 0);
    }
}

//! Pure-Rust MLP engine — same math as python/compile/model.py
//! (dense → relu → … → dense → mean softmax cross-entropy, plain SGD).
//!
//! Exists to (a) cross-validate the XLA artifact path step-for-step,
//! (b) run large figure sweeps quickly, (c) keep unit tests hermetic.
//! Scratch buffers are reused across steps (zero allocation in the hot
//! loop after warmup — see EXPERIMENTS.md §Perf).

use super::TrainEngine;
use crate::data::{Batch, Dataset};
use crate::model::ModelSpec;

pub struct NativeEngine {
    spec: ModelSpec,
    batch: usize,
    /// per-layer activations: acts[0] = input, acts[l+1] = output of layer l
    acts: Vec<Vec<f32>>,
    /// per-layer pre-activation gradients (delta), same shapes as acts[1..]
    deltas: Vec<Vec<f32>>,
    /// softmax probabilities buffer
    probs: Vec<f32>,
}

impl NativeEngine {
    pub fn new(spec: ModelSpec, batch: usize) -> Self {
        assert!(batch >= 1);
        let acts = std::iter::once(batch * spec.sizes[0])
            .chain((1..spec.sizes.len()).map(|i| batch * spec.sizes[i]))
            .map(|n| vec![0f32; n])
            .collect();
        let deltas = (1..spec.sizes.len())
            .map(|i| vec![0f32; batch * spec.sizes[i]])
            .collect();
        let probs = vec![0f32; batch * spec.num_classes()];
        NativeEngine { spec, batch, acts, deltas, probs }
    }

    /// logits = forward(params, x); fills self.acts. `b` = rows used.
    fn forward(&mut self, params: &[f32], x: &[f32], b: usize) {
        let sizes = &self.spec.sizes;
        self.acts[0][..b * sizes[0]].copy_from_slice(&x[..b * sizes[0]]);
        let segs = self.spec.segments();
        let n_layers = self.spec.num_layers();
        for l in 0..n_layers {
            let (w_off, w_shape) = &segs[2 * l];
            let (b_off, _) = &segs[2 * l + 1];
            let (fan_in, fan_out) = (w_shape[0], w_shape[1]);
            let w = &params[*w_off..*w_off + fan_in * fan_out];
            let bias = &params[*b_off..*b_off + fan_out];
            let (inp, out) = {
                // split_at_mut around layer l
                let (lo, hi) = self.acts.split_at_mut(l + 1);
                (&lo[l], &mut hi[0])
            };
            // out = inp @ w + bias  (row-major, ikj loop order)
            for r in 0..b {
                let orow = &mut out[r * fan_out..(r + 1) * fan_out];
                orow.copy_from_slice(bias);
                let irow = &inp[r * fan_in..(r + 1) * fan_in];
                for (i, &iv) in irow.iter().enumerate() {
                    if iv == 0.0 {
                        continue;
                    }
                    let wrow = &w[i * fan_out..(i + 1) * fan_out];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += iv * wv;
                    }
                }
            }
            if l < n_layers - 1 {
                for v in out[..b * fan_out].iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
    }

    /// Softmax + mean xent on acts.last(); fills self.probs; returns loss.
    fn loss_and_probs(&mut self, y: &[f32], b: usize) -> f32 {
        let c = self.spec.num_classes();
        let logits = self.acts.last().unwrap();
        let mut loss = 0f64;
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let yrow = &y[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut s = 0f64;
            for (j, &v) in row.iter().enumerate() {
                let e = ((v - m) as f64).exp();
                self.probs[r * c + j] = e as f32;
                s += e;
            }
            let ls = s.ln() as f32;
            for j in 0..c {
                self.probs[r * c + j] = (self.probs[r * c + j] as f64 / s) as f32;
                // xent contribution: -y * logp
                if yrow[j] != 0.0 {
                    loss += (yrow[j] * (m + ls - row[j])) as f64;
                }
            }
        }
        (loss / b as f64) as f32
    }

    /// Backward + SGD update. Requires forward + loss_and_probs done.
    fn backward_update(&mut self, params: &mut [f32], y: &[f32], lr: f32, b: usize) {
        let segs = self.spec.segments();
        let n_layers = self.spec.num_layers();
        let c = self.spec.num_classes();
        // delta_last = (probs - y)/b
        {
            let d = &mut self.deltas[n_layers - 1];
            let inv_b = 1.0 / b as f32;
            for i in 0..b * c {
                d[i] = (self.probs[i] - y[i]) * inv_b;
            }
        }
        // Walk layers backwards.
        for l in (0..n_layers).rev() {
            let (w_off, w_shape) = segs[2 * l].clone();
            let (b_off, _) = segs[2 * l + 1].clone();
            let (fan_in, fan_out) = (w_shape[0], w_shape[1]);
            // delta for previous layer (before relu mask): d_prev = d @ W^T
            if l > 0 {
                let (dprev, d) = {
                    let (lo, hi) = self.deltas.split_at_mut(l);
                    (&mut lo[l - 1], &hi[0])
                };
                let w = &params[w_off..w_off + fan_in * fan_out];
                let prev_act = &self.acts[l];
                for r in 0..b {
                    let drow = &d[r * fan_out..(r + 1) * fan_out];
                    let prow = &mut dprev[r * fan_in..(r + 1) * fan_in];
                    for (i, pv) in prow.iter_mut().enumerate() {
                        // relu mask: gradient flows only where act > 0
                        if prev_act[r * fan_in + i] <= 0.0 {
                            *pv = 0.0;
                            continue;
                        }
                        let wrow = &w[i * fan_out..(i + 1) * fan_out];
                        let mut acc = 0f32;
                        for (dv, wv) in drow.iter().zip(wrow) {
                            acc += dv * wv;
                        }
                        *pv = acc;
                    }
                }
            }
            // SGD update: W -= lr * A^T d ; bias -= lr * sum_rows(d)
            let d = &self.deltas[l];
            let a = &self.acts[l];
            let w = &mut params[w_off..w_off + fan_in * fan_out];
            for r in 0..b {
                let arow = &a[r * fan_in..(r + 1) * fan_in];
                let drow = &d[r * fan_out..(r + 1) * fan_out];
                for (i, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let scale = lr * av;
                    let wrow = &mut w[i * fan_out..(i + 1) * fan_out];
                    for (wv, &dv) in wrow.iter_mut().zip(drow) {
                        *wv -= scale * dv;
                    }
                }
            }
            let bias = &mut params[b_off..b_off + fan_out];
            for r in 0..b {
                let drow = &d[r * fan_out..(r + 1) * fan_out];
                for (bv, &dv) in bias.iter_mut().zip(drow) {
                    *bv -= lr * dv;
                }
            }
        }
    }
}

impl TrainEngine for NativeEngine {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(
            batch.batch == self.batch,
            "native engine built for batch {}, got {}",
            self.batch,
            batch.batch
        );
        anyhow::ensure!(params.len() == self.spec.num_params());
        let b = batch.batch;
        self.forward(params, &batch.x, b);
        let loss = self.loss_and_probs(&batch.y, b);
        self.backward_update(params, &batch.y, lr, b);
        Ok(loss)
    }

    fn evaluate_span(
        &mut self,
        params: &[f32],
        data: &Dataset,
        lo: usize,
        hi: usize,
    ) -> anyhow::Result<Vec<(f64, f64)>> {
        anyhow::ensure!(hi <= data.len() && lo <= hi);
        let c = self.spec.num_classes();
        let chunk = self.batch;
        let mut out = Vec::with_capacity((hi - lo).div_ceil(chunk.max(1)));
        let mut i = lo;
        while i < hi {
            let end = (i + chunk).min(hi);
            let idx: Vec<usize> = (i..end).collect();
            let batch = data.gather_batch(&idx);
            let b = batch.batch;
            self.forward(params, &batch.x, b);
            let loss = self.loss_and_probs(&batch.y, b) as f64 * b as f64;
            let logits = self.acts.last().unwrap();
            let mut correct = 0usize;
            for r in 0..b {
                let row = &logits[r * c..(r + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as u32 == data.labels[i + r] {
                    correct += 1;
                }
            }
            out.push((loss, correct as f64));
            i = end;
        }
        Ok(out)
    }

    fn train_batch(&self) -> usize {
        self.batch
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthFamily, SynthSpec};

    fn setup() -> (NativeEngine, Vec<f32>, crate::data::Dataset) {
        let spec = ModelSpec::by_name("mlp").unwrap();
        let params = spec.init_params(7);
        let engine = NativeEngine::new(spec, 32);
        let (train, _) = SynthSpec::family(SynthFamily::Mnist, 256, 64, 3).generate();
        (engine, params, train)
    }

    #[test]
    fn loss_starts_near_log_c() {
        let (mut e, params, data) = setup();
        let (loss, acc) = e.evaluate(&params, &data).unwrap();
        // He-uniform init gives logits of O(1) std, so the initial loss
        // sits near (but above) ln(10) ≈ 2.30.
        assert!(loss > 1.8 && loss < 4.5, "loss={loss}");
        assert!(acc < 0.35, "random init should be near chance, acc={acc}");
    }

    #[test]
    fn sgd_reduces_loss_and_improves_accuracy() {
        let (mut e, mut params, data) = setup();
        let (loss0, _) = e.evaluate(&params, &data).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..60 {
            let idx: Vec<usize> = (0..32).map(|_| rng.gen_range(data.len())).collect();
            let batch = data.gather_batch(&idx);
            e.train_step(&mut params, &batch, 0.1).unwrap();
        }
        let (loss1, acc1) = e.evaluate(&params, &data).unwrap();
        assert!(loss1 < loss0 * 0.7, "loss {loss0} -> {loss1}");
        assert!(acc1 > 0.5, "acc after training = {acc1}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // Spot-check d loss/d param via central differences on a tiny model.
        let spec = ModelSpec::new("tiny", vec![6, 4, 3]);
        let mut params = spec.init_params(11);
        let (data, _) = SynthSpec {
            dim: 6,
            classes: 3,
            train: 8,
            val: 1,
            margin: 1.0,
            noise: 0.5,
            style_rank: 1,
            style_scale: 0.1,
            label_noise: 0.0,
            seed: 2,
        }
        .generate();
        let idx: Vec<usize> = (0..8).collect();
        let batch = data.gather_batch(&idx);
        let mut engine = NativeEngine::new(spec.clone(), 8);
        // Analytic gradient = (params - params_after)/lr with tiny lr.
        let lr = 1e-3f32;
        let mut stepped = params.clone();
        engine.train_step(&mut stepped, &batch, lr).unwrap();
        let eval_loss = |p: &[f32], engine: &mut NativeEngine| -> f64 {
            engine.forward(p, &batch.x, 8);
            engine.loss_and_probs(&batch.y, 8) as f64
        };
        let eps = 1e-2f32;
        for &pi in &[0usize, 5, 24, 27, 30, params.len() - 1] {
            let analytic = (params[pi] - stepped[pi]) / lr;
            let orig = params[pi];
            params[pi] = orig + eps;
            let lp = eval_loss(&params, &mut engine);
            params[pi] = orig - eps;
            let lm = eval_loss(&params, &mut engine);
            params[pi] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (analytic - numeric).abs() < 2e-2 + 0.05 * numeric.abs(),
                "param {pi}: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn train_step_rejects_wrong_batch() {
        let (mut e, mut params, data) = setup();
        let idx: Vec<usize> = (0..16).collect();
        let batch = data.gather_batch(&idx);
        assert!(e.train_step(&mut params, &batch, 0.1).is_err());
    }

    #[test]
    fn deterministic_across_instances() {
        let (mut e1, mut p1, data) = setup();
        let spec = ModelSpec::by_name("mlp").unwrap();
        let mut e2 = NativeEngine::new(spec, 32);
        let mut p2 = p1.clone();
        let idx: Vec<usize> = (0..32).collect();
        let batch = data.gather_batch(&idx);
        let l1 = e1.train_step(&mut p1, &batch, 0.05).unwrap();
        let l2 = e2.train_step(&mut p2, &batch, 0.05).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(p1, p2);
    }
}

//! XLA engine: executes the AOT train-step / eval artifacts via PJRT.
//!
//! Each call marshals the flat parameter vector into per-layer literals
//! (the artifact's argument order is w0, b0, w1, b1, ..., x, y[, lr]),
//! executes, and copies the updated parameters back into the flat vector.
//! The executables are compiled once at construction.

use anyhow::{Context, Result};

use super::TrainEngine;
use crate::data::{Batch, Dataset};
use crate::model::ModelSpec;
use crate::runtime::{stub as xla, Runtime};

pub struct XlaEngine {
    spec: ModelSpec,
    runtime: Runtime,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    /// fused K-step executable (§Perf L2: one dispatch per client burst)
    train_k_exe: Option<(xla::PjRtLoadedExecutable, usize)>,
    train_batch: usize,
    eval_batch: usize,
    /// flat-vector segments: (offset, shape) per artifact argument
    segments: Vec<(usize, Vec<usize>)>,
}

impl XlaEngine {
    pub fn new(artifacts_dir: &str, spec: &ModelSpec) -> Result<Self> {
        let runtime = Runtime::new(artifacts_dir)?;
        let meta = runtime
            .meta
            .models
            .get(&spec.name)
            .with_context(|| {
                format!(
                    "model {:?} not in artifacts/meta.json — run `make artifacts`",
                    spec.name
                )
            })?
            .clone();
        anyhow::ensure!(
            meta.sizes == spec.sizes,
            "artifact sizes {:?} != rust ModelSpec {:?} — regenerate artifacts",
            meta.sizes,
            spec.sizes
        );
        anyhow::ensure!(meta.num_params == spec.num_params());
        // Cross-check flat layout against the artifact's declared shapes.
        let segments = spec.segments();
        for ((_, shape), (off, seg_shape)) in
            meta.param_shapes.iter().zip(&segments)
        {
            anyhow::ensure!(
                shape == seg_shape,
                "param layout mismatch at offset {off}: {shape:?} vs {seg_shape:?}"
            );
        }
        let train_exe = runtime.compile(&meta.train_step_file)?;
        let eval_exe = runtime.compile(&meta.eval_file)?;
        let train_k_exe = match (&meta.train_k_file, meta.k_max) {
            (Some(f), Some(k)) if k > 0 => Some((runtime.compile(f)?, k)),
            _ => None,
        };
        Ok(XlaEngine {
            spec: spec.clone(),
            train_batch: runtime.meta.train_batch,
            eval_batch: runtime.meta.eval_batch,
            runtime,
            train_exe,
            eval_exe,
            train_k_exe,
            segments,
        })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    fn param_literals(&self, params: &[f32]) -> Result<Vec<xla::Literal>> {
        self.segments
            .iter()
            .map(|(off, shape)| {
                let n: usize = shape.iter().product();
                Runtime::literal_f32(&params[*off..*off + n], shape)
            })
            .collect()
    }
}

impl TrainEngine for XlaEngine {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn train_step(
        &mut self,
        params: &mut [f32],
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        anyhow::ensure!(
            batch.batch == self.train_batch,
            "xla train artifact is shape-specialized to batch {}, got {}",
            self.train_batch,
            batch.batch
        );
        anyhow::ensure!(params.len() == self.spec.num_params());
        let mut inputs = self.param_literals(params)?;
        inputs.push(Runtime::literal_f32(
            &batch.x,
            &[batch.batch, batch.dim],
        )?);
        inputs.push(Runtime::literal_f32(
            &batch.y,
            &[batch.batch, batch.classes],
        )?);
        inputs.push(xla::Literal::scalar(lr));
        let outputs = Runtime::execute(&self.train_exe, &inputs)?;
        anyhow::ensure!(
            outputs.len() == self.segments.len() + 1,
            "train artifact returned {} outputs, expected {}",
            outputs.len(),
            self.segments.len() + 1
        );
        for ((off, shape), lit) in self.segments.iter().zip(&outputs) {
            let n: usize = shape.iter().product();
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("param out: {e:?}"))?;
            params[*off..*off + n].copy_from_slice(&v);
        }
        let loss = outputs
            .last()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss out: {e:?}"))?[0];
        Ok(loss)
    }

    fn train_steps(
        &mut self,
        params: &mut [f32],
        batches: &[Batch],
        lr: f32,
    ) -> Result<f32> {
        if batches.is_empty() {
            return Ok(0.0);
        }
        let Some((_, k_max)) = self.train_k_exe else {
            // no fused artifact: fall back to per-step dispatch
            let mut loss = 0.0;
            for b in batches {
                loss += self.train_step(params, b, lr)?;
            }
            return Ok(loss);
        };
        let mut total_loss = 0.0f32;
        for chunk in batches.chunks(k_max) {
            let h = chunk.len();
            let b0 = &chunk[0];
            anyhow::ensure!(b0.batch == self.train_batch);
            // Stack (K, B, din)/(K, B, C); slots >= h are zero-padded and
            // masked out inside the artifact by the h argument.
            let mut xs = vec![0f32; k_max * b0.batch * b0.dim];
            let mut ys = vec![0f32; k_max * b0.batch * b0.classes];
            for (q, b) in chunk.iter().enumerate() {
                anyhow::ensure!(b.batch == self.train_batch);
                xs[q * b.x.len()..(q + 1) * b.x.len()].copy_from_slice(&b.x);
                ys[q * b.y.len()..(q + 1) * b.y.len()].copy_from_slice(&b.y);
            }
            let mut inputs = self.param_literals(params)?;
            inputs.push(Runtime::literal_f32(
                &xs,
                &[k_max, b0.batch, b0.dim],
            )?);
            inputs.push(Runtime::literal_f32(
                &ys,
                &[k_max, b0.batch, b0.classes],
            )?);
            inputs.push(xla::Literal::scalar(lr));
            inputs.push(xla::Literal::scalar(h as i32));
            let exe = &self.train_k_exe.as_ref().unwrap().0;
            let outputs = Runtime::execute(exe, &inputs)?;
            anyhow::ensure!(outputs.len() == self.segments.len() + 1);
            for ((off, shape), lit) in self.segments.iter().zip(&outputs) {
                let n: usize = shape.iter().product();
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("param out: {e:?}"))?;
                params[*off..*off + n].copy_from_slice(&v);
            }
            total_loss += outputs
                .last()
                .unwrap()
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("loss out: {e:?}"))?[0];
        }
        Ok(total_loss)
    }

    fn evaluate_span(
        &mut self,
        params: &[f32],
        data: &Dataset,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<(f64, f64)>> {
        anyhow::ensure!(hi <= data.len() && lo <= hi);
        let chunk = self.eval_batch;
        let mut out_pairs = Vec::with_capacity((hi - lo).div_ceil(chunk.max(1)));
        let mut i = lo;
        while i < hi {
            let end = (i + chunk).min(hi);
            // The eval artifact is shape-specialized: pad the final chunk
            // by wrapping around the *full* dataset, then correct the sums
            // for the overlap (same walk whether or not the set is
            // sharded, so the chunk contributions are span-independent).
            let idx: Vec<usize> =
                (i..i + chunk).map(|j| j % data.len().max(1)).collect();
            let real = end - i;
            let batch = data.gather_batch(&idx);
            let mut inputs = self.param_literals(params)?;
            inputs.push(Runtime::literal_f32(&batch.x, &[chunk, batch.dim])?);
            inputs.push(Runtime::literal_f32(&batch.y, &[chunk, batch.classes])?);
            let out = Runtime::execute(&self.eval_exe, &inputs)?;
            anyhow::ensure!(out.len() == 2, "eval artifact must return 2 outputs");
            let chunk_loss = out[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("{e:?}"))?[0] as f64;
            let chunk_correct = out[1]
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("{e:?}"))?[0] as f64;
            if real == chunk {
                out_pairs.push((chunk_loss, chunk_correct));
            } else {
                // Proportioning the wrapped tail is approximate; for
                // exactness keep val sizes multiples of the eval batch
                // (the default config does).
                let frac = real as f64 / chunk as f64;
                out_pairs.push((chunk_loss * frac, chunk_correct * frac));
            }
            i = end;
        }
        Ok(out_pairs)
    }

    fn train_batch(&self) -> usize {
        self.train_batch
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

//! Client-availability process: gates which clients the server can reach
//! at a given simulated time.
//!
//! Two non-trivial models, both seeded and lazily materialized (state
//! advances only as simulated time passes, so replays are exact):
//!
//! - **Churn** — per-client alternating renewal process: exponential
//!   up-times and down-times (dropout/rejoin). Clients start up.
//! - **Duty cycle** — deterministic periodic windows with a per-client
//!   random phase: client `i` is reachable while
//!   `(t + phase_i) mod period < on_fraction * period` (think charging /
//!   nightly-connectivity windows).
//!
//! The default [`AvailabilityKind::Always`] routes sampling through the
//! exact pre-net RNG path (`Rng::sample_distinct`), so default-profile
//! trajectories stay bit-identical.
//!
//! ## Event-driven mode (`--event-driven`, default on)
//!
//! The legacy query path costs O(n) per round: sampling and reachability
//! walk every client (`(0..n).filter(is_up)`), which caps fleet sweeps at
//! n≈10⁴. [`ClientAvailability::with_mode`] instead maintains:
//!
//! - a global **event queue** (`BinaryHeap` keyed by time-then-id) holding
//!   each client's next up/down transition — touched only when due, so a
//!   round processes the transitions that actually happened, not n ticks;
//! - a **Fenwick-tree index of up-bits** ([`crate::util::fenwick`])
//!   updated in O(log n) per transition, whose `select(j)` yields the
//!   j-th reachable client in ascending id order — exactly `up[j]` of the
//!   legacy materialized candidate vector, never building it.
//!
//! Sampling then costs O(s log n): short rounds enumerate the ≤ s
//! reachable ids by rank, full rounds run a *sparse* Fisher–Yates
//! ([`crate::util::rng::Rng::sample_distinct_sparse`] — the identical
//! `gen_range` stream as the dense draw) over ranks and map each through
//! `select`. Both modes are bit-identical on every query — same
//! reachability answers, same sampled streams, same residual RNG — which
//! rust/tests/scale_parity.rs proves property-style; the legacy path is
//! retained as that suite's oracle.
//!
//! Exactness argument, per kind: churn clients own independent RNG
//! streams and `state(t)` depends only on the initial state and `t`, so
//! draining a client at a global event time instead of its next query
//! time consumes the same draws in the same order; duty-cycle reads stay
//! closed-form (bit-identical by construction) while the index schedules
//! each boundary conservatively early (− period·1e⁻⁹) and re-evaluates
//! the exact predicate at drain time, so the Fenwick bits agree with the
//! predicate at every query instant.
//!
//! Queries must be non-decreasing in `t` per client in legacy mode, and
//! **globally** non-decreasing in event mode (both hold: every
//! algorithm's clock is monotone; a `debug_assert` checks the global
//! contract on every drain).

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::util::fenwick::Fenwick;
use crate::util::rng::{derive_seed, Rng};

/// Which availability process gates the fleet.
#[derive(Clone, Debug, PartialEq)]
pub enum AvailabilityKind {
    /// every client reachable at all times (default)
    Always,
    /// alternating Exp(1/mean_up) up-times and Exp(1/mean_down) down-times
    Churn { mean_up: f64, mean_down: f64 },
    /// periodic windows: up while (t + phase_i) mod period < on * period
    DutyCycle { period: f64, on_fraction: f64 },
}

impl AvailabilityKind {
    pub fn validate(&self) -> Result<(), String> {
        match self {
            AvailabilityKind::Always => Ok(()),
            AvailabilityKind::Churn { mean_up, mean_down } => {
                if *mean_up <= 0.0 || *mean_down <= 0.0 {
                    return Err(format!(
                        "churn means ({mean_up}, {mean_down}) must be > 0"
                    ));
                }
                Ok(())
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                if *period <= 0.0 {
                    return Err(format!("duty period {period} must be > 0"));
                }
                if !(0.0 < *on_fraction && *on_fraction <= 1.0) {
                    return Err(format!(
                        "duty on-fraction {on_fraction} must be in (0, 1]"
                    ));
                }
                Ok(())
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AvailabilityKind::Always => "always",
            AvailabilityKind::Churn { .. } => "churn",
            AvailabilityKind::DutyCycle { .. } => "duty",
        }
    }
}

/// One client's lazily-materialized churn walk.
#[derive(Clone, Debug)]
struct ChurnState {
    up: bool,
    /// absolute time of the next up/down transition
    next_switch: f64,
    rng: Rng,
}

/// The exact legacy duty-cycle predicate — the single home of the float
/// expression, shared by both query modes so they cannot drift.
#[inline]
fn duty_up(phase: f64, period: f64, on_fraction: f64, t: f64) -> bool {
    (t + phase).rem_euclid(period) < on_fraction * period
}

/// Analytic time of the next duty-window boundary strictly after `t`.
#[inline]
fn duty_next_boundary(phase: f64, period: f64, on_fraction: f64, t: f64) -> f64 {
    let r = (t + phase).rem_euclid(period);
    if r < on_fraction * period {
        t + (on_fraction * period - r) // currently up: next edge is off
    } else {
        t + (period - r) // currently down: next edge is on
    }
}

/// Conservative scheduling margin for duty boundaries: macroscopically
/// larger than float rounding in the analytic boundary, so an event
/// always fires at-or-before the true edge (the drain re-evaluates the
/// exact predicate, so firing early is harmless and firing late never
/// happens).
#[inline]
fn duty_eps(period: f64) -> f64 {
    period * 1e-9
}

/// Smallest representable f64 strictly greater than `t` (t >= 0 finite).
#[inline]
fn next_after_pos(t: f64) -> f64 {
    debug_assert!(t >= 0.0 && t.is_finite());
    f64::from_bits(t.to_bits() + 1)
}

/// One pending up/down re-examination in the event queue.
#[derive(Clone, Debug, PartialEq)]
struct Event {
    time: f64,
    id: usize,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Simulated times are finite; ties break on client id so the
        // drain order is deterministic (same pattern as fedbuff's heap).
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(Ordering::Equal)
            .then(self.id.cmp(&other.id))
    }
}

/// The event-driven index over the availability process: the transition
/// queue plus the Fenwick up-bit set it keeps current.
#[derive(Clone, Debug)]
struct EventIndex {
    /// min-heap of pending transitions (time, then id)
    queue: BinaryHeap<Reverse<Event>>,
    /// 0/1 weight per client; `select(j)` = j-th reachable id, ascending
    up: Fenwick,
    /// high-water mark of processed event times (global monotone guard)
    drained_to: f64,
    /// passive observability counter: transitions popped off the queue
    /// since construction ([`crate::trace`] polls it at round boundaries)
    drained_events: u64,
}

/// The fleet's availability process (one state per client for churn; one
/// phase per client for duty cycles), with an optional event-driven index
/// (see the module docs).
pub struct ClientAvailability {
    kind: AvailabilityKind,
    churn: Vec<ChurnState>,
    phases: Vec<f64>,
    /// event-driven queries requested (also without an index, e.g. Always)
    event_driven: bool,
    /// the queue+Fenwick index (event mode, churn/duty kinds only)
    events: Option<EventIndex>,
    /// permanently evicted clients ([`crate::fault`] dead-client
    /// recovery) — excluded from every query path, in both modes
    dead: Vec<bool>,
    /// number of set bits in `dead`
    evicted: usize,
}

impl ClientAvailability {
    /// Legacy per-query walk — the parity-suite oracle.
    pub fn new(kind: AvailabilityKind, n: usize, seed: u64) -> Self {
        Self::with_mode(kind, n, seed, false)
    }

    /// Build with an explicit query mode. `event_driven = true` installs
    /// the event queue + Fenwick index; per-client processes (RNG
    /// streams, phases) are constructed identically in both modes, so the
    /// underlying stochastic trajectories are the same objects.
    pub fn with_mode(
        kind: AvailabilityKind,
        n: usize,
        seed: u64,
        event_driven: bool,
    ) -> Self {
        let mut churn = Vec::new();
        let mut phases = Vec::new();
        match &kind {
            AvailabilityKind::Always => {}
            AvailabilityKind::Churn { mean_up, .. } => {
                churn = (0..n)
                    .map(|i| {
                        let mut rng = Rng::new(derive_seed(
                            seed,
                            0xC0A0_0000 + i as u64,
                        ));
                        let first = rng.exponential(1.0 / mean_up);
                        ChurnState { up: true, next_switch: first, rng }
                    })
                    .collect();
            }
            AvailabilityKind::DutyCycle { period, .. } => {
                phases = (0..n)
                    .map(|i| {
                        let mut rng = Rng::new(derive_seed(
                            seed,
                            0xD07C_0000 + i as u64,
                        ));
                        rng.uniform(0.0, *period)
                    })
                    .collect();
            }
        }
        let events = if event_driven {
            match &kind {
                AvailabilityKind::Always => None, // nothing ever changes
                AvailabilityKind::Churn { .. } => {
                    let mut queue = BinaryHeap::with_capacity(n);
                    for (i, st) in churn.iter().enumerate() {
                        queue.push(Reverse(Event {
                            time: st.next_switch,
                            id: i,
                        }));
                    }
                    Some(EventIndex {
                        queue,
                        up: Fenwick::from_values(&vec![1; n]), // all start up
                        drained_to: 0.0,
                        drained_events: 0,
                    })
                }
                AvailabilityKind::DutyCycle { period, on_fraction } => {
                    let mut queue = BinaryHeap::new();
                    let bits: Vec<i64> = phases
                        .iter()
                        .map(|&ph| {
                            duty_up(ph, *period, *on_fraction, 0.0) as i64
                        })
                        .collect();
                    if *on_fraction < 1.0 {
                        queue.reserve(n);
                        for (i, &ph) in phases.iter().enumerate() {
                            let tb = duty_next_boundary(
                                ph,
                                *period,
                                *on_fraction,
                                0.0,
                            );
                            queue.push(Reverse(Event {
                                time: (tb - duty_eps(*period)).max(0.0),
                                id: i,
                            }));
                        }
                    } // on_fraction == 1.0: permanently up, no boundaries
                    Some(EventIndex {
                        queue,
                        up: Fenwick::from_values(&bits),
                        drained_to: 0.0,
                        drained_events: 0,
                    })
                }
            }
        } else {
            None
        };
        ClientAvailability {
            kind,
            churn,
            phases,
            event_driven,
            events,
            dead: vec![false; n],
            evicted: 0,
        }
    }

    /// Permanently remove client `id` from the availability process — the
    /// fault layer's dead-client eviction ([`crate::fault`]). The client
    /// is never reachable, never sampled, and `next_up` returns infinity;
    /// in event mode its Fenwick up-bit is cleared immediately and any
    /// still-queued transition event is discarded at its due time (no
    /// stale heap entry ever flips the bit back). Idempotent.
    pub fn evict(&mut self, id: usize) {
        if self.dead[id] {
            return;
        }
        self.dead[id] = true;
        self.evicted += 1;
        if let Some(ev) = self.events.as_mut() {
            if ev.up.get(id) == 1 {
                ev.up.add(id, -1);
            }
        }
    }

    /// True when `id` has been permanently evicted.
    pub fn is_evicted(&self, id: usize) -> bool {
        self.dead[id]
    }

    /// Number of permanently evicted clients.
    pub fn evicted_count(&self) -> usize {
        self.evicted
    }

    pub fn kind(&self) -> &AvailabilityKind {
        &self.kind
    }

    /// True when no process gates the fleet (the exact pre-net path).
    pub fn is_always(&self) -> bool {
        self.kind == AvailabilityKind::Always
    }

    /// True when queries run through the event queue + Fenwick index.
    pub fn is_event_driven(&self) -> bool {
        self.event_driven
    }

    /// Passive trace counters for the event-driven index:
    /// `(events_drained, queue_depth, fenwick_ops)` — all zero without an
    /// index (legacy mode, or `Always`). Polled by [`crate::trace`] at
    /// round boundaries; reading perturbs nothing.
    pub fn event_stats(&self) -> (u64, usize, u64) {
        match &self.events {
            Some(ev) => (ev.drained_events, ev.queue.len(), ev.up.ops()),
            None => (0, 0, 0),
        }
    }

    /// Process every transition due at or before `t`, keeping churn
    /// states and the Fenwick up-bits current. O(events·log n); a no-op
    /// when nothing is due. Event-mode queries must be globally
    /// non-decreasing in `t` (every algorithm's clock is monotone).
    fn drain(&mut self, t: f64) {
        let ClientAvailability { kind, churn, phases, events, dead, .. } =
            self;
        let Some(ev) = events.as_mut() else { return };
        debug_assert!(
            t >= ev.drained_to,
            "event-driven availability queried at t={t} after t={} — \
             queries must be globally non-decreasing",
            ev.drained_to
        );
        if t < ev.drained_to {
            return; // release-mode safety: never rewind the index
        }
        ev.drained_to = t;
        match kind {
            AvailabilityKind::Always => {}
            AvailabilityKind::Churn { mean_up, mean_down } => {
                let (mu, md) = (*mean_up, *mean_down);
                while let Some(Reverse(top)) = ev.queue.peek() {
                    if top.time > t {
                        break;
                    }
                    let Reverse(Event { id, .. }) = ev.queue.pop().unwrap();
                    if dead[id] {
                        continue; // evicted: discard, never re-schedule
                    }
                    ev.drained_events += 1;
                    let st = &mut churn[id];
                    let was_up = st.up;
                    // Identical to the legacy advance_churn walk: same
                    // per-client RNG stream, same draw order.
                    while st.next_switch <= t {
                        st.up = !st.up;
                        let mean = if st.up { mu } else { md };
                        st.next_switch += st.rng.exponential(1.0 / mean);
                    }
                    if st.up != was_up {
                        ev.up.add(id, if st.up { 1 } else { -1 });
                    }
                    ev.queue.push(Reverse(Event {
                        time: st.next_switch,
                        id,
                    }));
                }
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                let (p, on) = (*period, *on_fraction);
                while let Some(Reverse(top)) = ev.queue.peek() {
                    if top.time > t {
                        break;
                    }
                    let Reverse(Event { id, .. }) = ev.queue.pop().unwrap();
                    if dead[id] {
                        continue; // evicted: discard, never re-schedule
                    }
                    ev.drained_events += 1;
                    // The event time is conservative; the *exact* legacy
                    // predicate at the drain instant decides the bit.
                    let now_up = duty_up(phases[id], p, on, t);
                    let was_up = ev.up.get(id) == 1;
                    if now_up != was_up {
                        ev.up.add(id, if now_up { 1 } else { -1 });
                    }
                    let mut te =
                        duty_next_boundary(phases[id], p, on, t) - duty_eps(p);
                    if te <= t {
                        // Boundary is imminent (within eps): park the
                        // event just after t so the next drain at or past
                        // the edge applies the flip. Never re-fires
                        // within this drain.
                        te = next_after_pos(t);
                    }
                    ev.queue.push(Reverse(Event { time: te, id }));
                }
            }
        }
    }

    fn advance_churn(&mut self, i: usize, t: f64) {
        let (mean_up, mean_down) = match self.kind {
            AvailabilityKind::Churn { mean_up, mean_down } => (mean_up, mean_down),
            _ => unreachable!("advance_churn outside churn mode"),
        };
        let st = &mut self.churn[i];
        while st.next_switch <= t {
            st.up = !st.up;
            let mean = if st.up { mean_up } else { mean_down };
            st.next_switch += st.rng.exponential(1.0 / mean);
        }
    }

    /// Is client `i` reachable at time `t`? (`t` non-decreasing — per
    /// client in legacy mode, globally in event mode)
    pub fn is_up(&mut self, i: usize, t: f64) -> bool {
        if self.dead[i] {
            return false;
        }
        match &self.kind {
            AvailabilityKind::Always => true,
            AvailabilityKind::Churn { .. } => {
                if self.events.is_some() {
                    // After the drain every next_switch exceeds t, so the
                    // stored state is the state at t.
                    self.drain(t);
                } else {
                    self.advance_churn(i, t);
                }
                self.churn[i].up
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                // Closed-form in both modes — stateless, bit-identical.
                duty_up(self.phases[i], *period, *on_fraction, t)
            }
        }
    }

    /// Earliest time >= `t` at which client `i` is reachable. Returns `t`
    /// itself (bitwise) when the client is already up — the `Always` path
    /// is therefore an exact no-op.
    pub fn next_up(&mut self, i: usize, t: f64) -> f64 {
        if self.dead[i] {
            return f64::INFINITY; // evicted clients never come back
        }
        match &self.kind {
            AvailabilityKind::Always => t,
            AvailabilityKind::Churn { .. } => {
                if self.events.is_some() {
                    self.drain(t);
                } else {
                    self.advance_churn(i, t);
                }
                if self.churn[i].up {
                    t
                } else {
                    self.churn[i].next_switch
                }
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                let r = (t + self.phases[i]).rem_euclid(*period);
                if r < on_fraction * period {
                    t
                } else {
                    t + (period - r)
                }
            }
        }
    }

    /// All clients reachable at `t`, ascending id order — the candidate
    /// set the non-uniform selection policies rank. Legacy mode walks all
    /// n clients; event mode enumerates the `u` set bits of the Fenwick
    /// index by rank in O(u log n). Identical output, zero RNG, in both.
    pub fn reachable(&mut self, n: usize, t: f64) -> Vec<usize> {
        if self.is_always() {
            if self.evicted == 0 {
                return (0..n).collect();
            }
            return (0..n).filter(|&i| !self.dead[i]).collect();
        }
        if self.events.is_some() {
            self.drain(t);
            let ev = self.events.as_ref().unwrap();
            debug_assert_eq!(ev.up.len(), n, "fleet size mismatch");
            return (0..ev.up.total()).map(|j| ev.up.select(j)).collect();
        }
        (0..n).filter(|&i| self.is_up(i, t)).collect()
    }

    /// Sample up to `s` distinct reachable clients at time `t`. With
    /// `Always` this is exactly `rng.sample_distinct(n, s)` — same RNG
    /// stream, same result as the pre-net code (event mode runs the
    /// bit-identical sparse draw). Otherwise the draw happens inside the
    /// reachable subset; if it has <= `s` members they are all returned
    /// in ascending order without consuming randomness (a short round).
    /// Event mode replaces the materialized subset with Fenwick
    /// rank-selection: `select(j)` is the legacy `up[j]`, so picks and
    /// residual streams match the legacy path bit for bit.
    pub fn sample(
        &mut self,
        rng: &mut Rng,
        n: usize,
        s: usize,
        t: f64,
    ) -> Vec<usize> {
        if self.is_always() {
            if self.evicted > 0 {
                // Evictions only happen on faulted runs, so leaving the
                // exact pre-net RNG path here cannot perturb a default
                // trajectory.
                let live: Vec<usize> =
                    (0..n).filter(|&i| !self.dead[i]).collect();
                if live.len() <= s {
                    return live;
                }
                let picks = if self.event_driven {
                    rng.sample_distinct_sparse(live.len(), s)
                } else {
                    rng.sample_distinct(live.len(), s)
                };
                return picks.into_iter().map(|j| live[j]).collect();
            }
            return if self.event_driven {
                rng.sample_distinct_sparse(n, s)
            } else {
                rng.sample_distinct(n, s)
            };
        }
        if self.events.is_some() {
            self.drain(t);
            let ev = self.events.as_ref().unwrap();
            debug_assert_eq!(ev.up.len(), n, "fleet size mismatch");
            let m = ev.up.total();
            if m as usize <= s {
                return (0..m).map(|j| ev.up.select(j)).collect();
            }
            return rng
                .sample_distinct_sparse(m as usize, s)
                .into_iter()
                .map(|j| ev.up.select(j as i64))
                .collect();
        }
        let up: Vec<usize> = (0..n).filter(|&i| self.is_up(i, t)).collect();
        if up.len() <= s {
            return up;
        }
        rng.sample_distinct(up.len(), s)
            .into_iter()
            .map(|j| up[j])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_matches_plain_sampling_stream() {
        let mut av = ClientAvailability::new(AvailabilityKind::Always, 20, 1);
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for t in 0..10 {
            assert_eq!(
                av.sample(&mut r1, 20, 6, t as f64),
                r2.sample_distinct(20, 6)
            );
        }
        assert_eq!(av.next_up(3, 17.5).to_bits(), 17.5f64.to_bits());
        assert!(av.is_up(0, 0.0));
    }

    #[test]
    fn always_event_mode_matches_plain_sampling_stream() {
        let mut av = ClientAvailability::with_mode(
            AvailabilityKind::Always,
            20,
            1,
            true,
        );
        assert!(av.is_event_driven());
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for t in 0..10 {
            assert_eq!(
                av.sample(&mut r1, 20, 6, t as f64),
                r2.sample_distinct(20, 6)
            );
        }
        assert_eq!(r1.next_u64(), r2.next_u64(), "residual streams");
        assert_eq!(av.next_up(3, 17.5).to_bits(), 17.5f64.to_bits());
    }

    #[test]
    fn churn_replays_identically() {
        let kind = AvailabilityKind::Churn { mean_up: 30.0, mean_down: 10.0 };
        let mut a = ClientAvailability::new(kind.clone(), 8, 9);
        let mut b = ClientAvailability::new(kind, 8, 9);
        for step in 0..200 {
            let t = step as f64 * 1.7;
            for i in 0..8 {
                assert_eq!(a.is_up(i, t), b.is_up(i, t), "client {i} at {t}");
            }
        }
    }

    #[test]
    fn churn_event_mode_matches_legacy() {
        let kind = AvailabilityKind::Churn { mean_up: 30.0, mean_down: 10.0 };
        let mut legacy = ClientAvailability::new(kind.clone(), 8, 9);
        let mut event = ClientAvailability::with_mode(kind, 8, 9, true);
        for step in 0..200 {
            let t = step as f64 * 1.7;
            for i in 0..8 {
                assert_eq!(
                    legacy.is_up(i, t),
                    event.is_up(i, t),
                    "client {i} at {t}"
                );
                assert_eq!(
                    legacy.next_up(i, t).to_bits(),
                    event.next_up(i, t).to_bits(),
                    "client {i} at {t}"
                );
            }
            assert_eq!(legacy.reachable(8, t), event.reachable(8, t), "t={t}");
        }
    }

    #[test]
    fn churn_seed_changes_trajectory() {
        let kind = AvailabilityKind::Churn { mean_up: 20.0, mean_down: 20.0 };
        let mut a = ClientAvailability::new(kind.clone(), 8, 1);
        let mut b = ClientAvailability::new(kind, 8, 2);
        let mut diff = 0;
        for step in 0..100 {
            let t = step as f64 * 5.0;
            for i in 0..8 {
                if a.is_up(i, t) != b.is_up(i, t) {
                    diff += 1;
                }
            }
        }
        assert!(diff > 0, "different seeds must give different churn");
    }

    #[test]
    fn churn_long_run_fraction_matches_means() {
        // Stationary availability = mean_up / (mean_up + mean_down).
        let kind = AvailabilityKind::Churn { mean_up: 30.0, mean_down: 10.0 };
        let mut av = ClientAvailability::new(kind, 200, 5);
        let mut up = 0usize;
        let mut total = 0usize;
        for step in 1..=400 {
            let t = step as f64 * 7.0;
            for i in 0..200 {
                total += 1;
                if av.is_up(i, t) {
                    up += 1;
                }
            }
        }
        let frac = up as f64 / total as f64;
        assert!((frac - 0.75).abs() < 0.05, "availability {frac}");
    }

    #[test]
    fn churn_next_up_is_consistent() {
        let kind = AvailabilityKind::Churn { mean_up: 5.0, mean_down: 5.0 };
        let mut av = ClientAvailability::new(kind.clone(), 4, 3);
        let mut chk = ClientAvailability::new(kind, 4, 3);
        for step in 0..100 {
            let t = step as f64 * 2.3;
            for i in 0..4 {
                let nu = av.next_up(i, t);
                assert!(nu >= t);
                // The sibling process must agree the client is up there
                // (just after, for the boundary case of an exact switch).
                assert!(chk.is_up(i, nu + 1e-9), "client {i}: next_up {nu}");
            }
        }
    }

    #[test]
    fn duty_cycle_windows() {
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.5 };
        let mut av = ClientAvailability::new(kind, 3, 7);
        for i in 0..3 {
            // Over one full period the client is up about half the time.
            let up = (0..1000)
                .filter(|k| av.is_up(i, *k as f64 * 0.01))
                .count();
            assert!((up as f64 / 1000.0 - 0.5).abs() < 0.02, "duty {up}");
            // next_up always lands inside a window.
            for k in 0..40 {
                let t = k as f64 * 0.7;
                let nu = av.next_up(i, t);
                assert!(av.is_up(i, nu + 1e-9), "t={t} nu={nu}");
                assert!(nu >= t && nu <= t + 10.0);
            }
        }
    }

    #[test]
    fn duty_event_mode_matches_legacy() {
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.3 };
        let mut legacy = ClientAvailability::new(kind.clone(), 12, 7);
        let mut event = ClientAvailability::with_mode(kind, 12, 7, true);
        for step in 0..300 {
            let t = step as f64 * 0.31;
            for i in 0..12 {
                assert_eq!(
                    legacy.is_up(i, t),
                    event.is_up(i, t),
                    "client {i} at {t}"
                );
            }
            assert_eq!(legacy.reachable(12, t), event.reachable(12, t), "t={t}");
        }
    }

    #[test]
    fn duty_full_on_fraction_has_no_events_and_everyone_up() {
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 1.0 };
        let mut event = ClientAvailability::with_mode(kind, 6, 3, true);
        for step in 0..20 {
            let t = step as f64 * 3.3;
            assert_eq!(event.reachable(6, t), (0..6).collect::<Vec<_>>());
        }
    }

    #[test]
    fn gated_sampling_returns_only_reachable_clients() {
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.3 };
        let mut av = ClientAvailability::new(kind, 30, 11);
        let mut rng = Rng::new(1);
        for k in 0..30 {
            let t = k as f64 * 3.1;
            let picked = av.sample(&mut rng, 30, 10, t);
            assert!(picked.len() <= 10);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), picked.len(), "distinct");
            for &i in &picked {
                assert!(av.is_up(i, t), "client {i} sampled while down");
            }
        }
    }

    #[test]
    fn event_sampling_matches_legacy_streams() {
        for kind in [
            AvailabilityKind::Churn { mean_up: 40.0, mean_down: 15.0 },
            AvailabilityKind::DutyCycle { period: 12.0, on_fraction: 0.4 },
        ] {
            let mut legacy = ClientAvailability::new(kind.clone(), 40, 13);
            let mut event =
                ClientAvailability::with_mode(kind.clone(), 40, 13, true);
            let mut r1 = Rng::new(99);
            let mut r2 = Rng::new(99);
            for k in 0..60 {
                let t = k as f64 * 2.9;
                assert_eq!(
                    legacy.sample(&mut r1, 40, 7, t),
                    event.sample(&mut r2, 40, 7, t),
                    "{} t={t}",
                    kind.name()
                );
            }
            assert_eq!(r1.next_u64(), r2.next_u64(), "{}", kind.name());
        }
    }

    #[test]
    fn event_stats_count_drains_and_stay_zero_in_legacy_mode() {
        let kind = AvailabilityKind::Churn { mean_up: 5.0, mean_down: 5.0 };
        let mut legacy = ClientAvailability::new(kind.clone(), 8, 3);
        let mut event = ClientAvailability::with_mode(kind, 8, 3, true);
        for step in 0..40 {
            let t = step as f64 * 4.0;
            let _ = legacy.reachable(8, t);
            let _ = event.reachable(8, t);
        }
        assert_eq!(legacy.event_stats(), (0, 0, 0));
        let (drained, depth, fops) = event.event_stats();
        assert!(drained > 0, "churn over 160s must pop transitions");
        assert_eq!(depth, 8, "every churn client keeps one pending event");
        assert!(fops > 0, "fenwick served the reachability queries");
    }

    #[test]
    fn evicted_clients_leave_every_query_path() {
        // Satellite regression for [`crate::fault`] dead-client eviction:
        // across all three kinds and both query modes, an evicted client
        // is never up, never reachable, never sampled, and its next_up is
        // infinite — forever.
        let kinds = [
            AvailabilityKind::Always,
            AvailabilityKind::Churn { mean_up: 4.0, mean_down: 4.0 },
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.6 },
        ];
        for kind in kinds {
            for mode in [false, true] {
                let mut av =
                    ClientAvailability::with_mode(kind.clone(), 12, 9, mode);
                av.evict(3);
                av.evict(7);
                av.evict(7); // idempotent
                assert!(av.is_evicted(3) && av.is_evicted(7));
                assert!(!av.is_evicted(0));
                assert_eq!(av.evicted_count(), 2);
                let mut rng = Rng::new(5);
                for step in 0..80 {
                    let t = step as f64 * 1.3;
                    assert!(!av.is_up(3, t), "{} t={t}", kind.name());
                    assert_eq!(av.next_up(7, t), f64::INFINITY);
                    let reach = av.reachable(12, t);
                    assert!(
                        !reach.contains(&3) && !reach.contains(&7),
                        "{} mode={mode} t={t}: evicted client reachable",
                        kind.name()
                    );
                    for i in av.sample(&mut rng, 12, 5, t) {
                        assert!(i != 3 && i != 7, "evicted client sampled");
                    }
                }
            }
        }
    }

    #[test]
    fn eviction_keeps_fenwick_in_sync_with_live_oracle() {
        // The event queue holds a pending transition for every churn
        // client at eviction time; those stale events must be discarded —
        // not flip the Fenwick bit back — so the up-set always equals the
        // legacy per-client oracle restricted to live clients.
        let kind = AvailabilityKind::Churn { mean_up: 5.0, mean_down: 5.0 };
        let mut legacy = ClientAvailability::new(kind.clone(), 16, 21);
        let mut event = ClientAvailability::with_mode(kind, 16, 21, true);
        for id in [2, 5, 11] {
            legacy.evict(id);
            event.evict(id);
        }
        for step in 0..200 {
            let t = step as f64 * 0.9;
            let oracle: Vec<usize> =
                (0..16).filter(|&i| legacy.is_up(i, t)).collect();
            assert_eq!(event.reachable(16, t), oracle, "t={t}");
        }
        // Mid-run eviction of a currently-up client drops it immediately.
        let victim = event.reachable(16, 180.0)[0];
        event.evict(victim);
        legacy.evict(victim);
        for step in 200..260 {
            let t = step as f64 * 0.9;
            let oracle: Vec<usize> =
                (0..16).filter(|&i| legacy.is_up(i, t)).collect();
            assert_eq!(event.reachable(16, t), oracle, "t={t}");
            assert!(!event.reachable(16, t).contains(&victim));
        }
    }

    #[test]
    fn validate_kinds() {
        assert!(AvailabilityKind::Always.validate().is_ok());
        assert!(AvailabilityKind::Churn { mean_up: 0.0, mean_down: 1.0 }
            .validate()
            .is_err());
        assert!(AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.0 }
            .validate()
            .is_err());
        assert!(AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 1.0 }
            .validate()
            .is_ok());
    }
}

//! Client-availability process: gates which clients the server can reach
//! at a given simulated time.
//!
//! Two non-trivial models, both seeded and lazily materialized (state
//! advances only as simulated time passes, so replays are exact):
//!
//! - **Churn** — per-client alternating renewal process: exponential
//!   up-times and down-times (dropout/rejoin). Clients start up.
//! - **Duty cycle** — deterministic periodic windows with a per-client
//!   random phase: client `i` is reachable while
//!   `(t + phase_i) mod period < on_fraction * period` (think charging /
//!   nightly-connectivity windows).
//!
//! The default [`AvailabilityKind::Always`] routes sampling through the
//! exact pre-net RNG path (`Rng::sample_distinct`), so default-profile
//! trajectories stay bit-identical.
//!
//! Queries must be non-decreasing in `t` per client (they are: every
//! algorithm's clock is monotone), matching the lazy churn walk.

use crate::util::rng::{derive_seed, Rng};

/// Which availability process gates the fleet.
#[derive(Clone, Debug, PartialEq)]
pub enum AvailabilityKind {
    /// every client reachable at all times (default)
    Always,
    /// alternating Exp(1/mean_up) up-times and Exp(1/mean_down) down-times
    Churn { mean_up: f64, mean_down: f64 },
    /// periodic windows: up while (t + phase_i) mod period < on * period
    DutyCycle { period: f64, on_fraction: f64 },
}

impl AvailabilityKind {
    pub fn validate(&self) -> Result<(), String> {
        match self {
            AvailabilityKind::Always => Ok(()),
            AvailabilityKind::Churn { mean_up, mean_down } => {
                if *mean_up <= 0.0 || *mean_down <= 0.0 {
                    return Err(format!(
                        "churn means ({mean_up}, {mean_down}) must be > 0"
                    ));
                }
                Ok(())
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                if *period <= 0.0 {
                    return Err(format!("duty period {period} must be > 0"));
                }
                if !(0.0 < *on_fraction && *on_fraction <= 1.0) {
                    return Err(format!(
                        "duty on-fraction {on_fraction} must be in (0, 1]"
                    ));
                }
                Ok(())
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AvailabilityKind::Always => "always",
            AvailabilityKind::Churn { .. } => "churn",
            AvailabilityKind::DutyCycle { .. } => "duty",
        }
    }
}

/// One client's lazily-materialized churn walk.
#[derive(Clone, Debug)]
struct ChurnState {
    up: bool,
    /// absolute time of the next up/down transition
    next_switch: f64,
    rng: Rng,
}

/// The fleet's availability process (one state per client for churn; one
/// phase per client for duty cycles).
pub struct ClientAvailability {
    kind: AvailabilityKind,
    churn: Vec<ChurnState>,
    phases: Vec<f64>,
}

impl ClientAvailability {
    pub fn new(kind: AvailabilityKind, n: usize, seed: u64) -> Self {
        let mut churn = Vec::new();
        let mut phases = Vec::new();
        match &kind {
            AvailabilityKind::Always => {}
            AvailabilityKind::Churn { mean_up, .. } => {
                churn = (0..n)
                    .map(|i| {
                        let mut rng = Rng::new(derive_seed(
                            seed,
                            0xC0A0_0000 + i as u64,
                        ));
                        let first = rng.exponential(1.0 / mean_up);
                        ChurnState { up: true, next_switch: first, rng }
                    })
                    .collect();
            }
            AvailabilityKind::DutyCycle { period, .. } => {
                phases = (0..n)
                    .map(|i| {
                        let mut rng = Rng::new(derive_seed(
                            seed,
                            0xD07C_0000 + i as u64,
                        ));
                        rng.uniform(0.0, *period)
                    })
                    .collect();
            }
        }
        ClientAvailability { kind, churn, phases }
    }

    pub fn kind(&self) -> &AvailabilityKind {
        &self.kind
    }

    /// True when no process gates the fleet (the exact pre-net path).
    pub fn is_always(&self) -> bool {
        self.kind == AvailabilityKind::Always
    }

    fn advance_churn(&mut self, i: usize, t: f64) {
        let (mean_up, mean_down) = match self.kind {
            AvailabilityKind::Churn { mean_up, mean_down } => (mean_up, mean_down),
            _ => unreachable!("advance_churn outside churn mode"),
        };
        let st = &mut self.churn[i];
        while st.next_switch <= t {
            st.up = !st.up;
            let mean = if st.up { mean_up } else { mean_down };
            st.next_switch += st.rng.exponential(1.0 / mean);
        }
    }

    /// Is client `i` reachable at time `t`? (`t` non-decreasing per client)
    pub fn is_up(&mut self, i: usize, t: f64) -> bool {
        match &self.kind {
            AvailabilityKind::Always => true,
            AvailabilityKind::Churn { .. } => {
                self.advance_churn(i, t);
                self.churn[i].up
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                (t + self.phases[i]).rem_euclid(*period) < on_fraction * period
            }
        }
    }

    /// Earliest time >= `t` at which client `i` is reachable. Returns `t`
    /// itself (bitwise) when the client is already up — the `Always` path
    /// is therefore an exact no-op.
    pub fn next_up(&mut self, i: usize, t: f64) -> f64 {
        match &self.kind {
            AvailabilityKind::Always => t,
            AvailabilityKind::Churn { .. } => {
                self.advance_churn(i, t);
                if self.churn[i].up {
                    t
                } else {
                    self.churn[i].next_switch
                }
            }
            AvailabilityKind::DutyCycle { period, on_fraction } => {
                let r = (t + self.phases[i]).rem_euclid(*period);
                if r < on_fraction * period {
                    t
                } else {
                    t + (period - r)
                }
            }
        }
    }

    /// Sample up to `s` distinct reachable clients at time `t`. With
    /// `Always` this is exactly `rng.sample_distinct(n, s)` — same RNG
    /// stream, same result as the pre-net code. Otherwise the reachable
    /// subset is enumerated first and the draw happens inside it; if the
    /// subset has <= `s` members they are all returned (a short round).
    pub fn sample(
        &mut self,
        rng: &mut Rng,
        n: usize,
        s: usize,
        t: f64,
    ) -> Vec<usize> {
        if self.is_always() {
            return rng.sample_distinct(n, s);
        }
        let up: Vec<usize> = (0..n).filter(|&i| self.is_up(i, t)).collect();
        if up.len() <= s {
            return up;
        }
        rng.sample_distinct(up.len(), s)
            .into_iter()
            .map(|j| up[j])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_matches_plain_sampling_stream() {
        let mut av = ClientAvailability::new(AvailabilityKind::Always, 20, 1);
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for t in 0..10 {
            assert_eq!(
                av.sample(&mut r1, 20, 6, t as f64),
                r2.sample_distinct(20, 6)
            );
        }
        assert_eq!(av.next_up(3, 17.5).to_bits(), 17.5f64.to_bits());
        assert!(av.is_up(0, 0.0));
    }

    #[test]
    fn churn_replays_identically() {
        let kind = AvailabilityKind::Churn { mean_up: 30.0, mean_down: 10.0 };
        let mut a = ClientAvailability::new(kind.clone(), 8, 9);
        let mut b = ClientAvailability::new(kind, 8, 9);
        for step in 0..200 {
            let t = step as f64 * 1.7;
            for i in 0..8 {
                assert_eq!(a.is_up(i, t), b.is_up(i, t), "client {i} at {t}");
            }
        }
    }

    #[test]
    fn churn_seed_changes_trajectory() {
        let kind = AvailabilityKind::Churn { mean_up: 20.0, mean_down: 20.0 };
        let mut a = ClientAvailability::new(kind.clone(), 8, 1);
        let mut b = ClientAvailability::new(kind, 8, 2);
        let mut diff = 0;
        for step in 0..100 {
            let t = step as f64 * 5.0;
            for i in 0..8 {
                if a.is_up(i, t) != b.is_up(i, t) {
                    diff += 1;
                }
            }
        }
        assert!(diff > 0, "different seeds must give different churn");
    }

    #[test]
    fn churn_long_run_fraction_matches_means() {
        // Stationary availability = mean_up / (mean_up + mean_down).
        let kind = AvailabilityKind::Churn { mean_up: 30.0, mean_down: 10.0 };
        let mut av = ClientAvailability::new(kind, 200, 5);
        let mut up = 0usize;
        let mut total = 0usize;
        for step in 1..=400 {
            let t = step as f64 * 7.0;
            for i in 0..200 {
                total += 1;
                if av.is_up(i, t) {
                    up += 1;
                }
            }
        }
        let frac = up as f64 / total as f64;
        assert!((frac - 0.75).abs() < 0.05, "availability {frac}");
    }

    #[test]
    fn churn_next_up_is_consistent() {
        let kind = AvailabilityKind::Churn { mean_up: 5.0, mean_down: 5.0 };
        let mut av = ClientAvailability::new(kind.clone(), 4, 3);
        let mut chk = ClientAvailability::new(kind, 4, 3);
        for step in 0..100 {
            let t = step as f64 * 2.3;
            for i in 0..4 {
                let nu = av.next_up(i, t);
                assert!(nu >= t);
                // The sibling process must agree the client is up there
                // (just after, for the boundary case of an exact switch).
                assert!(chk.is_up(i, nu + 1e-9), "client {i}: next_up {nu}");
            }
        }
    }

    #[test]
    fn duty_cycle_windows() {
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.5 };
        let mut av = ClientAvailability::new(kind, 3, 7);
        for i in 0..3 {
            // Over one full period the client is up about half the time.
            let up = (0..1000)
                .filter(|k| av.is_up(i, *k as f64 * 0.01))
                .count();
            assert!((up as f64 / 1000.0 - 0.5).abs() < 0.02, "duty {up}");
            // next_up always lands inside a window.
            for k in 0..40 {
                let t = k as f64 * 0.7;
                let nu = av.next_up(i, t);
                assert!(av.is_up(i, nu + 1e-9), "t={t} nu={nu}");
                assert!(nu >= t && nu <= t + 10.0);
            }
        }
    }

    #[test]
    fn gated_sampling_returns_only_reachable_clients() {
        let kind =
            AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.3 };
        let mut av = ClientAvailability::new(kind, 30, 11);
        let mut rng = Rng::new(1);
        for k in 0..30 {
            let t = k as f64 * 3.1;
            let picked = av.sample(&mut rng, 30, 10, t);
            assert!(picked.len() <= 10);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), picked.len(), "distinct");
            for &i in &picked {
                assert!(av.is_up(i, t), "client {i} sampled while down");
            }
        }
    }

    #[test]
    fn validate_kinds() {
        assert!(AvailabilityKind::Always.validate().is_ok());
        assert!(AvailabilityKind::Churn { mean_up: 0.0, mean_down: 1.0 }
            .validate()
            .is_err());
        assert!(AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 0.0 }
            .validate()
            .is_err());
        assert!(AvailabilityKind::DutyCycle { period: 10.0, on_fraction: 1.0 }
            .validate()
            .is_ok());
    }
}

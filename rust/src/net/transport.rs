//! Transport pricing: convert *actual* encoded message sizes into
//! simulated transmission time, per client and per direction.
//!
//! The contract with the algorithms is strict: every server↔client
//! exchange is priced from the exact bit count the quantizer encoder
//! produced for that message (`QuantMessage::bits`, or the analytic
//! `Quantizer::encoded_bits` when the send time must be known before the
//! payload is materialized — the two are property-tested equal in
//! `rust/tests/net_parity.rs`). [`IdealTransport`] prices everything at
//! exactly `0.0`, which makes the default network profile a bit-exact
//! no-op on every trajectory.

use crate::util::rng::{derive_seed, Rng};

use super::dist::Dist;

/// Prices one directed transfer. `Sync` so the coordinator can share it
/// with worker threads if an algorithm ever prices inside a fan-out.
pub trait Transport: Send + Sync {
    /// Simulated time for `bits` to travel server → client `i`.
    fn downlink_time(&self, client: usize, bits: u64) -> f64;
    /// Simulated time for `bits` to travel client `i` → server.
    fn uplink_time(&self, client: usize, bits: u64) -> f64;
    fn name(&self) -> &'static str;
}

/// The zero-cost network: every exchange is instantaneous. Default — and
/// deliberately `0.0` (not "very fast") so `t + cost` is bitwise `t` and
/// pre-net trajectories are reproduced exactly.
pub struct IdealTransport;

impl Transport for IdealTransport {
    fn downlink_time(&self, _client: usize, _bits: u64) -> f64 {
        0.0
    }

    fn uplink_time(&self, _client: usize, _bits: u64) -> f64 {
        0.0
    }

    fn name(&self) -> &'static str {
        "ideal"
    }
}

/// One client's link: fixed for the run (bandwidth skew is a per-client
/// property; per-message jitter comes from message sizes and the latency
/// floor).
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// uplink bandwidth, bits per simulated-time unit
    pub up_bw: f64,
    /// downlink bandwidth, bits per simulated-time unit
    pub down_bw: f64,
    /// per-message latency floor, either direction
    pub latency: f64,
}

/// Per-client links drawn once from the profile's distributions at setup
/// (seeded — the same profile + seed materializes the same fleet).
pub struct SimTransport {
    links: Vec<Link>,
}

/// Floor that keeps a pathological draw from producing infinite transfer
/// times (bits / bw stays finite).
const MIN_BANDWIDTH: f64 = 1e-6;

impl SimTransport {
    pub fn draw(
        n: usize,
        up_bw: &Dist,
        down_bw: &Dist,
        latency: &Dist,
        seed: u64,
    ) -> Self {
        let links = (0..n)
            .map(|i| {
                let mut rng =
                    Rng::new(derive_seed(seed, 0x4E70_0000 + i as u64));
                Link {
                    up_bw: up_bw.sample(&mut rng).max(MIN_BANDWIDTH),
                    down_bw: down_bw.sample(&mut rng).max(MIN_BANDWIDTH),
                    latency: latency.sample(&mut rng).max(0.0),
                }
            })
            .collect();
        SimTransport { links }
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }
}

impl Transport for SimTransport {
    fn downlink_time(&self, client: usize, bits: u64) -> f64 {
        let l = &self.links[client];
        l.latency + bits as f64 / l.down_bw
    }

    fn uplink_time(&self, client: usize, bits: u64) -> f64 {
        let l = &self.links[client];
        l.latency + bits as f64 / l.up_bw
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_exactly_zero() {
        let t = IdealTransport;
        assert_eq!(t.uplink_time(3, u64::MAX).to_bits(), 0f64.to_bits());
        assert_eq!(t.downlink_time(0, 0).to_bits(), 0f64.to_bits());
    }

    #[test]
    fn sim_prices_latency_plus_serialization() {
        let t = SimTransport {
            links: vec![Link { up_bw: 100.0, down_bw: 400.0, latency: 0.5 }],
        };
        assert!((t.uplink_time(0, 1000) - 10.5).abs() < 1e-12);
        assert!((t.downlink_time(0, 1000) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn draw_is_seed_deterministic_and_per_client() {
        let up = Dist::Pareto { scale: 1e4, shape: 1.5 };
        let down = Dist::LogNormal { median: 1e6, sigma: 0.5 };
        let lat = Dist::Const(0.1);
        let a = SimTransport::draw(16, &up, &down, &lat, 7);
        let b = SimTransport::draw(16, &up, &down, &lat, 7);
        for (x, y) in a.links().iter().zip(b.links()) {
            assert_eq!(x.up_bw.to_bits(), y.up_bw.to_bits());
            assert_eq!(x.down_bw.to_bits(), y.down_bw.to_bits());
            assert_eq!(x.latency, y.latency);
        }
        // Different clients get independent draws (bandwidth skew).
        let distinct: std::collections::BTreeSet<u64> =
            a.links().iter().map(|l| l.up_bw.to_bits()).collect();
        assert!(distinct.len() > 8, "per-client draws should differ");
        let c = SimTransport::draw(16, &up, &down, &lat, 8);
        assert_ne!(
            a.links()[0].up_bw.to_bits(),
            c.links()[0].up_bw.to_bits()
        );
    }

    #[test]
    fn zero_bandwidth_draw_is_floored() {
        let t = SimTransport::draw(
            1,
            &Dist::Const(0.0),
            &Dist::Const(0.0),
            &Dist::Const(0.0),
            1,
        );
        assert!(t.uplink_time(0, 1_000_000).is_finite());
    }
}

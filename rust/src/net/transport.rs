//! Transport pricing: convert *actual* encoded message sizes into
//! simulated transmission time, per client and per direction.
//!
//! The contract with the algorithms is strict: every server↔client
//! exchange is priced from the exact bit count the quantizer encoder
//! produced for that message (`QuantMessage::bits`, or the analytic
//! `Quantizer::encoded_bits` when the send time must be known before the
//! payload is materialized — the two are property-tested equal in
//! `rust/tests/net_parity.rs`). [`IdealTransport`] prices everything at
//! exactly `0.0`, which makes the default network profile a bit-exact
//! no-op on every trajectory.

use crate::util::rng::{derive_seed, Rng};
use crate::util::stats::{normal_cdf, normal_quantile};

use super::dist::Dist;

/// Prices one directed transfer. `Sync` so the coordinator can share it
/// with worker threads if an algorithm ever prices inside a fan-out.
pub trait Transport: Send + Sync {
    /// Simulated time for `bits` to travel server → client `i`.
    fn downlink_time(&self, client: usize, bits: u64) -> f64;
    /// Simulated time for `bits` to travel client `i` → server.
    fn uplink_time(&self, client: usize, bits: u64) -> f64;
    fn name(&self) -> &'static str;
}

/// The zero-cost network: every exchange is instantaneous. Default — and
/// deliberately `0.0` (not "very fast") so `t + cost` is bitwise `t` and
/// pre-net trajectories are reproduced exactly.
pub struct IdealTransport;

impl Transport for IdealTransport {
    fn downlink_time(&self, _client: usize, _bits: u64) -> f64 {
        0.0
    }

    fn uplink_time(&self, _client: usize, _bits: u64) -> f64 {
        0.0
    }

    fn name(&self) -> &'static str {
        "ideal"
    }
}

/// One client's link: fixed for the run (bandwidth skew is a per-client
/// property; per-message jitter comes from message sizes and the latency
/// floor).
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// uplink bandwidth, bits per simulated-time unit
    pub up_bw: f64,
    /// downlink bandwidth, bits per simulated-time unit
    pub down_bw: f64,
    /// per-message latency floor, either direction
    pub latency: f64,
}

/// Per-client links drawn once from the profile's distributions at setup
/// (seeded — the same profile + seed materializes the same fleet).
pub struct SimTransport {
    links: Vec<Link>,
}

/// Floor that keeps a pathological draw from producing infinite transfer
/// times (bits / bw stays finite).
const MIN_BANDWIDTH: f64 = 1e-6;

impl SimTransport {
    pub fn draw(
        n: usize,
        up_bw: &Dist,
        down_bw: &Dist,
        latency: &Dist,
        seed: u64,
    ) -> Self {
        let links = (0..n)
            .map(|i| {
                let mut rng =
                    Rng::new(derive_seed(seed, 0x4E70_0000 + i as u64));
                Link {
                    up_bw: up_bw.sample(&mut rng).max(MIN_BANDWIDTH),
                    down_bw: down_bw.sample(&mut rng).max(MIN_BANDWIDTH),
                    latency: latency.sample(&mut rng).max(0.0),
                }
            })
            .collect();
        SimTransport { links }
    }

    /// Like [`SimTransport::draw`], but with a Gaussian-copula rank
    /// correlation `rho` between each client's *compute rate* and its
    /// bandwidth draws (`--net-compute-corr`): fast clients get fast
    /// links for `rho > 0`, slow links for `rho < 0`.
    ///
    /// Per client: its compute side enters as the latent percentile of
    /// its rate among the fleet (ties — the fast/slow speed classes —
    /// broken uniformly at random within the class), pushed through Φ⁻¹
    /// to a latent normal `z_c`; each direction's bandwidth is drawn at
    /// the quantile `Φ(ρ·z_c + √(1−ρ²)·ε)` with an independent ε per
    /// direction, so ρ = ±1 gives comonotone/antimonotone rate↔bandwidth
    /// coupling while the marginal bandwidth distributions stay exactly
    /// the configured ones ([`Dist::quantile`]). Latency stays an
    /// independent draw. `rho == 0.0` is routed to [`SimTransport::draw`]
    /// by the config layer, keeping the default bit-exact.
    pub fn draw_correlated(
        n: usize,
        up_bw: &Dist,
        down_bw: &Dist,
        latency: &Dist,
        seed: u64,
        compute_rates: &[f64],
        rho: f64,
    ) -> Self {
        assert_eq!(compute_rates.len(), n, "one compute rate per client");
        let rho = rho.clamp(-1.0, 1.0);
        let ortho = (1.0 - rho * rho).sqrt();
        // Rank statistics of the rate vector, computed once: below[i] =
        // #{j : rate_j < rate_i}, ties[i] = #{j : rate_j == rate_i}.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            compute_rates[a]
                .partial_cmp(&compute_rates[b])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut below = vec![0usize; n];
        let mut ties = vec![0usize; n];
        let mut j = 0;
        while j < n {
            let mut k = j;
            while k < n && compute_rates[order[k]] == compute_rates[order[j]] {
                k += 1;
            }
            for &idx in &order[j..k] {
                below[idx] = j;
                ties[idx] = k - j;
            }
            j = k;
        }
        let links = (0..n)
            .map(|i| {
                let mut rng =
                    Rng::new(derive_seed(seed, 0xC0_0000_0000 + i as u64));
                let u_c = (below[i] as f64 + rng.next_f64() * ties[i] as f64)
                    / n as f64;
                let z_c = normal_quantile(u_c.clamp(1e-12, 1.0 - 1e-12));
                let z_up = rho * z_c + ortho * rng.normal();
                let z_down = rho * z_c + ortho * rng.normal();
                Link {
                    up_bw: up_bw
                        .quantile(normal_cdf(z_up), &mut rng)
                        .max(MIN_BANDWIDTH),
                    down_bw: down_bw
                        .quantile(normal_cdf(z_down), &mut rng)
                        .max(MIN_BANDWIDTH),
                    latency: latency.sample(&mut rng).max(0.0),
                }
            })
            .collect();
        SimTransport { links }
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }
}

impl Transport for SimTransport {
    fn downlink_time(&self, client: usize, bits: u64) -> f64 {
        let l = &self.links[client];
        l.latency + bits as f64 / l.down_bw
    }

    fn uplink_time(&self, client: usize, bits: u64) -> f64 {
        let l = &self.links[client];
        l.latency + bits as f64 / l.up_bw
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_exactly_zero() {
        let t = IdealTransport;
        assert_eq!(t.uplink_time(3, u64::MAX).to_bits(), 0f64.to_bits());
        assert_eq!(t.downlink_time(0, 0).to_bits(), 0f64.to_bits());
    }

    #[test]
    fn sim_prices_latency_plus_serialization() {
        let t = SimTransport {
            links: vec![Link { up_bw: 100.0, down_bw: 400.0, latency: 0.5 }],
        };
        assert!((t.uplink_time(0, 1000) - 10.5).abs() < 1e-12);
        assert!((t.downlink_time(0, 1000) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn draw_is_seed_deterministic_and_per_client() {
        let up = Dist::Pareto { scale: 1e4, shape: 1.5 };
        let down = Dist::LogNormal { median: 1e6, sigma: 0.5 };
        let lat = Dist::Const(0.1);
        let a = SimTransport::draw(16, &up, &down, &lat, 7);
        let b = SimTransport::draw(16, &up, &down, &lat, 7);
        for (x, y) in a.links().iter().zip(b.links()) {
            assert_eq!(x.up_bw.to_bits(), y.up_bw.to_bits());
            assert_eq!(x.down_bw.to_bits(), y.down_bw.to_bits());
            assert_eq!(x.latency, y.latency);
        }
        // Different clients get independent draws (bandwidth skew).
        let distinct: std::collections::BTreeSet<u64> =
            a.links().iter().map(|l| l.up_bw.to_bits()).collect();
        assert!(distinct.len() > 8, "per-client draws should differ");
        let c = SimTransport::draw(16, &up, &down, &lat, 8);
        assert_ne!(
            a.links()[0].up_bw.to_bits(),
            c.links()[0].up_bw.to_bits()
        );
    }

    /// Median of a sample (test helper — heavy-tailed draws make means
    /// unstable, medians not).
    fn median(mut v: Vec<f64>) -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    /// Two-class rate vector mirroring the fast/slow clock fleet.
    fn rates_two_class(n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| if i < n / 2 { 0.125 } else { 0.5 })
            .collect()
    }

    #[test]
    fn correlated_draw_couples_rate_and_bandwidth() {
        let n = 400;
        let rates = rates_two_class(n);
        let up = Dist::Pareto { scale: 5e4, shape: 1.5 };
        let down = Dist::LogNormal { median: 2e5, sigma: 1.0 };
        let lat = Dist::Const(0.2);
        let t = SimTransport::draw_correlated(n, &up, &down, &lat, 3, &rates, 0.9);
        let slow_up: Vec<f64> =
            (0..n / 2).map(|i| t.links()[i].up_bw).collect();
        let fast_up: Vec<f64> =
            (n / 2..n).map(|i| t.links()[i].up_bw).collect();
        assert!(
            median(fast_up.clone()) > median(slow_up.clone()),
            "rho=0.9: fast clients should get faster uplinks"
        );
        let slow_down: Vec<f64> =
            (0..n / 2).map(|i| t.links()[i].down_bw).collect();
        let fast_down: Vec<f64> =
            (n / 2..n).map(|i| t.links()[i].down_bw).collect();
        assert!(median(fast_down) > median(slow_down));
        // Negative correlation flips the coupling.
        let t_neg =
            SimTransport::draw_correlated(n, &up, &down, &lat, 3, &rates, -0.9);
        let slow_up_neg: Vec<f64> =
            (0..n / 2).map(|i| t_neg.links()[i].up_bw).collect();
        let fast_up_neg: Vec<f64> =
            (n / 2..n).map(|i| t_neg.links()[i].up_bw).collect();
        assert!(
            median(fast_up_neg) < median(slow_up_neg),
            "rho=-0.9: fast clients should get slower uplinks"
        );
    }

    #[test]
    fn correlated_draw_preserves_marginals() {
        // The copula reshuffles *which client* gets which link, not the
        // fleet-wide link distribution: medians with and without the
        // correlation must agree closely.
        let n = 2000;
        let rates = rates_two_class(n);
        let up = Dist::LogNormal { median: 1e6, sigma: 0.5 };
        let down = Dist::LogNormal { median: 4e6, sigma: 0.5 };
        let lat = Dist::Const(0.05);
        let plain = SimTransport::draw(n, &up, &down, &lat, 7);
        let corr =
            SimTransport::draw_correlated(n, &up, &down, &lat, 7, &rates, 0.8);
        let med_plain = median(plain.links().iter().map(|l| l.up_bw).collect());
        let med_corr = median(corr.links().iter().map(|l| l.up_bw).collect());
        assert!(
            (med_plain / med_corr - 1.0).abs() < 0.1,
            "marginal drifted: {med_plain} vs {med_corr}"
        );
    }

    #[test]
    fn correlated_draw_is_seed_deterministic() {
        let n = 32;
        let rates = rates_two_class(n);
        let up = Dist::Pareto { scale: 1e4, shape: 1.5 };
        let down = Dist::LogNormal { median: 1e6, sigma: 0.5 };
        let lat = Dist::Const(0.1);
        let a = SimTransport::draw_correlated(n, &up, &down, &lat, 5, &rates, 0.6);
        let b = SimTransport::draw_correlated(n, &up, &down, &lat, 5, &rates, 0.6);
        for (x, y) in a.links().iter().zip(b.links()) {
            assert_eq!(x.up_bw.to_bits(), y.up_bw.to_bits());
            assert_eq!(x.down_bw.to_bits(), y.down_bw.to_bits());
        }
        let c = SimTransport::draw_correlated(n, &up, &down, &lat, 6, &rates, 0.6);
        assert_ne!(a.links()[0].up_bw.to_bits(), c.links()[0].up_bw.to_bits());
    }

    #[test]
    fn zero_bandwidth_draw_is_floored() {
        let t = SimTransport::draw(
            1,
            &Dist::Const(0.0),
            &Dist::Const(0.0),
            &Dist::Const(0.0),
            1,
        );
        assert!(t.uplink_time(0, 1_000_000).is_finite());
    }
}

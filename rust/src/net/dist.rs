//! Scalar distributions for per-client link parameters (bandwidth,
//! latency): constant, lognormal, Pareto, and two-component mixtures of
//! those. Every draw is an explicit-seed `Rng` call, so a network profile
//! materializes identically on every run.
//!
//! CLI grammar (no commas — comma separates *lists* of profiles in the
//! sweep runner, so component separators are `:` and `/`, mixtures `+`):
//!
//! ```text
//! const:V               always V
//! lognormal:MEDIAN/SIGMA  MEDIAN * exp(SIGMA * N(0,1))
//! pareto:SCALE/SHAPE    SCALE / U^(1/SHAPE)   (heavy tail for SHAPE <~ 2)
//! mix:P+DIST_A+DIST_B   DIST_A with probability P, else DIST_B
//! ```
//!
//! Inside a mixture, write exponents without a sign (`1e5`, not `1e+5`) —
//! `+` is the component separator.

use crate::util::rng::Rng;
use crate::util::stats::normal_quantile;

/// A seeded scalar distribution.
#[derive(Clone, Debug, PartialEq)]
pub enum Dist {
    /// degenerate point mass
    Const(f64),
    /// `median * exp(sigma * N(0,1))` — the classic bandwidth-skew model
    LogNormal { median: f64, sigma: f64 },
    /// `scale / U^(1/shape)` — heavy-tailed (infinite variance for
    /// shape <= 2), the straggler-link model
    Pareto { scale: f64, shape: f64 },
    /// draw from `a` with probability `p`, else from `b`
    Mix { p: f64, a: Box<Dist>, b: Box<Dist> },
}

impl Dist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Dist::Const(v) => *v,
            Dist::LogNormal { median, sigma } => {
                median * (sigma * rng.normal()).exp()
            }
            Dist::Pareto { scale, shape } => {
                // U in (0, 1]: 1 - next_f64() avoids U = 0.
                let u = 1.0 - rng.next_f64();
                scale / u.powf(1.0 / shape)
            }
            Dist::Mix { p, a, b } => {
                if rng.next_f64() < *p {
                    a.sample(rng)
                } else {
                    b.sample(rng)
                }
            }
        }
    }

    /// Inverse-CDF draw at quantile `u ∈ (0, 1)` — the Gaussian-copula
    /// hook (`--net-compute-corr`): [`crate::net::SimTransport`] maps a
    /// correlated normal through Φ and asks each marginal for that
    /// quantile, so the marginal distributions stay exactly the
    /// configured ones. A mixture picks its component from `rng` (the
    /// copula correlates *within* the chosen component) and applies the
    /// component's quantile.
    pub fn quantile(&self, u: f64, rng: &mut Rng) -> f64 {
        let u = u.clamp(1e-12, 1.0 - 1e-12);
        match self {
            Dist::Const(v) => *v,
            Dist::LogNormal { median, sigma } => {
                median * (sigma * normal_quantile(u)).exp()
            }
            Dist::Pareto { scale, shape } => {
                scale / (1.0 - u).powf(1.0 / shape)
            }
            Dist::Mix { p, a, b } => {
                if rng.next_f64() < *p {
                    a.quantile(u, rng)
                } else {
                    b.quantile(u, rng)
                }
            }
        }
    }

    /// Parse the CLI grammar (module docs). Mixture components must be
    /// non-mixture distributions.
    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(rest) = s.strip_prefix("mix:") {
            let parts: Vec<&str> = rest.split('+').collect();
            if parts.len() != 3 {
                return Err(format!(
                    "mixture must be mix:P+DIST_A+DIST_B, got {s:?} \
                     (write exponents without a sign: 1e5, not 1e+5)"
                ));
            }
            let p: f64 = parts[0]
                .parse()
                .map_err(|_| format!("bad mixture weight {:?}", parts[0]))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("mixture weight {p} outside [0, 1]"));
            }
            let a = Dist::parse_simple(parts[1])?;
            let b = Dist::parse_simple(parts[2])?;
            return Ok(Dist::Mix { p, a: Box::new(a), b: Box::new(b) });
        }
        Dist::parse_simple(s)
    }

    fn parse_simple(s: &str) -> Result<Self, String> {
        let num = |t: &str| -> Result<f64, String> {
            t.parse().map_err(|_| format!("bad number {t:?} in dist {s:?}"))
        };
        if let Some(rest) = s.strip_prefix("const:") {
            return Ok(Dist::Const(num(rest)?));
        }
        if let Some(rest) = s.strip_prefix("lognormal:") {
            let (m, sg) = rest
                .split_once('/')
                .ok_or_else(|| format!("lognormal:MEDIAN/SIGMA, got {s:?}"))?;
            return Ok(Dist::LogNormal { median: num(m)?, sigma: num(sg)? });
        }
        if let Some(rest) = s.strip_prefix("pareto:") {
            let (sc, sh) = rest
                .split_once('/')
                .ok_or_else(|| format!("pareto:SCALE/SHAPE, got {s:?}"))?;
            return Ok(Dist::Pareto { scale: num(sc)?, shape: num(sh)? });
        }
        Err(format!(
            "unknown distribution {s:?} \
             (const:V | lognormal:M/S | pareto:SC/SH | mix:P+A+B)"
        ))
    }

    /// All parameters positive / well-formed, and every possible draw > 0
    /// when `strictly_positive` (bandwidths must be; latencies may be 0).
    pub fn validate(&self, strictly_positive: bool) -> Result<(), String> {
        match self {
            Dist::Const(v) => {
                if *v < 0.0 || (strictly_positive && *v <= 0.0) {
                    return Err(format!("const value {v} must be positive"));
                }
            }
            Dist::LogNormal { median, sigma } => {
                if *median <= 0.0 {
                    return Err(format!("lognormal median {median} must be > 0"));
                }
                if *sigma < 0.0 {
                    return Err(format!("lognormal sigma {sigma} must be >= 0"));
                }
            }
            Dist::Pareto { scale, shape } => {
                if *scale <= 0.0 || *shape <= 0.0 {
                    return Err(format!(
                        "pareto scale/shape ({scale}, {shape}) must be > 0"
                    ));
                }
            }
            Dist::Mix { p, a, b } => {
                if !(0.0..=1.0).contains(p) {
                    return Err(format!("mixture weight {p} outside [0, 1]"));
                }
                a.validate(strictly_positive)?;
                b.validate(strictly_positive)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_simple() {
        assert_eq!(Dist::parse("const:1e5").unwrap(), Dist::Const(1e5));
        assert_eq!(
            Dist::parse("lognormal:2e5/0.5").unwrap(),
            Dist::LogNormal { median: 2e5, sigma: 0.5 }
        );
        assert_eq!(
            Dist::parse("pareto:5e4/1.5").unwrap(),
            Dist::Pareto { scale: 5e4, shape: 1.5 }
        );
        assert!(Dist::parse("triangular:1/2").is_err());
        assert!(Dist::parse("lognormal:1e5").is_err());
    }

    #[test]
    fn parse_mixture() {
        let d = Dist::parse("mix:0.3+const:1e5+const:1e7").unwrap();
        match d {
            Dist::Mix { p, a, b } => {
                assert_eq!(p, 0.3);
                assert_eq!(*a, Dist::Const(1e5));
                assert_eq!(*b, Dist::Const(1e7));
            }
            other => panic!("expected mixture, got {other:?}"),
        }
        assert!(Dist::parse("mix:0.3+const:1").is_err());
        assert!(Dist::parse("mix:1.5+const:1+const:2").is_err());
    }

    #[test]
    fn const_is_exact_and_deterministic() {
        let d = Dist::Const(7.25);
        let mut r = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(d.sample(&mut r), 7.25);
        }
    }

    #[test]
    fn lognormal_median_is_median() {
        let d = Dist::LogNormal { median: 100.0, sigma: 1.0 };
        let mut r = Rng::new(2);
        let n = 20_000;
        let below = (0..n).filter(|_| d.sample(&mut r) < 100.0).count();
        let frac = below as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "P[X < median] = {frac}");
    }

    #[test]
    fn pareto_bounded_below_by_scale_and_heavy_tailed() {
        let d = Dist::Pareto { scale: 10.0, shape: 1.5 };
        let mut r = Rng::new(3);
        let draws: Vec<f64> = (0..20_000).map(|_| d.sample(&mut r)).collect();
        assert!(draws.iter().all(|&x| x >= 10.0));
        // P[X > 4*scale] = 4^{-shape} = 0.125 for shape = 1.5.
        let tail = draws.iter().filter(|&&x| x > 40.0).count() as f64
            / draws.len() as f64;
        assert!((tail - 0.125).abs() < 0.02, "tail mass {tail}");
    }

    #[test]
    fn mixture_weights_respected() {
        let d = Dist::parse("mix:0.25+const:1+const:2").unwrap();
        let mut r = Rng::new(4);
        let n = 20_000;
        let low = (0..n).filter(|_| d.sample(&mut r) == 1.0).count();
        let frac = low as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "component-A mass {frac}");
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let d = Dist::parse("mix:0.5+lognormal:1e5/0.7+pareto:2e4/1.2").unwrap();
        let a: Vec<f64> = {
            let mut r = Rng::new(9);
            (0..50).map(|_| d.sample(&mut r)).collect()
        };
        let b: Vec<f64> = {
            let mut r = Rng::new(9);
            (0..50).map(|_| d.sample(&mut r)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn quantile_is_monotone_and_matches_known_points() {
        let mut r = Rng::new(1);
        assert_eq!(Dist::Const(7.0).quantile(0.9, &mut r), 7.0);
        // Lognormal: the median is the 0.5 quantile by definition.
        let ln = Dist::LogNormal { median: 100.0, sigma: 0.7 };
        assert!((ln.quantile(0.5, &mut r) - 100.0).abs() < 1e-6);
        // Pareto: P[X <= scale / (1-u)^(1/shape)] = u exactly.
        let pa = Dist::Pareto { scale: 10.0, shape: 2.0 };
        assert!((pa.quantile(0.75, &mut r) - 20.0).abs() < 1e-9);
        for d in [ln, pa] {
            let mut prev = f64::NEG_INFINITY;
            for k in 1..20 {
                let q = d.quantile(k as f64 / 20.0, &mut r);
                assert!(q >= prev, "quantile not monotone");
                prev = q;
            }
        }
    }

    #[test]
    fn quantile_preserves_marginal_distribution() {
        // Pushing U(0,1) through the quantile must reproduce the same
        // distribution as direct sampling (compare tail masses).
        let d = Dist::Pareto { scale: 10.0, shape: 1.5 };
        let mut r = Rng::new(8);
        let n = 20_000;
        let tail_direct = (0..n)
            .filter(|_| d.sample(&mut r) > 40.0)
            .count() as f64
            / n as f64;
        let mut r2 = Rng::new(9);
        let tail_quantile = (0..n)
            .filter(|_| {
                let u = r2.next_f64();
                d.quantile(u, &mut r2) > 40.0
            })
            .count() as f64
            / n as f64;
        assert!(
            (tail_direct - tail_quantile).abs() < 0.02,
            "direct {tail_direct} vs quantile {tail_quantile}"
        );
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(Dist::Const(0.0).validate(true).is_err());
        assert!(Dist::Const(0.0).validate(false).is_ok());
        assert!(Dist::Const(-1.0).validate(false).is_err());
        assert!(Dist::LogNormal { median: 0.0, sigma: 1.0 }.validate(true).is_err());
        assert!(Dist::Pareto { scale: 1.0, shape: 0.0 }.validate(true).is_err());
        assert!(Dist::parse("const:5").unwrap().validate(true).is_ok());
    }
}

//! Simulated transport & client-availability subsystem.
//!
//! The paper's headline claim is *communication* efficiency, yet a
//! timing-only simulation prices every exchange at zero and makes the
//! compressed and uncompressed protocols indistinguishable on the
//! sim-time axis. This subsystem closes that gap:
//!
//! - [`transport::Transport`] converts each exchange's **actual encoded
//!   bit count** (what the quantizer produced, not a nominal d·32) into
//!   simulated transmission time, per client and per direction;
//! - [`dist::Dist`] draws per-client uplink/downlink bandwidth and latency
//!   from constant / lognormal / Pareto mixtures (bandwidth skew,
//!   straggler links);
//! - [`availability::ClientAvailability`] gates sampling with a
//!   dropout/rejoin churn process or duty-cycle windows.
//!
//! Everything is seeded and deterministic, and the default
//! [`NetProfile::Ideal`] + [`AvailabilityKind::Always`] combination is a
//! **bit-exact no-op**: costs are exactly `0.0`, sampling uses the exact
//! pre-net RNG path, so every existing trajectory is reproduced bit for
//! bit (`rust/tests/net_parity.rs`).
//!
//! CLI surface (the `run`, `figures` and `sweep` subcommands):
//!
//! ```text
//! --net ideal|broadband|mobile|DIST   preset or symmetric bandwidth dist
//! --net-up DIST / --net-down DIST     per-direction bandwidth (bits/unit)
//! --net-latency DIST                  per-message latency floor
//! --churn MEAN_UP/MEAN_DOWN           exponential dropout/rejoin churn
//! --duty PERIOD/ON_FRACTION           periodic availability windows
//! --net-compute-corr RHO              Gaussian-copula rank correlation
//!                                     between a client's compute rate and
//!                                     its bandwidth draws (0.0 = today's
//!                                     independent draws, bit-exact)
//! ```
//!
//! Distances are simulated-time units (the unit of `swt`/`sit` and the
//! Exp(λ) step times); bandwidths are bits per unit. For scale: the mlp's
//! fp32 model is ~0.8 Mbit and its 10-bit lattice encoding ~0.33 Mbit, so
//! a 1e5 bits/unit uplink prices them at ~8 vs ~3.3 units against the
//! default swt = 10.

pub mod availability;
pub mod dist;
pub mod transport;

pub use availability::{AvailabilityKind, ClientAvailability};
pub use dist::Dist;
pub use transport::{IdealTransport, Link, SimTransport, Transport};

use crate::util::cli::Args;
use crate::util::rng::derive_seed;

/// Link-pricing profile: how per-client bandwidths/latencies materialize.
#[derive(Clone, Debug, PartialEq)]
pub enum NetProfile {
    /// zero-cost network (default; bit-exact no-op on trajectories)
    Ideal,
    /// per-client links drawn from the given distributions at setup
    Custom { up_bw: Dist, down_bw: Dist, latency: Dist },
}

impl NetProfile {
    /// Named presets (documented units: bits per simulated-time unit).
    ///
    /// - `broadband`: mild lognormal skew, fast symmetric-ish links —
    ///   communication is noticeable but rarely dominates.
    /// - `mobile`: Pareto uplink (heavy straggler tail) + slower, skewed
    ///   downlink + higher latency — uplink cost dominates rounds, the
    ///   regime where compressed and uncompressed protocols reorder.
    pub fn preset(name: &str) -> Option<NetProfile> {
        match name {
            "ideal" => Some(NetProfile::Ideal),
            "broadband" => Some(NetProfile::Custom {
                up_bw: Dist::LogNormal { median: 1e6, sigma: 0.5 },
                down_bw: Dist::LogNormal { median: 4e6, sigma: 0.5 },
                latency: Dist::Const(0.05),
            }),
            "mobile" => Some(NetProfile::Custom {
                up_bw: Dist::Pareto { scale: 5e4, shape: 1.5 },
                down_bw: Dist::LogNormal { median: 2e5, sigma: 1.0 },
                latency: Dist::Const(0.2),
            }),
            _ => None,
        }
    }

    pub fn is_ideal(&self) -> bool {
        *self == NetProfile::Ideal
    }
}

/// Everything the coordinator needs to materialize the network: a link
/// profile plus an availability process. Defaults to the bit-exact no-op.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkConfig {
    pub profile: NetProfile,
    pub availability: AvailabilityKind,
    /// Gaussian-copula rank correlation between a client's compute rate
    /// and its bandwidth draws (`--net-compute-corr`, in [-1, 1]). The
    /// default 0.0 keeps the legacy independent per-client draws —
    /// bit-exact ([`SimTransport::draw`]); any other value routes through
    /// [`SimTransport::draw_correlated`]. Ignored by the `Ideal` profile
    /// (no bandwidth is drawn).
    pub compute_corr: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            profile: NetProfile::Ideal,
            availability: AvailabilityKind::Always,
            compute_corr: 0.0,
        }
    }
}

impl NetworkConfig {
    /// CLI keys this subsystem owns (merged into the run/sweep key sets).
    pub const CLI_KEYS: &'static [&'static str] = &[
        "net", "net-up", "net-down", "net-latency", "churn", "duty",
        "net-compute-corr",
    ];

    /// Parse `--net NAME|DIST`, one NetworkConfig per string — also the
    /// grammar of each entry of the sweep runner's `--nets` list. A bare
    /// dist applies symmetrically with zero latency.
    pub fn profile_from_str(s: &str) -> Result<NetProfile, String> {
        if let Some(p) = NetProfile::preset(s) {
            return Ok(p);
        }
        let d = Dist::parse(s).map_err(|e| {
            format!("--net {s:?}: not a preset (ideal|broadband|mobile) and {e}")
        })?;
        Ok(NetProfile::Custom {
            up_bw: d.clone(),
            down_bw: d,
            latency: Dist::Const(0.0),
        })
    }

    /// Parse `A/B` pairs (`--churn 200/50`, `--duty 100/0.5`).
    fn pair(key: &str, s: &str) -> Result<(f64, f64), String> {
        let (a, b) = s
            .split_once('/')
            .ok_or_else(|| format!("--{key} expects A/B, got {s:?}"))?;
        let pa = a.parse().map_err(|_| format!("--{key}: bad number {a:?}"))?;
        let pb = b.parse().map_err(|_| format!("--{key}: bad number {b:?}"))?;
        Ok((pa, pb))
    }

    /// Build from CLI args (run/figures/sweep subcommands). Fine-grained
    /// `--net-up/--net-down/--net-latency` override preset components.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        // Every network key takes a value; a bare `--churn` would
        // otherwise parse as a flag, pass the typo guard, and silently
        // leave the default Ideal/Always network in place.
        for key in Self::CLI_KEYS {
            if args.flag(key) {
                return Err(format!("--{key} requires a value"));
            }
        }
        let mut cfg = NetworkConfig::default();
        if let Some(net) = args.get("net") {
            cfg.profile = Self::profile_from_str(net)?;
        }
        let overrides = [
            args.get("net-up"),
            args.get("net-down"),
            args.get("net-latency"),
        ];
        if overrides.iter().any(Option::is_some) {
            // Start from the current profile's components (Ideal resolves
            // to unlimited bandwidth / zero latency) and patch.
            let (mut up, mut down, mut lat) = match cfg.profile {
                NetProfile::Ideal => (
                    Dist::Const(f64::INFINITY),
                    Dist::Const(f64::INFINITY),
                    Dist::Const(0.0),
                ),
                NetProfile::Custom { up_bw, down_bw, latency } => {
                    (up_bw, down_bw, latency)
                }
            };
            if let Some(s) = overrides[0] {
                up = Dist::parse(s)?;
            }
            if let Some(s) = overrides[1] {
                down = Dist::parse(s)?;
            }
            if let Some(s) = overrides[2] {
                lat = Dist::parse(s)?;
            }
            cfg.profile =
                NetProfile::Custom { up_bw: up, down_bw: down, latency: lat };
        }
        if let Some(s) = args.get("churn") {
            let (mean_up, mean_down) = Self::pair("churn", s)?;
            cfg.availability = AvailabilityKind::Churn { mean_up, mean_down };
        }
        if let Some(s) = args.get("duty") {
            if args.get("churn").is_some() {
                return Err("--churn and --duty are mutually exclusive".into());
            }
            let (period, on_fraction) = Self::pair("duty", s)?;
            cfg.availability =
                AvailabilityKind::DutyCycle { period, on_fraction };
        }
        if let Some(s) = args.get("net-compute-corr") {
            cfg.compute_corr = s
                .parse()
                .map_err(|_| format!("--net-compute-corr: bad number {s:?}"))?;
            // The ideal profile draws no bandwidth, so a correlation
            // would be a silent no-op — reject the footgun at the CLI.
            // (Programmatic configs — e.g. a sweep's ideal arm with a
            // fleet-wide rho — stay permissive; the label says ideal.)
            if cfg.compute_corr != 0.0 && cfg.profile.is_ideal() {
                return Err(format!(
                    "--net-compute-corr {} has no effect on the ideal \
                     profile; pick a priced --net first",
                    cfg.compute_corr
                ));
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if let NetProfile::Custom { up_bw, down_bw, latency } = &self.profile {
            up_bw.validate(true)?;
            down_bw.validate(true)?;
            latency.validate(false)?;
        }
        if !(-1.0..=1.0).contains(&self.compute_corr) {
            return Err(format!(
                "--net-compute-corr {} outside [-1, 1]",
                self.compute_corr
            ));
        }
        self.availability.validate()
    }

    /// `""` when always-on, else `"+churn"`/`"+duty"` — appended to
    /// profile tokens in labels so gated availability is never invisible.
    pub fn availability_suffix(&self) -> String {
        match &self.availability {
            AvailabilityKind::Always => String::new(),
            a => format!("+{}", a.name()),
        }
    }

    /// Short label for figure arms / sweep rows.
    pub fn label(&self) -> String {
        let p = match &self.profile {
            NetProfile::Ideal => "ideal",
            NetProfile::Custom { .. } => "custom",
        };
        format!("{p}{}", self.availability_suffix())
    }

    /// Materialize the per-client links. Consumes no shared RNG state, so
    /// building the network never perturbs the rest of the experiment.
    /// `compute_rates` (one clock rate per client) feeds the optional
    /// compute↔bandwidth copula; with the default `compute_corr == 0.0`
    /// the legacy independent-draw path runs bit-exactly.
    pub fn build_transport(
        &self,
        n: usize,
        seed: u64,
        compute_rates: &[f64],
    ) -> Box<dyn Transport> {
        match &self.profile {
            NetProfile::Ideal => Box::new(IdealTransport),
            NetProfile::Custom { up_bw, down_bw, latency } => {
                let seed = derive_seed(seed, 0x7A45);
                if self.compute_corr == 0.0 {
                    Box::new(SimTransport::draw(n, up_bw, down_bw, latency, seed))
                } else {
                    Box::new(SimTransport::draw_correlated(
                        n,
                        up_bw,
                        down_bw,
                        latency,
                        seed,
                        compute_rates,
                        self.compute_corr,
                    ))
                }
            }
        }
    }

    /// Materialize the availability process (seeded independently of the
    /// transport draws). `event_driven` picks the query engine — the
    /// O(s log n) event queue + Fenwick index or the legacy O(n) walk —
    /// without touching the seeded process itself (the two are
    /// bit-identical on every query; rust/tests/scale_parity.rs).
    pub fn build_availability(
        &self,
        n: usize,
        seed: u64,
        event_driven: bool,
    ) -> ClientAvailability {
        ClientAvailability::with_mode(
            self.availability.clone(),
            n,
            derive_seed(seed, 0xA4A1),
            event_driven,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn default_is_ideal_always() {
        let c = NetworkConfig::default();
        assert!(c.profile.is_ideal());
        assert_eq!(c.availability, AvailabilityKind::Always);
        assert!(c.validate().is_ok());
        assert_eq!(c.label(), "ideal");
    }

    #[test]
    fn presets_parse_and_validate() {
        for name in ["ideal", "broadband", "mobile"] {
            let p = NetProfile::preset(name).unwrap();
            let c = NetworkConfig { profile: p, ..Default::default() };
            assert!(c.validate().is_ok(), "{name}");
        }
        assert!(NetProfile::preset("dialup").is_none());
    }

    #[test]
    fn from_args_full_surface() {
        let a = cli::parse(&sv(&[
            "run", "--net", "mobile", "--net-latency", "const:0.5", "--churn",
            "200/50",
        ]));
        let c = NetworkConfig::from_args(&a).unwrap();
        match &c.profile {
            NetProfile::Custom { latency, .. } => {
                assert_eq!(*latency, Dist::Const(0.5));
            }
            other => panic!("expected custom, got {other:?}"),
        }
        assert_eq!(
            c.availability,
            AvailabilityKind::Churn { mean_up: 200.0, mean_down: 50.0 }
        );
        assert_eq!(c.label(), "custom+churn");
    }

    #[test]
    fn from_args_bare_dist_is_symmetric() {
        let a = cli::parse(&sv(&["run", "--net", "const:1e5"]));
        let c = NetworkConfig::from_args(&a).unwrap();
        match &c.profile {
            NetProfile::Custom { up_bw, down_bw, latency } => {
                assert_eq!(*up_bw, Dist::Const(1e5));
                assert_eq!(*down_bw, Dist::Const(1e5));
                assert_eq!(*latency, Dist::Const(0.0));
            }
            other => panic!("expected custom, got {other:?}"),
        }
    }

    #[test]
    fn from_args_rejects_conflicts_and_garbage() {
        let a = cli::parse(&sv(&["run", "--churn", "10/5", "--duty", "10/0.5"]));
        assert!(NetworkConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--net", "warp-drive"]));
        assert!(NetworkConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--churn", "10,5"]));
        assert!(NetworkConfig::from_args(&a).is_err());
        // A forgotten value must error, not silently fall back to Ideal.
        let a = cli::parse(&sv(&["run", "--churn"]));
        assert!(NetworkConfig::from_args(&a).is_err());
    }

    #[test]
    fn ideal_transport_from_config_prices_zero() {
        let c = NetworkConfig::default();
        let t = c.build_transport(4, 1, &[0.5; 4]);
        assert_eq!(t.uplink_time(0, 1 << 30).to_bits(), 0f64.to_bits());
    }

    #[test]
    fn custom_transport_prices_positive() {
        let c = NetworkConfig {
            profile: NetProfile::preset("mobile").unwrap(),
            ..Default::default()
        };
        let t = c.build_transport(4, 1, &[0.5; 4]);
        assert!(t.uplink_time(0, 1_000_000) > 0.0);
        assert!(t.downlink_time(3, 1_000_000) > 0.0);
    }

    #[test]
    fn compute_corr_parses_validates_and_switches_draw_path() {
        let a = cli::parse(&sv(&[
            "run", "--net", "mobile", "--net-compute-corr", "0.8",
        ]));
        let c = NetworkConfig::from_args(&a).unwrap();
        assert_eq!(c.compute_corr, 0.8);
        // Out-of-range, garbage, and the ideal-profile no-op footgun are
        // all rejected at the CLI.
        let a = cli::parse(&sv(&[
            "run", "--net", "mobile", "--net-compute-corr", "1.5",
        ]));
        assert!(NetworkConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&[
            "run", "--net", "mobile", "--net-compute-corr", "lots",
        ]));
        assert!(NetworkConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--net-compute-corr", "0.5"]));
        assert!(NetworkConfig::from_args(&a).is_err(), "ideal + rho");
        // Zero correlation routes through the exact legacy draw: same
        // seed derivation, same independent per-client streams.
        let base = NetworkConfig {
            profile: NetProfile::preset("mobile").unwrap(),
            ..Default::default()
        };
        let rates: Vec<f64> =
            (0..16).map(|i| if i < 8 { 0.125 } else { 0.5 }).collect();
        let (up, down, lat) = match &base.profile {
            NetProfile::Custom { up_bw, down_bw, latency } => {
                (up_bw.clone(), down_bw.clone(), latency.clone())
            }
            NetProfile::Ideal => unreachable!("mobile is custom"),
        };
        let legacy =
            SimTransport::draw(16, &up, &down, &lat, derive_seed(9, 0x7A45));
        let zero = base.build_transport(16, 9, &rates);
        let corr = NetworkConfig { compute_corr: 0.9, ..base }
            .build_transport(16, 9, &rates);
        let mut corr_differs = false;
        for i in 0..16 {
            let bits = 1_000_000;
            assert_eq!(
                legacy.uplink_time(i, bits).to_bits(),
                zero.uplink_time(i, bits).to_bits(),
                "client {i}: rho=0 must be the legacy draw"
            );
            if legacy.uplink_time(i, bits).to_bits()
                != corr.uplink_time(i, bits).to_bits()
            {
                corr_differs = true;
            }
        }
        assert!(corr_differs, "rho=0.9 must change the link draw");
    }
}

//! Communication compression (paper §2.2 "Fully-Quantized Communication").
//!
//! Two families, matching the paper's comparison (Figures 5/16):
//!
//! - [`lattice::LatticeQuantizer`] — the position-aware lattice scheme of
//!   Davies et al. [7] as the paper instantiates it: a seeded random
//!   rotation (sign flip ∘ Hadamard) followed by per-coordinate modular
//!   b-bit stochastic quantization on a grid of spacing γ. `Enc(x)` does
//!   not depend on the decoder; `Dec(key, Enc(x))` resolves the modular
//!   wraparound *toward the decoder's key*, so the error depends only on
//!   γ — and correct decoding needs only that x and key are close
//!   (Lemma 3.1's "decoding key" semantics). This is why QuAFL can always
//!   send compressed *models* rather than updates.
//! - [`qsgd::QsgdQuantizer`] — the standard norm-scaled stochastic
//!   quantizer [1]; its error is proportional to ‖x‖, the property the
//!   paper shows is problematic for model transmission.
//!
//! [`identity::IdentityQuantizer`] (32-bit passthrough) completes the grid
//! for "no quantization" arms of the experiments.

pub mod identity;
pub mod lattice;
pub mod qsgd;

pub use identity::IdentityQuantizer;
pub use lattice::{LatticeQuantizer, lattice_gamma_for};
pub use qsgd::QsgdQuantizer;

/// An encoded vector in flight between server and client.
#[derive(Clone, Debug)]
pub struct QuantMessage {
    /// packed payload
    pub payload: Vec<u8>,
    /// exact number of meaningful bits in `payload` plus side info
    /// (seed/γ/norm headers) — this is what the bit-accounting reports
    pub bits: usize,
    /// original (unpadded) dimension
    pub dim: usize,
    /// shared-randomness seed for the rotation
    pub seed: u64,
}

/// Server↔client codec. `encode` must not depend on the decoder's state;
/// `decode` receives the decoder's local model as `key` (position-aware
/// schemes use it, oblivious schemes ignore it).
pub trait Quantizer: Send + Sync {
    fn encode(&self, x: &[f32], seed: u64) -> QuantMessage;
    fn decode(&self, msg: &QuantMessage, key: &[f32]) -> Vec<f32>;
    fn name(&self) -> &'static str;
    /// Nominal bits per coordinate (for reporting; exact counts are in the
    /// messages themselves).
    fn bits_per_coord(&self) -> f64;
    /// Exact wire size of `encode` for a `dim`-vector, *before* the
    /// payload exists. Every scheme's size is a deterministic function of
    /// the dimension (property-tested equal to `encode(..).bits` in
    /// rust/tests/net_parity.rs), which lets the [`crate::net`] transport
    /// schedule a transfer's arrival ahead of materializing it (FedBuff's
    /// event queue needs this).
    fn encoded_bits(&self, dim: usize) -> usize;
}

/// Convenience: encode then decode (what one directed transfer does).
pub fn roundtrip(q: &dyn Quantizer, x: &[f32], key: &[f32], seed: u64) -> (Vec<f32>, usize) {
    let msg = q.encode(x, seed);
    let bits = msg.bits;
    (q.decode(&msg, key), bits)
}

/// Wire size of the integrity frame header the fault subsystem prepends
/// to every quantized payload when chaos is armed (`crate::fault`): a
/// 32-bit [`frame_checksum`] over the payload bytes. The header exists
/// only on faulted runs — [`Quantizer::encoded_bits`] and the default
/// bit accounting are untouched, preserving the `--faults off` bit-exact
/// contract (rust/tests/fault_parity.rs).
pub const FRAME_HEADER_BITS: usize = 32;

/// 32-bit FNV-1a over the payload bytes — the frame header's integrity
/// check. Each step XORs one byte into the state and multiplies by an
/// odd prime; both are bijections on u32, so two payloads differing in
/// exactly one byte (any single-bit flip) always hash differently —
/// the fault layer's in-flight corruption is detected deterministically.
pub fn frame_checksum(payload: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in payload {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32 * scale).collect()
    }

    /// All quantizers agree on the trait contract: output dim == input dim,
    /// bits accounted > 0, decode is deterministic given the message.
    #[test]
    fn trait_contract_all_quantizers() {
        let qs: Vec<Box<dyn Quantizer>> = vec![
            Box::new(LatticeQuantizer::new(10, 0.05)),
            Box::new(QsgdQuantizer::new(10)),
            Box::new(IdentityQuantizer),
        ];
        let x = randvec(301, 1, 1.0);
        let key = x.iter().map(|v| v + 0.01).collect::<Vec<_>>();
        for q in &qs {
            let msg = q.encode(&x, 42);
            assert_eq!(msg.dim, x.len(), "{}", q.name());
            assert!(msg.bits > 0);
            assert_eq!(
                msg.bits,
                q.encoded_bits(x.len()),
                "{}: analytic size must match the encoder",
                q.name()
            );
            let d1 = q.decode(&msg, &key);
            let d2 = q.decode(&msg, &key);
            assert_eq!(d1.len(), x.len());
            assert_eq!(d1, d2, "{} decode must be deterministic", q.name());
        }
    }

    #[test]
    fn identity_bits_are_32_per_coord_plus_header() {
        let q = IdentityQuantizer;
        let x = randvec(100, 2, 1.0);
        let msg = q.encode(&x, 0);
        assert!(msg.bits >= 3200);
        assert!(msg.bits < 3200 + 128);
    }

    #[test]
    fn frame_checksum_detects_every_single_bit_flip() {
        // The corruption model flips one bit in flight; FNV-1a's
        // per-byte xor/multiply chain is a bijection composition, so any
        // single-byte difference must change the hash. Exhaustive over a
        // real encoded payload.
        let q = LatticeQuantizer::new(8, 0.05);
        let msg = q.encode(&randvec(97, 5, 1.0), 11);
        let sent = frame_checksum(&msg.payload);
        for bit in 0..msg.payload.len() * 8 {
            let mut wire = msg.payload.clone();
            wire[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(
                frame_checksum(&wire),
                sent,
                "undetected flip at bit {bit}"
            );
        }
        // Identical payloads agree, and the header size is fixed.
        assert_eq!(frame_checksum(&msg.payload), sent);
        assert_eq!(FRAME_HEADER_BITS, 32);
    }

    #[test]
    fn lattice_beats_qsgd_for_model_transmission() {
        // The paper's core argument: for a vector with large norm but small
        // distance to the decoder's key, the position-aware scheme's error
        // is tiny while QSGD's error scales with the norm.
        let n = 4096;
        let base = randvec(n, 3, 10.0); // big-norm "model"
        let x: Vec<f32> = base.iter().map(|v| v + 0.001).collect();
        let lat = LatticeQuantizer::new(8, 0.01);
        let qs = QsgdQuantizer::new(8);
        let (dl, _) = roundtrip(&lat, &x, &base, 7);
        let (dq, _) = roundtrip(&qs, &x, &base, 7);
        let el = crate::util::stats::l2_dist(&dl, &x);
        let eq = crate::util::stats::l2_dist(&dq, &x);
        assert!(el * 10.0 < eq, "lattice err {el} vs qsgd err {eq}");
    }
}

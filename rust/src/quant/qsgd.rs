//! QSGD quantizer (Alistarh et al. [1]) — the norm-scaled stochastic
//! baseline the paper compares against (Figures 5 and 16).
//!
//! Encode: transmit ‖x‖₂ (32 bits) plus, per coordinate, a sign bit and a
//! stochastically-rounded level ℓ ∈ {0..s} with s = 2^{b−1}−1 levels, so
//! each coordinate costs b bits. Decode ignores the key (oblivious):
//! x̂ᵢ = sign·(ℓ/s)·‖x‖.
//!
//! Unbiased, but the per-message error is Θ(‖x‖/√s per coordinate) — when
//! the payload is a *model* (not a small update) this error is huge, which
//! is exactly the failure mode the paper demonstrates for naive
//! quantization of FedAvg-style transmissions.

use super::{QuantMessage, Quantizer};
use crate::util::bits::{BitReader, BitWriter};
use crate::util::rng::Rng;
use crate::util::stats::l2_norm;

#[derive(Clone, Debug)]
pub struct QsgdQuantizer {
    /// total bits per coordinate (1 sign + b-1 level bits), 2..=16
    pub bits: u8,
}

impl QsgdQuantizer {
    pub fn new(bits: u8) -> Self {
        assert!((2..=16).contains(&bits), "qsgd bits must be in 2..=16");
        QsgdQuantizer { bits }
    }

    fn levels(&self) -> u32 {
        (1u32 << (self.bits - 1)) - 1
    }
}

impl Quantizer for QsgdQuantizer {
    fn encode(&self, x: &[f32], seed: u64) -> QuantMessage {
        let norm = l2_norm(x) as f32;
        let s = self.levels();
        let mut w = BitWriter::with_capacity_bits(x.len() * self.bits as usize + 32);
        w.write_f32(norm);
        let mut rng = Rng::new(seed ^ 0x0517_D00D);
        if norm > 0.0 {
            let inv_norm = s as f64 / norm as f64;
            for &v in x {
                let sign = (v < 0.0) as u32;
                let t = v.abs() as f64 * inv_norm;
                let fl = t.floor();
                let level =
                    (fl as u32 + (rng.next_f64() < (t - fl)) as u32).min(s);
                // single packed write: sign bit | level
                w.write(sign | (level << 1), self.bits);
            }
        } else {
            for _ in x {
                w.write(0, self.bits);
            }
        }
        let bits = w.len_bits() + 64;
        let (payload, _) = w.into_bytes();
        QuantMessage { payload, bits, dim: x.len(), seed }
    }

    fn decode(&self, msg: &QuantMessage, _key: &[f32]) -> Vec<f32> {
        let mut r = BitReader::new(&msg.payload);
        let norm = r.read_f32();
        let s = self.levels() as f32;
        (0..msg.dim)
            .map(|_| {
                let packed = r.read(self.bits);
                let sign = if packed & 1 == 1 { -1.0f32 } else { 1.0 };
                let level = (packed >> 1) as f32;
                sign * (level / s) * norm
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn bits_per_coord(&self) -> f64 {
        self.bits as f64
    }

    /// norm header (32) + b bits/coordinate + seed header (64)
    fn encoded_bits(&self, dim: usize) -> usize {
        dim * self.bits as usize + 32 + 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::{l2_dist, l2_norm};

    fn randvec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32 * scale).collect()
    }

    #[test]
    fn roundtrip_error_bounded_by_theory() {
        // QSGD error per coord <= norm/s, so L2 error <= norm*sqrt(n)/s.
        let q = QsgdQuantizer::new(8);
        let n = 1024;
        let x = randvec(n, 1, 1.0);
        let y = q.decode(&q.encode(&x, 5), &x);
        let bound = l2_norm(&x) * (n as f64).sqrt() / q.levels() as f64;
        let err = l2_dist(&x, &y);
        assert!(err <= bound, "err={err} bound={bound}");
    }

    #[test]
    fn unbiased() {
        let q = QsgdQuantizer::new(4);
        let n = 64;
        let x = randvec(n, 2, 1.0);
        let trials = 600;
        let mut acc = vec![0f64; n];
        for t in 0..trials {
            for (a, v) in acc.iter_mut().zip(q.decode(&q.encode(&x, t), &x)) {
                *a += v as f64;
            }
        }
        let mean: Vec<f32> = acc.iter().map(|a| (*a / trials as f64) as f32).collect();
        let bias = l2_dist(&mean, &x);
        assert!(bias < 0.3, "bias={bias}");
    }

    #[test]
    fn zero_vector_roundtrips() {
        let q = QsgdQuantizer::new(8);
        let x = vec![0f32; 33];
        let y = q.decode(&q.encode(&x, 1), &x);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn error_scales_with_norm() {
        // The documented failure mode: same shape, 100x norm => ~100x error.
        let q = QsgdQuantizer::new(8);
        let x = randvec(512, 3, 1.0);
        let xl: Vec<f32> = x.iter().map(|v| v * 100.0).collect();
        let e1 = l2_dist(&q.decode(&q.encode(&x, 9), &x), &x);
        let e2 = l2_dist(&q.decode(&q.encode(&xl, 9), &xl), &xl);
        assert!(e2 > e1 * 30.0, "e1={e1} e2={e2}");
    }

    #[test]
    fn bits_accounting_exact() {
        let q = QsgdQuantizer::new(8);
        let msg = q.encode(&randvec(100, 1, 1.0), 2);
        assert_eq!(msg.bits, 100 * 8 + 32 + 64);
    }

    #[test]
    fn max_magnitude_coord_hits_top_level() {
        let q = QsgdQuantizer::new(8);
        // One-hot: normalized magnitude of the hot coord is exactly 1.
        let mut x = vec![0f32; 16];
        x[3] = -2.5;
        let y = q.decode(&q.encode(&x, 1), &x);
        assert!((y[3] + 2.5).abs() < 1e-6, "{}", y[3]);
    }
}

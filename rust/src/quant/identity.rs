//! Identity "quantizer": full-precision passthrough (32 bits/coordinate).
//! Used for the uncompressed arms of experiments (FedAvg, QuAFL b=32) so
//! every algorithm goes through the same message/bit-accounting path.

use super::{QuantMessage, Quantizer};

#[derive(Clone, Debug, Default)]
pub struct IdentityQuantizer;

impl Quantizer for IdentityQuantizer {
    fn encode(&self, x: &[f32], seed: u64) -> QuantMessage {
        let mut payload = Vec::with_capacity(x.len() * 4);
        for &v in x {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        QuantMessage { bits: x.len() * 32 + 64, payload, dim: x.len(), seed }
    }

    fn decode(&self, msg: &QuantMessage, _key: &[f32]) -> Vec<f32> {
        msg.payload
            .chunks_exact(4)
            .take(msg.dim)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    fn name(&self) -> &'static str {
        "identity"
    }

    fn bits_per_coord(&self) -> f64 {
        32.0
    }

    fn encoded_bits(&self, dim: usize) -> usize {
        dim * 32 + 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_roundtrip() {
        let q = IdentityQuantizer;
        let x = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let y = q.decode(&q.encode(&x, 0), &x);
        assert_eq!(x, y);
    }

    #[test]
    fn key_is_ignored() {
        let q = IdentityQuantizer;
        let x = vec![3.0f32; 7];
        let key = vec![-100.0f32; 7];
        assert_eq!(q.decode(&q.encode(&x, 1), &key), x);
    }
}

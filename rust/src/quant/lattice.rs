//! Position-aware lattice quantizer (Davies et al. [7], as used by QuAFL).
//!
//! Encode(x):  apply a seeded *block-diagonal* random rotation
//! R = diag(R_1..R_m), where each R_j = (1/√B)·H·D is a sign-diagonal ∘
//! Hadamard rotation over a block of B = 4096 coordinates (the tail block
//! is padded to its next power of two — the only padding in the scheme, so
//! the wire cost is d·b + O(B) bits, a ≥3.2× saving at b = 10 as the paper
//! claims). Then stochastically round each rotated coordinate to the grid
//! γ·ℤ and transmit only the *residue* of the grid index modulo 2^b.
//!
//! Decode(key, msg):  rotate the decoder's key with the same seed, and for
//! each coordinate pick the unique grid index congruent to the received
//! residue (mod 2^b) that is nearest the key's rotated coordinate; then
//! rotate back.
//!
//! Properties mirrored from the paper's Lemma 3.1 and checked by the
//! property tests in `rust/tests/quantizer_props.rs`:
//!
//! 1. *Unbiased*: stochastic rounding makes E[Q(x)] = x (over the rounding
//!    randomness; the rotation is orthonormal so it cancels exactly).
//! 2. *Error bound*: ‖Q(x) − x‖ ≤ γ·√d′ (each rotated coordinate moves by
//!    at most γ).
//! 3. *Decodability*: if every rotated coordinate of x is within
//!    γ·(2^{b−1}−1) of the key's, the modular wraparound resolves to the
//!    encoder's exact grid point. Rotation concentrates the per-coordinate
//!    distance around ‖x−key‖/√d′, so in vector terms the scheme decodes
//!    whenever ‖x−key‖ ≲ γ·2^{b−1}·√d′ — the closeness the paper's
//!    potential argument (Lemma 3.4) maintains.
//!
//! γ is the precision/range trade-off: error ∝ γ, decodable radius
//! ∝ γ·2^b. [`lattice_gamma_for`] picks γ from a model-distance bound the
//! caller supplies (QuAFL derives it from η, K and the gradient scale —
//! Theorem 3.2 does the same with problem constants).

use super::{QuantMessage, Quantizer};
use crate::util::bits::{BitReader, BitWriter};
use crate::util::hadamard;
use crate::util::rng::Rng;

/// Rotation block size: large enough to mix coordinates well, small enough
/// that the tail block's power-of-two padding is negligible for model-scale
/// dims (overhead < 4096 coords regardless of d).
pub const ROT_BLOCK: usize = 4096;

/// Block decomposition of a dimension: (offset, true_len, padded_len).
pub(crate) fn rotation_blocks(dim: usize) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0;
    while dim - off >= ROT_BLOCK {
        out.push((off, ROT_BLOCK, ROT_BLOCK));
        off += ROT_BLOCK;
    }
    if off < dim {
        let rem = dim - off;
        out.push((off, rem, rem.next_power_of_two()));
    }
    out
}

/// Total padded (wire) dimension for a given input dimension.
pub fn padded_dim(dim: usize) -> usize {
    rotation_blocks(dim).iter().map(|&(_, _, p)| p).sum()
}

#[derive(Clone, Debug)]
pub struct LatticeQuantizer {
    /// bits per coordinate (residue width), 2..=24
    pub bits: u8,
    /// lattice spacing γ in the rotated domain
    pub gamma: f32,
}

impl LatticeQuantizer {
    pub fn new(bits: u8, gamma: f32) -> Self {
        assert!((2..=24).contains(&bits), "lattice bits must be in 2..=24");
        assert!(gamma > 0.0, "gamma must be positive");
        LatticeQuantizer { bits, gamma }
    }

    /// Per-coordinate decodable radius in the rotated domain.
    pub fn coord_radius(&self) -> f32 {
        self.gamma * ((1u64 << (self.bits - 1)) - 1) as f32
    }

    /// Approximate L2 radius within which (x, key) pairs decode correctly.
    pub fn max_decodable_distance(&self, dim: usize) -> f64 {
        // Rotated coordinates of (x-key) are ~N(0, ||x-key||^2/d'); allow a
        // 5-sigma margin so failure probability is negligible.
        let dp = padded_dim(dim) as f64;
        self.coord_radius() as f64 * dp.sqrt() / 5.0
    }
}

/// Pick γ so that vectors within `dist_bound` (L2) of the decoding key
/// decode correctly w.h.p., given `bits` per coordinate and dimension.
pub fn lattice_gamma_for(dist_bound: f64, bits: u8, dim: usize) -> f32 {
    let dp = padded_dim(dim) as f64;
    let radius = ((1u64 << (bits - 1)) - 1) as f64;
    // per-coord distance concentrates around dist/sqrt(d'); 5x margin.
    (dist_bound * 5.0 / (dp.sqrt() * radius)).max(1e-12) as f32
}

impl Quantizer for LatticeQuantizer {
    fn encode(&self, x: &[f32], seed: u64) -> QuantMessage {
        let dim = x.len();
        let blocks = rotation_blocks(dim);
        let total_padded = padded_dim(dim);
        let m = 1u64 << self.bits;
        let inv_gamma = 1.0 / self.gamma as f64;
        let mut w =
            BitWriter::with_capacity_bits(total_padded * self.bits as usize + 96);
        // Side info: γ travels with the message (32 bits); the seed is
        // carried in the message header (64 bits) — both counted.
        w.write_f32(self.gamma);
        let mut rng = Rng::new(seed ^ 0x51ACE5EED);
        let mut buf = vec![0f32; ROT_BLOCK];
        for (bi, &(off, len, padded)) in blocks.iter().enumerate() {
            let v = &mut buf[..padded];
            v[..len].copy_from_slice(&x[off..off + len]);
            v[len..].fill(0.0);
            hadamard::rotate(v, block_seed(seed, bi));
            let mask = m - 1;
            for &c in v.iter() {
                // Unbiased stochastic rounding of c/γ.
                let t = c as f64 * inv_gamma;
                let fl = t.floor();
                let frac = t - fl;
                let q = fl as i64 + (rng.next_f64() < frac) as i64;
                // Residue mod 2^b: two's-complement low bits (m = 2^b).
                let residue = (q as u64 & mask) as u32;
                w.write(residue, self.bits);
            }
        }
        let bits = w.len_bits() + 64; // + seed header
        let (payload, _) = w.into_bytes();
        QuantMessage { payload, bits, dim, seed }
    }

    fn decode(&self, msg: &QuantMessage, key: &[f32]) -> Vec<f32> {
        assert_eq!(key.len(), msg.dim, "decode key dimension mismatch");
        let blocks = rotation_blocks(msg.dim);
        let mut r = BitReader::new(&msg.payload);
        let gamma = r.read_f32() as f64;
        let inv_gamma = 1.0 / gamma;
        let m = 1i64 << self.bits;
        let inv_m = 1.0 / m as f64;
        let mut out = vec![0f32; msg.dim];
        let mut kbuf = vec![0f32; ROT_BLOCK];
        for (bi, &(off, len, padded)) in blocks.iter().enumerate() {
            let k = &mut kbuf[..padded];
            k[..len].copy_from_slice(&key[off..off + len]);
            k[len..].fill(0.0);
            let bseed = block_seed(msg.seed, bi);
            hadamard::rotate(k, bseed);
            for kc in k.iter_mut() {
                let residue = r.read(self.bits) as i64;
                // Nearest integer ≡ residue (mod 2^b) to key/γ.
                let target = *kc as f64 * inv_gamma;
                let wraps = ((target - residue as f64) * inv_m).round() as i64;
                let q = residue + wraps * m;
                *kc = (q as f64 * gamma) as f32;
            }
            hadamard::rotate_inverse(k, bseed);
            out[off..off + len].copy_from_slice(&k[..len]);
        }
        out
    }

    fn name(&self) -> &'static str {
        "lattice"
    }

    fn bits_per_coord(&self) -> f64 {
        self.bits as f64
    }

    /// γ header (32) + b bits per *padded* coordinate + seed header (64)
    fn encoded_bits(&self, dim: usize) -> usize {
        padded_dim(dim) * self.bits as usize + 32 + 64
    }
}

#[inline]
fn block_seed(seed: u64, block: usize) -> u64 {
    crate::util::rng::derive_seed(seed, 0xB10C_0000 + block as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::{l2_dist, l2_norm};

    fn randvec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32 * scale).collect()
    }

    #[test]
    fn exact_on_close_vectors() {
        // key == x: decoding must recover the encoder's grid point, i.e.
        // error <= gamma per rotated coordinate.
        let q = LatticeQuantizer::new(8, 0.01);
        for &n in &[17usize, 256, 1000, 9000] {
            let x = randvec(n, n as u64, 1.0);
            let msg = q.encode(&x, 9);
            let y = q.decode(&msg, &x);
            let err = l2_dist(&x, &y);
            let bound = q.gamma as f64 * (padded_dim(n) as f64).sqrt();
            assert!(err <= bound, "n={n} err={err} bound={bound}");
        }
    }

    #[test]
    fn error_independent_of_norm() {
        // Shift both x and key by a huge constant vector: error unchanged.
        let q = LatticeQuantizer::new(8, 0.01);
        let n = 512;
        let x = randvec(n, 1, 0.1);
        let key: Vec<f32> = x.iter().map(|v| v + 0.002).collect();
        let err_small = l2_dist(&q.decode(&q.encode(&x, 3), &key), &x);
        let xl: Vec<f32> = x.iter().map(|v| v + 1000.0).collect();
        let keyl: Vec<f32> = key.iter().map(|v| v + 1000.0).collect();
        let err_large = l2_dist(&q.decode(&q.encode(&xl, 3), &keyl), &xl);
        assert!(
            err_large < err_small * 3.0 + 1e-3,
            "err_small={err_small} err_large={err_large}"
        );
    }

    #[test]
    fn unbiased_decoding() {
        // Average Q(x) over many seeds ≈ x (property 1 of Lemma 3.1).
        let q = LatticeQuantizer::new(6, 0.05);
        let n = 64;
        let x = randvec(n, 5, 1.0);
        let trials = 400;
        let mut acc = vec![0f64; n];
        for t in 0..trials {
            let msg = q.encode(&x, 1000 + t);
            let y = q.decode(&msg, &x);
            for (a, v) in acc.iter_mut().zip(&y) {
                *a += *v as f64;
            }
        }
        let mean: Vec<f32> = acc.iter().map(|a| (*a / trials as f64) as f32).collect();
        let bias = l2_dist(&mean, &x);
        // std of the mean ~ gamma*sqrt(n)/sqrt(12*trials)
        let tol = q.gamma as f64 * (n as f64).sqrt() / (trials as f64).sqrt() * 4.0;
        assert!(bias < tol.max(5e-3), "bias={bias} tol={tol}");
    }

    #[test]
    fn decodes_within_radius_fails_gracefully_outside() {
        let n = 1024;
        let bits = 8;
        let x = randvec(n, 11, 1.0);
        // Close key: well inside radius.
        let dist = 0.05;
        let gamma = lattice_gamma_for(dist, bits, n);
        let q = LatticeQuantizer::new(bits, gamma);
        let mut rng = Rng::new(13);
        let dir: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let dn = l2_norm(&dir);
        let key: Vec<f32> = x
            .iter()
            .zip(&dir)
            .map(|(v, d)| v + d * (dist as f32) / dn as f32)
            .collect();
        let y = q.decode(&q.encode(&x, 2), &key);
        let err = l2_dist(&y, &x);
        let bound = gamma as f64 * (n as f64).sqrt();
        assert!(err <= bound * 1.5, "in-radius err={err} bound={bound}");

        // Far key (100x the radius): decode lands near the KEY's lattice
        // sheet, not x — i.e. the wraparound misresolves. We only check it
        // does not explode to infinity (graceful failure).
        let far_key: Vec<f32> = x.iter().map(|v| v + 100.0 * dist as f32).collect();
        let yf = q.decode(&q.encode(&x, 2), &far_key);
        assert!(yf.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bits_accounting_exact() {
        let q = LatticeQuantizer::new(10, 0.01);
        let n = 300; // single tail block, pads to 512
        let msg = q.encode(&randvec(n, 1, 1.0), 4);
        assert_eq!(msg.bits, 512 * 10 + 32 + 64);
    }

    #[test]
    fn compression_ratio_exceeds_3x_at_model_dims() {
        // The paper's headline: >3x compression at b=10 for real model
        // sizes. Block rotation keeps padding overhead below 2.5%.
        let q = LatticeQuantizer::new(10, 0.001);
        let d = 25_450; // the paper's (784,32,10) MLP
        assert_eq!(padded_dim(d), 6 * 4096 + 1024);
        let msg = q.encode(&randvec(d, 2, 1.0), 5);
        let ratio = (d as f64 * 32.0) / msg.bits as f64;
        assert!(ratio > 3.1, "ratio={ratio}");
    }

    #[test]
    fn rotation_blocks_cover_exactly() {
        for &d in &[1usize, 5, 4096, 4097, 8192, 25_450, 235_146] {
            let blocks = rotation_blocks(d);
            let mut expect_off = 0;
            for &(off, len, padded) in &blocks {
                assert_eq!(off, expect_off);
                assert!(padded >= len && padded.is_power_of_two());
                assert!(padded <= ROT_BLOCK);
                expect_off += len;
            }
            assert_eq!(expect_off, d);
            assert!(padded_dim(d) >= d && padded_dim(d) < d + ROT_BLOCK);
        }
    }

    #[test]
    fn gamma_for_radius_roundtrip() {
        let g = lattice_gamma_for(1.0, 10, 25450);
        let q = LatticeQuantizer::new(10, g);
        assert!(q.max_decodable_distance(25450) >= 0.99);
    }

    #[test]
    fn deterministic_encode_given_seed() {
        let q = LatticeQuantizer::new(8, 0.02);
        let x = randvec(100, 3, 1.0);
        let a = q.encode(&x, 77);
        let b = q.encode(&x, 77);
        assert_eq!(a.payload, b.payload);
        let c = q.encode(&x, 78);
        assert_ne!(a.payload, c.payload);
    }
}

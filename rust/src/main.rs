//! `quafl` CLI — the launcher.
//!
//! Subcommands:
//!   run          — run one experiment (algorithm × data × quantizer ×
//!                  timing × network)
//!   figures      — regenerate the paper's figures (+ §net arms) as CSV
//!                  series
//!   sweep        — grid runner: algorithms × quantizers × nets × seeds
//!   trace-report — aggregate a `--trace` JSONL file into a per-phase
//!                  breakdown + BENCH_phase.json
//!   health-report — aggregate the `metric` events of a `--trace` JSONL
//!                  file into a fleet-health dashboard + BENCH_health.json
//!   bench-compare — diff two canonical BENCH_*.json artifacts and exit
//!                  nonzero on wall-time regressions
//!   info         — print artifact/platform/runtime information
//!
//! Examples:
//!   quafl run --algorithm quafl --n 100 --s 10 --quantizer lattice:14 \
//!             --partition by-class --rounds 200 --out results/run.csv
//!   quafl run --net mobile --churn 200/50 --rounds 100
//!   quafl figures --out-dir results [--paper-scale|--smoke] [fig1 net_bw ...]
//!   quafl sweep --algorithms quafl,fedavg --quantizers lattice:10,none \
//!               --nets ideal,mobile --seeds 1,2 --out-dir results/sweep
//!   quafl info

use std::sync::Arc;

use quafl::config::{Algorithm, ExperimentConfig, QuantizerKind};
use quafl::coordinator;
use quafl::figures;
use quafl::net::NetworkConfig;
use quafl::trace::{self, JsonlSink, Level};
use quafl::util::cli;

/// Options that never take a value (declared so trailing positionals —
/// e.g. `figures --smoke fig2` — are not swallowed as flag values).
const BOOL_FLAGS: &[&str] = &[
    "smoke", "paper-scale", "weighted", "xla", "price-init-broadcast",
    "dense-fleet", "broadcast-downlink", "event-driven", "track-potential",
    "dense-potential", "telemetry",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse_with_bool_flags(&argv, BOOL_FLAGS);
    // Process-wide diagnostic level + optional trace mirror: `quafl::log!`
    // lines follow `--trace-level` and, when `--trace` names a file, are
    // mirrored into it as `log` events alongside the runs' own sinks.
    if let Some(lvl) = args.get("trace-level") {
        match Level::parse(lvl) {
            Ok(l) => trace::set_log_level(l),
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
    if let Some(path) = args.get("trace") {
        match JsonlSink::append(path) {
            Ok(sink) => trace::install_log_mirror(Arc::new(sink)),
            Err(e) => {
                eprintln!("opening trace file {path}: {e}");
                std::process::exit(2);
            }
        }
    }
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("figures") => cmd_figures(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("trace-report") => cmd_trace_report(&args),
        Some("health-report") => cmd_health_report(&args),
        Some("bench-compare") => cmd_bench_compare(&args),
        Some("info") => cmd_info(),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: quafl <run|figures|sweep|trace-report|health-report|\
         bench-compare|info> [options]\n\
         \n\
         run options (defaults in parentheses):\n\
         \x20 --algorithm quafl|fedavg|fedbuff|baseline (quafl)\n\
         \x20 --n INT clients (20)        --s INT sampled/round (5)\n\
         \x20 --k INT max local steps (10) --lr FLOAT (0.1)\n\
         \x20 --rounds INT (100)          --model mlp|mlp_wide|mlp_deep|mlp_tiny\n\
         \x20 --family mnist|hard|celeb|tiny --partition iid|by-class|dirichlet:A\n\
         \x20 --quantizer none|lattice:B|qsgd:B (lattice:10)\n\
         \x20 --averaging both|server-only|client-only\n\
         \x20 --weighted                  --swt/--sit FLOAT\n\
         \x20 --slow-fraction FLOAT (0.25) --batch INT (32)\n\
         \x20 --workers INT client-exec threads (0 = all cores)\n\
         \x20 --engine-kernel scalar|blocked|simd (blocked) native GEMM\n\
         \x20                             backend; scalar/blocked are\n\
         \x20                             bit-identical, simd needs\n\
         \x20                             --features simd\n\
         \x20 --price-init-broadcast      price the t=0 init-model broadcast\n\
         \x20 --dense-fleet               eager O(n·d) client models\n\
         \x20                             (reference layout; default is the\n\
         \x20                             CoW fleet store, bit-identical)\n\
         \x20 --seed INT --xla --gamma FLOAT --out FILE.csv\n\
         tracing (default: off — hooks are no-ops, bit-identical runs):\n\
         \x20 --trace FILE.jsonl          append structured span/counter/\n\
         \x20                             sample events (dual wall/sim\n\
         \x20                             stamps; see docs/TRACE_SCHEMA.md)\n\
         \x20 --trace-level off|error|info|debug (info) diagnostic level\n\
         telemetry (rides --trace; see docs/TELEMETRY.md):\n\
         \x20 --telemetry true|false      stream convergence/fleet metrics\n\
         \x20                             as `metric` events (default true;\n\
         \x20                             only arms when --trace is set)\n\
         \x20 --track-potential           record the paper's potential\n\
         \x20                             Φ_t per round (incremental\n\
         \x20                             O(touched·d) probe)\n\
         \x20 --dense-potential           Φ_t via the reference O(n·d)\n\
         \x20                             dense fold (parity oracle)\n\
         client selection (default: the paper's uniform draw):\n\
         \x20 --select uniform|staleness|fairness|loss-poc\n\
         \x20 --select-cap N              hard staleness cap (staleness;\n\
         \x20                             FedBuff drops over-cap updates)\n\
         \x20 --select-candidates D       power-of-choice candidates >= s\n\
         \x20                             (loss-poc; default 2*s)\n\
         network (defaults: ideal transport, always-on clients):\n\
         \x20 --net ideal|broadband|mobile|DIST  (DIST = const:V |\n\
         \x20       lognormal:MEDIAN/SIGMA | pareto:SCALE/SHAPE | mix:P+A+B,\n\
         \x20       bits per sim-time unit, applied to both directions)\n\
         \x20 --net-up/--net-down/--net-latency DIST  per-component override\n\
         \x20 --churn MEAN_UP/MEAN_DOWN   exponential dropout/rejoin churn\n\
         \x20 --duty PERIOD/ON_FRACTION   periodic availability windows\n\
         \x20 --net-compute-corr RHO      copula correlation between compute\n\
         \x20                             rate and bandwidth (default 0.0)\n\
         \x20 --broadcast-downlink        price FedAvg's downlink as one\n\
         \x20                             shared broadcast (slowest link)\n\
         \x20 --event-driven true|false   O(s log n) event-queue availability\n\
         \x20                             index (default true; false = legacy\n\
         \x20                             O(n) walk, bit-identical)\n\
         faults (default: off — no engine built, bit-exact legacy runs;\n\
         \x20       seeded chaos + recovery, see docs/FAULTS.md):\n\
         \x20 --fault-crash P             P(client crashes after local SGD,\n\
         \x20                             before upload) per interaction\n\
         \x20 --fault-drop P              P(loss per transmission attempt,\n\
         \x20                             both directions)\n\
         \x20 --fault-corrupt P           P(uplink payload corruption);\n\
         \x20                             checksum-detected server-side and\n\
         \x20                             treated as a drop\n\
         \x20 --fault-straggle P:MULT     chronic-straggler fleet fraction\n\
         \x20                             and link-slowdown multiplier\n\
         \x20 --fault-retries N (2)       bounded retransmissions per message\n\
         \x20 --fault-backoff S (0.5)     initial backoff; attempt i waits\n\
         \x20                             S*2^i simulated seconds\n\
         \x20 --round-deadline S          server closes the round S sim-\n\
         \x20                             seconds in, once quorum is met\n\
         \x20 --fault-quorum K (1)        min arrivals before the deadline\n\
         \x20                             may close the round (K-of-s)\n\
         \x20 --faults off|on             master switch cross-checked\n\
         \x20                             against the flags above\n\
         \n\
         figures options: --out-dir DIR (results) --paper-scale|--smoke [ids...]\n\
         \n\
         sweep options: run options (base config) plus\n\
         \x20 --algorithms A,B,..  --quantizers Q1,Q2,..\n\
         \x20 --nets N1,N2,.. (each: preset|DIST) --seeds S1,S2,..\n\
         \x20 --out-dir DIR (results/sweep)\n\
         \n\
         trace-report options: quafl trace-report FILE.jsonl\n\
         \x20 --out-dir DIR (results)     prints the per-phase wall/sim\n\
         \x20                             breakdown and writes\n\
         \x20                             DIR/BENCH_phase.json\n\
         \n\
         health-report options: quafl health-report FILE.jsonl\n\
         \x20 --out-dir DIR (results)     prints the fleet-health dashboard\n\
         \x20                             (convergence curves, distribution\n\
         \x20                             quantiles, selection bias) and\n\
         \x20                             writes DIR/BENCH_health.json\n\
         \n\
         bench-compare options: quafl bench-compare OLD.json NEW.json\n\
         \x20 --max-regress PCT (25)      fail (exit 1) when a wall-time\n\
         \x20                             column regresses by more than PCT%\n"
    );
}

fn cmd_sweep(args: &cli::Args) -> i32 {
    let mut known = ExperimentConfig::cli_keys();
    known.extend_from_slice(&[
        "algorithms", "quantizers", "nets", "seeds", "out-dir",
    ]);
    if let Err(e) = args.check_known(&known) {
        eprintln!("{e}");
        return 2;
    }
    let base = match ExperimentConfig::from_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let parse_list = |key: &str| -> Option<Vec<String>> {
        args.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    };
    let spec = (|| -> Result<figures::SweepSpec, String> {
        let algorithms = match parse_list("algorithms") {
            Some(items) => items
                .iter()
                .map(|s| Algorithm::parse(s))
                .collect::<Result<Vec<_>, _>>()?,
            None => vec![base.algorithm],
        };
        let quantizers = match parse_list("quantizers") {
            Some(items) => items
                .iter()
                .map(|s| QuantizerKind::parse(s))
                .collect::<Result<Vec<_>, _>>()?,
            None => vec![base.quantizer],
        };
        // Availability comes from the base flags (--churn/--duty) and
        // applies to every cell; its suffix stays visible in each label.
        let avail_suffix = base.net.availability_suffix();
        let nets = match parse_list("nets") {
            Some(items) => items
                .iter()
                .map(|s| {
                    NetworkConfig::profile_from_str(s).map(|profile| {
                        (
                            format!(
                                "{}{avail_suffix}",
                                s.replace([':', '/', '+'], "-")
                            ),
                            NetworkConfig {
                                profile,
                                availability: base.net.availability.clone(),
                                compute_corr: base.net.compute_corr,
                            },
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => vec![(base.net.label(), base.net.clone())],
        };
        let seeds = match parse_list("seeds") {
            Some(items) => items
                .iter()
                .map(|s| {
                    s.parse::<u64>().map_err(|_| format!("bad seed {s:?}"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => vec![base.seed],
        };
        Ok(figures::SweepSpec { algorithms, quantizers, nets, seeds })
    })();
    let spec = match spec {
        Ok(s) => s,
        Err(e) => {
            eprintln!("sweep error: {e}");
            return 2;
        }
    };
    let out_dir = args.get_str("out-dir", "results/sweep");
    let cells = spec.algorithms.len()
        * spec.quantizers.len()
        * spec.nets.len()
        * spec.seeds.len();
    quafl::log!(
        Info,
        "[sweep] {cells} cells ({} algorithms x {} quantizers x {} nets x {} seeds) -> {out_dir}",
        spec.algorithms.len(),
        spec.quantizers.len(),
        spec.nets.len(),
        spec.seeds.len()
    );
    match figures::run_sweep(&base, &spec, &out_dir) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("sweep failed: {e:#}");
            1
        }
    }
}

fn cmd_run(args: &cli::Args) -> i32 {
    if let Err(e) = args.check_known(&ExperimentConfig::cli_keys()) {
        eprintln!("{e}");
        return 2;
    }
    let cfg = match ExperimentConfig::from_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    quafl::log!(
        Info,
        "[quafl] {} n={} s={} K={} rounds={} model={} quant={:?} part={:?} engine={} workers={} net={}",
        cfg.algorithm.name(),
        cfg.n,
        cfg.s,
        cfg.k,
        cfg.rounds,
        cfg.model,
        cfg.quantizer,
        cfg.partition,
        if cfg.use_xla { "xla" } else { "native" },
        if cfg.workers == 0 { "auto".to_string() } else { cfg.workers.to_string() },
        cfg.net.label(),
    );
    let t0 = std::time::Instant::now();
    match coordinator::run(&cfg) {
        Ok(metrics) => {
            for p in &metrics.points {
                println!(
                    "round={:<6} time={:<10.1} steps={:<8} val_loss={:.4} val_acc={:.4} train_loss={:.4}",
                    p.round, p.sim_time, p.total_client_steps, p.val_loss,
                    p.val_acc, p.train_loss
                );
            }
            println!(
                "final: acc={:.4} loss={:.4} bits_total={} comm_time={:.1} short_rounds={} P[H=0]={:.3} meanH={:.2} wall={:.1}s",
                metrics.final_acc(),
                metrics.final_loss(),
                metrics.total_bits(),
                metrics.total_comm_time(),
                metrics.short_rounds,
                metrics.zero_progress_fraction(),
                metrics.mean_observed_steps(),
                t0.elapsed().as_secs_f64()
            );
            if let Some(out) = args.get("out") {
                if let Err(e) = metrics.write_csv(out) {
                    eprintln!("writing {out}: {e}");
                    return 1;
                }
                quafl::log!(Info, "[quafl] wrote {out}");
            }
            0
        }
        Err(e) => {
            eprintln!("run failed: {e:#}");
            1
        }
    }
}

fn cmd_figures(args: &cli::Args) -> i32 {
    if let Err(e) =
        args.check_known(&["out-dir", "paper-scale", "smoke", "trace", "trace-level"])
    {
        eprintln!("{e}");
        return 2;
    }
    let out_dir = args.get_str("out-dir", "results");
    let paper = args.bool("paper-scale");
    let smoke = args.bool("smoke");
    if paper && smoke {
        eprintln!("--paper-scale and --smoke are mutually exclusive");
        return 2;
    }
    let ids: Vec<String> = if args.positional.is_empty() {
        figures::list().iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    for id in &ids {
        quafl::log!(Info, "[figures] {id} ...");
        if let Err(e) =
            figures::run_figure(id, &out_dir, paper, smoke, args.get("trace"))
        {
            eprintln!("figure {id} failed: {e:#}");
            return 1;
        }
    }
    0
}

fn cmd_trace_report(args: &cli::Args) -> i32 {
    if let Err(e) = args.check_known(&["out-dir", "trace", "trace-level"]) {
        eprintln!("{e}");
        return 2;
    }
    let file = match args.positional.first() {
        Some(f) => f,
        None => {
            eprintln!("usage: quafl trace-report FILE.jsonl [--out-dir DIR]");
            return 2;
        }
    };
    let text = match std::fs::read_to_string(file) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {file}: {e}");
            return 1;
        }
    };
    let events = match quafl::util::json::parse_lines(&text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("parsing {file}: {e}");
            return 1;
        }
    };
    let report = quafl::trace::report::aggregate(&events);
    print!("{}", report.render());
    let out_dir = args.get_str("out-dir", "results");
    match report.write_bench(&out_dir) {
        Ok(path) => {
            quafl::log!(Info, "[trace-report] wrote {path}");
            0
        }
        Err(e) => {
            eprintln!("writing BENCH_phase.json: {e}");
            1
        }
    }
}

fn cmd_health_report(args: &cli::Args) -> i32 {
    if let Err(e) = args.check_known(&["out-dir", "trace", "trace-level"]) {
        eprintln!("{e}");
        return 2;
    }
    let file = match args.positional.first() {
        Some(f) => f,
        None => {
            eprintln!("usage: quafl health-report FILE.jsonl [--out-dir DIR]");
            return 2;
        }
    };
    let text = match std::fs::read_to_string(file) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {file}: {e}");
            return 1;
        }
    };
    let events = match quafl::util::json::parse_lines(&text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("parsing {file}: {e}");
            return 1;
        }
    };
    let report = quafl::telemetry::health::aggregate(&events);
    print!("{}", report.render());
    let out_dir = args.get_str("out-dir", "results");
    match report.write_bench(&out_dir) {
        Ok(path) => {
            quafl::log!(Info, "[health-report] wrote {path}");
            0
        }
        Err(e) => {
            eprintln!("writing BENCH_health.json: {e}");
            1
        }
    }
}

fn cmd_bench_compare(args: &cli::Args) -> i32 {
    if let Err(e) = args.check_known(&["max-regress", "trace", "trace-level"]) {
        eprintln!("{e}");
        return 2;
    }
    let (old_path, new_path) =
        match (args.positional.first(), args.positional.get(1)) {
            (Some(o), Some(n)) => (o, n),
            _ => {
                eprintln!(
                    "usage: quafl bench-compare OLD.json NEW.json \
                     [--max-regress PCT]"
                );
                return 2;
            }
        };
    let max_regress = args.get_f64("max-regress", 25.0);
    let load = |path: &str| -> Result<quafl::util::json::Json, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        quafl::util::json::parse(text.trim())
            .map_err(|e| format!("parsing {path}: {e}"))
    };
    let (old, new) = match (load(old_path), load(new_path)) {
        (Ok(o), Ok(n)) => (o, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match quafl::testing::compare::compare(&old, &new, max_regress) {
        Ok(out) => {
            print!("{}", out.render(max_regress));
            if out.passed() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("bench-compare: {e}");
            2
        }
    }
}

fn cmd_info() -> i32 {
    println!("quafl {} — QuAFL reproduction", env!("CARGO_PKG_VERSION"));
    match quafl::runtime::Runtime::new(coordinator::DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!(
                "artifacts: train_batch={} eval_batch={}",
                rt.meta.train_batch, rt.meta.eval_batch
            );
            for (name, m) in &rt.meta.models {
                println!(
                    "  model {name}: sizes={:?} d={} files=({}, {})",
                    m.sizes, m.num_params, m.train_step_file, m.eval_file
                );
            }
            0
        }
        Err(e) => {
            println!("artifacts not available: {e:#}");
            println!("run `make artifacts` first; native engine still works.");
            0
        }
    }
}

//! `quafl` CLI — the launcher.
//!
//! Subcommands:
//!   run      — run one experiment (algorithm × data × quantizer × timing)
//!   figures  — regenerate the paper's figures as CSV series
//!   info     — print artifact/platform/runtime information
//!
//! Examples:
//!   quafl run --algorithm quafl --n 100 --s 10 --quantizer lattice:14 \
//!             --partition by-class --rounds 200 --out results/run.csv
//!   quafl figures --out-dir results [--paper-scale] [fig1 fig2 ...]
//!   quafl info

use quafl::config::ExperimentConfig;
use quafl::coordinator;
use quafl::figures;
use quafl::util::cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("figures") => cmd_figures(&args),
        Some("info") => cmd_info(),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: quafl <run|figures|info> [options]\n\
         \n\
         run options (defaults in parentheses):\n\
         \x20 --algorithm quafl|fedavg|fedbuff|baseline (quafl)\n\
         \x20 --n INT clients (20)        --s INT sampled/round (5)\n\
         \x20 --k INT max local steps (10) --lr FLOAT (0.1)\n\
         \x20 --rounds INT (100)          --model mlp|mlp_wide|mlp_deep\n\
         \x20 --family mnist|hard|celeb   --partition iid|by-class|dirichlet:A\n\
         \x20 --quantizer none|lattice:B|qsgd:B (lattice:10)\n\
         \x20 --averaging both|server-only|client-only\n\
         \x20 --weighted                  --swt/--sit FLOAT\n\
         \x20 --slow-fraction FLOAT (0.25) --batch INT (32)\n\
         \x20 --workers INT client-exec threads (0 = all cores)\n\
         \x20 --seed INT --xla --gamma FLOAT --out FILE.csv\n\
         \n\
         figures options: --out-dir DIR (results) --paper-scale [ids...]\n"
    );
}

fn cmd_run(args: &cli::Args) -> i32 {
    if let Err(e) = args.check_known(ExperimentConfig::CLI_KEYS) {
        eprintln!("{e}");
        return 2;
    }
    let cfg = match ExperimentConfig::from_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    eprintln!(
        "[quafl] {} n={} s={} K={} rounds={} model={} quant={:?} part={:?} engine={} workers={}",
        cfg.algorithm.name(),
        cfg.n,
        cfg.s,
        cfg.k,
        cfg.rounds,
        cfg.model,
        cfg.quantizer,
        cfg.partition,
        if cfg.use_xla { "xla" } else { "native" },
        if cfg.workers == 0 { "auto".to_string() } else { cfg.workers.to_string() },
    );
    let t0 = std::time::Instant::now();
    match coordinator::run(&cfg) {
        Ok(metrics) => {
            for p in &metrics.points {
                println!(
                    "round={:<6} time={:<10.1} steps={:<8} val_loss={:.4} val_acc={:.4} train_loss={:.4}",
                    p.round, p.sim_time, p.total_client_steps, p.val_loss,
                    p.val_acc, p.train_loss
                );
            }
            println!(
                "final: acc={:.4} loss={:.4} bits_total={} P[H=0]={:.3} meanH={:.2} wall={:.1}s",
                metrics.final_acc(),
                metrics.final_loss(),
                metrics.total_bits(),
                metrics.zero_progress_fraction(),
                metrics.mean_observed_steps(),
                t0.elapsed().as_secs_f64()
            );
            if let Some(out) = args.get("out") {
                if let Err(e) = metrics.write_csv(out) {
                    eprintln!("writing {out}: {e}");
                    return 1;
                }
                eprintln!("[quafl] wrote {out}");
            }
            0
        }
        Err(e) => {
            eprintln!("run failed: {e:#}");
            1
        }
    }
}

fn cmd_figures(args: &cli::Args) -> i32 {
    let out_dir = args.get_str("out-dir", "results");
    let paper = args.flag("paper-scale");
    let ids: Vec<String> = if args.positional.is_empty() {
        figures::list().iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    for id in &ids {
        eprintln!("[figures] {id} ...");
        if let Err(e) = figures::run_figure(id, &out_dir, paper) {
            eprintln!("figure {id} failed: {e:#}");
            return 1;
        }
    }
    0
}

fn cmd_info() -> i32 {
    println!("quafl {} — QuAFL reproduction", env!("CARGO_PKG_VERSION"));
    match quafl::runtime::Runtime::new(coordinator::DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!(
                "artifacts: train_batch={} eval_batch={}",
                rt.meta.train_batch, rt.meta.eval_batch
            );
            for (name, m) in &rt.meta.models {
                println!(
                    "  model {name}: sizes={:?} d={} files=({}, {})",
                    m.sizes, m.num_params, m.train_step_file, m.eval_file
                );
            }
            0
        }
        Err(e) => {
            println!("artifacts not available: {e:#}");
            println!("run `make artifacts` first; native engine still works.");
            0
        }
    }
}

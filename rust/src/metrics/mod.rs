//! Metrics: the series every figure plots — validation loss/accuracy (and
//! train loss) against simulated time, server rounds, total client steps,
//! cumulative communication bits, and per-phase communication time (what
//! the [`crate::net`] transport charged for uplinks vs downlinks).

use crate::util::csv::CsvWriter;

/// Cumulative per-run accounting the algorithms carry between eval
/// points: client steps, exact communication bits, and the simulated
/// transmission time the transport charged, split by phase (up = client →
/// server). Under the default `Ideal` network both time fields stay 0.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommTally {
    pub total_steps: u64,
    pub bits_up: u64,
    pub bits_down: u64,
    pub comm_up_time: f64,
    pub comm_down_time: f64,
    /// high-water mark of resident per-client model bytes, measured by
    /// every algorithm at the same boundary — the round's reduction:
    /// fleet-store distinct allocations ([`crate::fleet`]) plus in-flight
    /// client models held outside the workers (QuAFL's returned
    /// next-models, FedBuff's live pull snapshot and popped-but-
    /// unprocessed start snapshots, FedAvg's shared broadcast snapshot +
    /// returned models). Worker-side SGD scratch and decoded-message
    /// buffers are excluded (transient compute state, identical under
    /// the dense layout). O((s + touched)·d) under the CoW store vs the
    /// eager layout's O(n·d).
    pub peak_model_bytes: u64,
    /// uplink bits that bought nothing: FedBuff pushes the admission rule
    /// rejected, plus (under [`crate::fault`]) lost/corrupted attempts
    /// and updates discarded at the round deadline. A subset of
    /// `bits_up` — rejection's cost was previously invisible next to the
    /// event-count `rejected_interactions`.
    pub wasted_up_bits: u64,
    /// simulated client compute seconds whose results never entered the
    /// server model: FedBuff rejected pushes, crashed clients, and
    /// dropped/deadline-missed updates.
    pub wasted_compute_time: f64,
}

/// One evaluation point.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub round: usize,
    pub sim_time: f64,
    pub total_client_steps: u64,
    pub bits_up: u64,
    pub bits_down: u64,
    /// cumulative simulated uplink transmission time
    pub comm_up_time: f64,
    /// cumulative simulated downlink transmission time
    pub comm_down_time: f64,
    /// peak resident client-model bytes so far (see [`CommTally`])
    pub peak_model_bytes: u64,
    /// Gini coefficient of per-client participation counts so far
    /// ([`crate::select::ParticipationTracker`]; 0 = perfectly equal)
    pub participation_gini: f64,
    /// max model-snapshot staleness (rounds) across the fleet at eval time
    pub staleness_max: u64,
    /// mean model-snapshot staleness (rounds) across the fleet
    pub staleness_mean: f64,
    pub val_loss: f64,
    pub val_acc: f64,
    /// loss on a fixed training subsample (the paper's train-loss curves)
    pub train_loss: f64,
    /// cumulative uplink bits that bought nothing (see [`CommTally`])
    pub wasted_up_bits: u64,
    /// cumulative compute seconds that bought nothing (see [`CommTally`])
    pub wasted_compute_time: f64,
}

/// Full run record.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub label: String,
    pub points: Vec<EvalPoint>,
    /// count of sampled interactions where the client had zero progress
    pub zero_progress_interactions: u64,
    pub total_interactions: u64,
    /// mean observed local steps per interaction (H empirical)
    pub sum_observed_steps: u64,
    /// per-round potential Φ_t = ‖X_t − μ_t‖² + Σᵢ‖Xⁱ − μ_t‖² (paper
    /// Lemma 3.4) — populated only when `ExperimentConfig::track_potential`
    pub potential: Vec<f64>,
    /// rounds where fewer than the configured `s` clients were reachable
    /// (churn/duty-cycle visibility; 0 under `Always` availability)
    pub short_rounds: u64,
    /// FedBuff arrivals the selection policy's admission rule rejected
    /// (staleness cap / fairness quota / loss gate; 0 under `Uniform`)
    pub rejected_interactions: u64,
    /// per-round selected client sets `(sim_time, ids)` — recorded only
    /// when `ExperimentConfig::track_selection` (test/diagnostic hook;
    /// FedBuff records each admitted arrival as a singleton set)
    pub selections: Vec<(f64, Vec<usize>)>,
    /// fault/recovery counter totals ([`crate::fault`]; all zero when
    /// `--faults off` — the `figures chaos` bench rows read these)
    pub fault: crate::fault::FaultCounters,
}

impl RunMetrics {
    pub fn new(label: &str) -> Self {
        RunMetrics { label: label.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, p: EvalPoint) {
        self.points.push(p);
    }

    pub fn final_acc(&self) -> f64 {
        self.points.last().map(|p| p.val_acc).unwrap_or(0.0)
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.val_loss).unwrap_or(f64::NAN)
    }

    pub fn total_bits(&self) -> u64 {
        self.points
            .last()
            .map(|p| p.bits_up + p.bits_down)
            .unwrap_or(0)
    }

    /// Empirical P[H_i = 0] over interactions (paper reports 27% for slow
    /// clients in the Figure 1 setup).
    pub fn zero_progress_fraction(&self) -> f64 {
        if self.total_interactions == 0 {
            return 0.0;
        }
        self.zero_progress_interactions as f64 / self.total_interactions as f64
    }

    /// Mean observed steps per interaction (empirical H).
    pub fn mean_observed_steps(&self) -> f64 {
        if self.total_interactions == 0 {
            return 0.0;
        }
        self.sum_observed_steps as f64 / self.total_interactions as f64
    }

    /// First simulated time at which validation accuracy reached `target`,
    /// if ever — the "time-to-accuracy" headline metric.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.val_acc >= target)
            .map(|p| p.sim_time)
    }

    /// Total simulated communication time charged by the transport.
    pub fn total_comm_time(&self) -> f64 {
        self.points
            .last()
            .map(|p| p.comm_up_time + p.comm_down_time)
            .unwrap_or(0.0)
    }

    /// Peak resident client-model bytes over the whole run (the fleet
    /// store's high-water mark — see [`crate::fleet`]); the series in the
    /// CSV is monotone, so the last point carries the run-level peak.
    pub fn peak_model_bytes(&self) -> u64 {
        self.points
            .last()
            .map(|p| p.peak_model_bytes)
            .unwrap_or(0)
    }

    /// Participation Gini at the last eval point (the series is computed
    /// per point, so the last one is the run-level figure-of-merit).
    pub fn participation_gini(&self) -> f64 {
        self.points
            .last()
            .map(|p| p.participation_gini)
            .unwrap_or(0.0)
    }

    /// Max snapshot staleness at the last eval point.
    pub fn staleness_max(&self) -> u64 {
        self.points.last().map(|p| p.staleness_max).unwrap_or(0)
    }

    /// Mean snapshot staleness at the last eval point.
    pub fn staleness_mean(&self) -> f64 {
        self.points.last().map(|p| p.staleness_mean).unwrap_or(0.0)
    }

    pub const CSV_HEADER: &'static [&'static str] = &[
        "round",
        "sim_time",
        "client_steps",
        "bits_up",
        "bits_down",
        "val_loss",
        "val_acc",
        "train_loss",
        "comm_up_time",
        "comm_down_time",
        "peak_model_bytes",
        "participation_gini",
        "staleness_max",
        "staleness_mean",
        "wasted_up_bits",
        "wasted_compute_s",
    ];

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = CsvWriter::create(path, Self::CSV_HEADER)?;
        for p in &self.points {
            w.row(&[
                p.round as f64,
                p.sim_time,
                p.total_client_steps as f64,
                p.bits_up as f64,
                p.bits_down as f64,
                p.val_loss,
                p.val_acc,
                p.train_loss,
                p.comm_up_time,
                p.comm_down_time,
                p.peak_model_bytes as f64,
                p.participation_gini,
                p.staleness_max as f64,
                p.staleness_mean,
                p.wasted_up_bits as f64,
                p.wasted_compute_time,
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(round: usize, t: f64, acc: f64) -> EvalPoint {
        EvalPoint {
            round,
            sim_time: t,
            total_client_steps: round as u64 * 10,
            bits_up: 100,
            bits_down: 100,
            comm_up_time: round as f64 * 0.5,
            comm_down_time: round as f64 * 0.25,
            peak_model_bytes: 4096 + round as u64,
            participation_gini: 0.1 * round as f64,
            staleness_max: round as u64,
            staleness_mean: round as f64 * 0.5,
            val_loss: 1.0 - acc,
            val_acc: acc,
            train_loss: 1.0 - acc,
            wasted_up_bits: round as u64 * 8,
            wasted_compute_time: round as f64 * 0.125,
        }
    }

    #[test]
    fn time_to_accuracy() {
        let mut m = RunMetrics::new("x");
        m.push(pt(0, 0.0, 0.1));
        m.push(pt(10, 5.0, 0.5));
        m.push(pt(20, 9.0, 0.8));
        assert_eq!(m.time_to_accuracy(0.5), Some(5.0));
        assert_eq!(m.time_to_accuracy(0.9), None);
        assert_eq!(m.final_acc(), 0.8);
    }

    #[test]
    fn zero_progress_fraction() {
        let mut m = RunMetrics::new("x");
        m.total_interactions = 100;
        m.zero_progress_interactions = 27;
        m.sum_observed_steps = 410;
        assert!((m.zero_progress_fraction() - 0.27).abs() < 1e-12);
        assert!((m.mean_observed_steps() - 4.1).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = RunMetrics::new("x");
        m.push(pt(0, 0.0, 0.1));
        m.push(pt(5, 2.0, 0.2));
        let dir = std::env::temp_dir().join("quafl_metrics_test");
        let path = dir.join("m.csv");
        m.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("round,sim_time"));
        assert!(text.lines().next().unwrap().ends_with(
            "participation_gini,staleness_max,staleness_mean,\
             wasted_up_bits,wasted_compute_s"
        ));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn comm_time_accumulates() {
        let mut m = RunMetrics::new("x");
        m.push(pt(0, 0.0, 0.1));
        m.push(pt(4, 2.0, 0.2));
        assert!((m.total_comm_time() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn peak_model_bytes_reads_last_point() {
        let mut m = RunMetrics::new("x");
        assert_eq!(m.peak_model_bytes(), 0);
        m.push(pt(0, 0.0, 0.1));
        m.push(pt(7, 2.0, 0.2));
        assert_eq!(m.peak_model_bytes(), 4096 + 7);
    }

    #[test]
    fn selection_metrics_read_last_point() {
        let mut m = RunMetrics::new("x");
        assert_eq!(m.participation_gini(), 0.0);
        assert_eq!(m.staleness_max(), 0);
        assert_eq!(m.staleness_mean(), 0.0);
        m.push(pt(4, 2.0, 0.2));
        assert!((m.participation_gini() - 0.4).abs() < 1e-12);
        assert_eq!(m.staleness_max(), 4);
        assert!((m.staleness_mean() - 2.0).abs() < 1e-12);
    }
}

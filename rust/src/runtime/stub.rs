//! Offline stand-in for the `xla` (PJRT) bindings crate.
//!
//! The build policy for this repository is "no external deps beyond
//! `anyhow`" (the image builds fully offline), so the real PJRT bindings
//! cannot be a Cargo dependency. This module mirrors the exact API surface
//! [`crate::runtime`] and [`crate::engine::xla`] consume, and fails at the
//! first *runtime* touchpoint ([`PjRtClient::cpu`]) with an actionable
//! error. Everything still type-checks, so the XLA code path stays
//! compiled, reviewed, and ready: vendoring the real bindings and swapping
//! the two `use ... as xla` aliases back restores full PJRT execution.
//!
//! The native engine ([`crate::engine::NativeEngine`], the default) is
//! unaffected; XLA integration tests skip themselves when `artifacts/` is
//! absent.

/// Error type matching the bindings' `{e:?}`-style reporting.
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(
            "XLA/PJRT bindings are not vendored in this offline build; \
             use the native engine (default) or vendor the `xla` crate \
             (see rust/src/runtime/stub.rs)"
                .to_string(),
        )
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single entry point, and
/// in the stub it always errors — no other method is reachable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (the artifacts are HLO text files).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// Computation wrapper handed to [`PjRtClient::compile`].
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Host-side tensor literal.
pub struct Literal;

impl Literal {
    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_cpu_fails_with_actionable_error() {
        let err = PjRtClient::cpu().err().unwrap();
        let msg = format!("{err:?}");
        assert!(msg.contains("native engine"), "unhelpful error: {msg}");
    }
}

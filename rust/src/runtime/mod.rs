//! PJRT runtime: loads the AOT artifacts emitted by `python/compile/aot.py`
//! and compiles them on the CPU PJRT client (`xla` bindings).
//!
//! Interchange format is HLO **text** — `HloModuleProto::from_text_file`
//! reassigns instruction ids, sidestepping the 64-bit-id protos jax ≥ 0.5
//! emits that xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).
//!
//! One `Runtime` per process; executables are compiled once and cached.
//!
//! The offline build does not vendor the PJRT bindings; [`stub`] stands in
//! with the same API and errors at client construction. Swap the alias
//! below to the real crate to restore PJRT execution.

pub mod stub;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use self::stub as xla;

use crate::util::json::{self, Json};

/// Parsed `artifacts/meta.json`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub train_batch: usize,
    pub eval_batch: usize,
    pub models: BTreeMap<String, ModelMeta>,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub sizes: Vec<usize>,
    pub num_params: usize,
    /// (name, shape) in flat argument order (w0, b0, w1, b1, ...)
    pub param_shapes: Vec<(String, Vec<usize>)>,
    pub train_step_file: String,
    pub eval_file: String,
    /// fused K-step artifact (§Perf L2), if emitted
    pub train_k_file: Option<String>,
    pub k_max: Option<usize>,
}

impl Meta {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let models_j = j
            .get("models")
            .and_then(Json::as_obj)
            .context("meta.json: missing models")?;
        let mut models = BTreeMap::new();
        for (name, m) in models_j {
            let sizes = m
                .get("sizes")
                .and_then(Json::as_arr)
                .context("model sizes")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let param_shapes = m
                .get("param_shapes")
                .and_then(Json::as_arr)
                .context("param_shapes")?
                .iter()
                .map(|p| {
                    let pname = p.get("name").and_then(Json::as_str).unwrap_or("");
                    let shape = p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| {
                            a.iter().map(|v| v.as_usize().unwrap_or(0)).collect()
                        })
                        .unwrap_or_default();
                    (pname.to_string(), shape)
                })
                .collect();
            models.insert(
                name.clone(),
                ModelMeta {
                    sizes,
                    num_params: m
                        .get("num_params")
                        .and_then(Json::as_usize)
                        .context("num_params")?,
                    param_shapes,
                    train_step_file: m
                        .get("train_step")
                        .and_then(Json::as_str)
                        .context("train_step")?
                        .to_string(),
                    eval_file: m
                        .get("eval")
                        .and_then(Json::as_str)
                        .context("eval")?
                        .to_string(),
                    train_k_file: m
                        .get("train_k")
                        .and_then(Json::as_str)
                        .map(str::to_string),
                    k_max: m.get("k_max").and_then(Json::as_usize),
                },
            );
        }
        Ok(Meta {
            train_batch: j
                .get("train_batch")
                .and_then(Json::as_usize)
                .context("train_batch")?,
            eval_batch: j
                .get("eval_batch")
                .and_then(Json::as_usize)
                .context("eval_batch")?,
            models,
        })
    }
}

/// PJRT CPU client + artifact directory + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub meta: Meta,
}

impl Runtime {
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let meta = Meta::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime { client, dir, meta })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))
    }

    /// Execute with literal inputs; unwraps the single tuple output into
    /// its elements (aot.py lowers with return_tuple=True).
    pub fn execute(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))
    }

    /// Build an f32 literal of the given shape from a slice.
    pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let numel: usize = dims.iter().product();
        anyhow::ensure!(numel == data.len(), "literal shape/data mismatch");
        let lit = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(lit);
        }
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims_i64)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime construction and execution against real artifacts is covered
    // in rust/tests/integration.rs (requires `make artifacts`). Here we
    // test the metadata parsing in isolation.

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir().join("quafl_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{
              "train_batch": 32, "eval_batch": 256,
              "models": {
                "mlp": {
                  "sizes": [784, 32, 10],
                  "num_params": 25450,
                  "param_shapes": [
                    {"name": "w0", "shape": [784, 32]},
                    {"name": "b0", "shape": [32]},
                    {"name": "w1", "shape": [32, 10]},
                    {"name": "b1", "shape": [10]}
                  ],
                  "train_step": "mlp_train_step.hlo.txt",
                  "eval": "mlp_eval.hlo.txt"
                }
              }
            }"#,
        )
        .unwrap();
        let meta = Meta::load(&dir).unwrap();
        assert_eq!(meta.train_batch, 32);
        let mlp = &meta.models["mlp"];
        assert_eq!(mlp.sizes, vec![784, 32, 10]);
        assert_eq!(mlp.param_shapes.len(), 4);
        assert_eq!(mlp.param_shapes[0].1, vec![784, 32]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn meta_missing_file_is_actionable() {
        let err = Meta::load(Path::new("/nonexistent-quafl")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}

//! Seeded fault injection + failure handling (the chaos subsystem).
//!
//! The paper's QuAFL server is *built* to tolerate partial client
//! asynchrony — it aggregates whatever quantized updates arrive rather
//! than waiting for all of them — but without injected failures that
//! robustness is never exercised: churn/duty gate *pre-selection*
//! availability only, and once a client is selected its exchange always
//! succeeds. This module closes the gap with four seeded fault models
//! behind a [`FaultConfig`] plus the server-side recovery machinery that
//! turns injected faults into graceful degradation:
//!
//! - **crash** (`--fault-crash P`): the client dies after local SGD but
//!   before upload — the compute is wasted (priced into
//!   `wasted_compute_time`) and repeated crashes evict the client
//!   permanently ([`FaultEngine::record_crash`], fed to the
//!   availability index so it is never resampled);
//! - **drop** (`--fault-drop P`): per-attempt uplink/downlink message
//!   loss, recovered by bounded retry with exponential backoff — every
//!   retransmission costs real bits and real simulated time through the
//!   existing `Transport` prices ([`FaultEngine::deliver`]);
//! - **corrupt** (`--fault-corrupt P`): payload corruption of the
//!   quantized encoding. When chaos is armed every uplink payload is
//!   framed with a 32-bit FNV-1a checksum header
//!   ([`crate::quant::frame_checksum`], [`crate::quant::FRAME_HEADER_BITS`]
//!   extra bits on the wire); the server verifies the frame, detects the
//!   flip, and treats the message as a drop (retry path);
//! - **straggle** (`--fault-straggle P:MULT`): a seeded subset of
//!   chronic stragglers whose compute and link times are multiplied by
//!   `MULT`, fattening the delay tail the deadline must cut.
//!
//! Recovery: a per-round deadline (`--round-deadline D`) closes the
//! round at `D` simulated seconds with whatever arrived — K-of-s quorum
//! semantics ([`FaultEngine::quorum_cutoff`]): if fewer than
//! `--fault-quorum` updates beat the deadline the server waits for the
//! quorum-th fastest arrival, and if even that is impossible the round
//! degrades gracefully to whatever was delivered (never hangs).
//! Aggregation reweights by *arrivals*, not by the nominal sample size.
//!
//! Everything draws from a private RNG tree derived off the master seed
//! (`derive_seed(seed, 0xFA17)`), one leaf per (round, client,
//! decision) — never from a shared mutable stream — so fault schedules
//! are bit-identical across `--workers` counts and replays. The default
//! `--faults off` constructs no engine at all and is a bit-exact no-op
//! (rust/tests/fault_parity.rs). Semantics contract: docs/FAULTS.md.

use crate::quant::frame_checksum;
use crate::util::cli::Args;
use crate::util::rng::{derive_seed, Rng};

/// Salt of the fault subsystem's RNG tree under the master seed.
const FAULT_STREAM: u64 = 0xFA17;
/// Per-decision salts inside the fault tree.
const SALT_STRAGGLER: u64 = 0x57A6;
const SALT_CRASH: u64 = 0x11;
const SALT_UP: u64 = 0x22;
const SALT_DOWN: u64 = 0x33;

/// Crashes before a client is declared dead and evicted for good.
pub const CRASHES_TO_EVICT: u32 = 2;
/// Default bounded-retry attempts after the first transmission.
pub const DEFAULT_MAX_RETRIES: u32 = 2;
/// Default initial backoff delay (simulated seconds); doubles per retry.
pub const DEFAULT_BACKOFF_BASE: f64 = 0.5;

/// Which direction a message travels (distinct RNG salts, distinct
/// counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkDir {
    Up,
    Down,
}

/// The fault plan: all rates default to zero and
/// [`FaultConfig::enabled`] == false, which the coordinator maps to "no
/// engine constructed" — the bit-exact no-op path.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// P(client crashes after local SGD, before upload), per interaction.
    pub crash: f64,
    /// P(one transmission attempt is lost), per attempt and direction.
    pub drop: f64,
    /// P(a delivered uplink payload is corrupted in flight), per attempt.
    pub corrupt: f64,
    /// Fraction of the fleet that are chronic stragglers.
    pub straggle: f64,
    /// Compute/link slowdown multiplier for stragglers (>= 1).
    pub straggle_mult: f64,
    /// Round deadline in simulated seconds; 0.0 = no deadline.
    pub round_deadline: f64,
    /// Bounded retransmissions after the first attempt.
    pub max_retries: u32,
    /// Initial backoff delay; attempt i waits `backoff_base * 2^i`.
    pub backoff_base: f64,
    /// Minimum arrivals before the deadline may close the round.
    pub quorum: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            crash: 0.0,
            drop: 0.0,
            corrupt: 0.0,
            straggle: 0.0,
            straggle_mult: 1.0,
            round_deadline: 0.0,
            max_retries: DEFAULT_MAX_RETRIES,
            backoff_base: DEFAULT_BACKOFF_BASE,
            quorum: 1,
        }
    }
}

impl FaultConfig {
    /// CLI keys this subsystem owns (merged into the run/sweep key sets).
    pub const CLI_KEYS: &'static [&'static str] = &[
        "faults",
        "fault-crash",
        "fault-drop",
        "fault-corrupt",
        "fault-straggle",
        "fault-retries",
        "fault-backoff",
        "fault-quorum",
        "round-deadline",
    ];

    /// Any fault model or recovery knob active? `false` means the
    /// coordinator builds no engine and the run is bit-exact legacy.
    pub fn enabled(&self) -> bool {
        self.crash > 0.0
            || self.drop > 0.0
            || self.corrupt > 0.0
            || self.straggle > 0.0
            || self.round_deadline > 0.0
    }

    /// Short label for trace meta / figure arms: `"off"` or the active
    /// knobs, e.g. `"crash=0.1,drop=0.05,deadline=30"`.
    pub fn label(&self) -> String {
        if !self.enabled() {
            return "off".into();
        }
        let mut parts = Vec::new();
        if self.crash > 0.0 {
            parts.push(format!("crash={}", self.crash));
        }
        if self.drop > 0.0 {
            parts.push(format!("drop={}", self.drop));
        }
        if self.corrupt > 0.0 {
            parts.push(format!("corrupt={}", self.corrupt));
        }
        if self.straggle > 0.0 {
            parts.push(format!(
                "straggle={}x{}",
                self.straggle, self.straggle_mult
            ));
        }
        if self.round_deadline > 0.0 {
            parts.push(format!("deadline={}", self.round_deadline));
            if self.quorum > 1 {
                parts.push(format!("quorum={}", self.quorum));
            }
        }
        parts.join(",")
    }

    fn prob(key: &str, s: &str) -> Result<f64, String> {
        let p: f64 =
            s.parse().map_err(|_| format!("--{key}: bad number {s:?}"))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("--{key} {p} outside [0, 1]"));
        }
        Ok(p)
    }

    /// Build from CLI args. `--fault-straggle` takes `P:MULT`; the other
    /// rates take a bare probability. A `--faults off|on` master switch
    /// cross-checks the rest (off + any rate, or on + no rate, are both
    /// rejected as inconsistent).
    pub fn from_args(args: &Args) -> Result<Self, String> {
        // Every fault key takes a value; a bare flag would pass the typo
        // guard and silently leave chaos disarmed.
        for key in Self::CLI_KEYS {
            if args.flag(key) {
                return Err(format!("--{key} requires a value"));
            }
        }
        let mut c = FaultConfig::default();
        if let Some(s) = args.get("fault-crash") {
            c.crash = Self::prob("fault-crash", s)?;
        }
        if let Some(s) = args.get("fault-drop") {
            c.drop = Self::prob("fault-drop", s)?;
        }
        if let Some(s) = args.get("fault-corrupt") {
            c.corrupt = Self::prob("fault-corrupt", s)?;
        }
        if let Some(s) = args.get("fault-straggle") {
            let (p, m) = s.split_once(':').ok_or_else(|| {
                format!("--fault-straggle expects P:MULT, got {s:?}")
            })?;
            c.straggle = Self::prob("fault-straggle", p)?;
            c.straggle_mult = m
                .parse()
                .map_err(|_| format!("--fault-straggle: bad MULT {m:?}"))?;
        }
        if let Some(s) = args.get("round-deadline") {
            c.round_deadline = s
                .parse()
                .map_err(|_| format!("--round-deadline: bad number {s:?}"))?;
        }
        if let Some(s) = args.get("fault-retries") {
            c.max_retries = s
                .parse()
                .map_err(|_| format!("--fault-retries: bad count {s:?}"))?;
            if c.drop == 0.0 && c.corrupt == 0.0 {
                return Err("--fault-retries has no effect without \
                            --fault-drop or --fault-corrupt"
                    .into());
            }
        }
        if let Some(s) = args.get("fault-backoff") {
            c.backoff_base = s
                .parse()
                .map_err(|_| format!("--fault-backoff: bad number {s:?}"))?;
            if c.drop == 0.0 && c.corrupt == 0.0 {
                return Err("--fault-backoff has no effect without \
                            --fault-drop or --fault-corrupt"
                    .into());
            }
        }
        if let Some(s) = args.get("fault-quorum") {
            c.quorum = s
                .parse()
                .map_err(|_| format!("--fault-quorum: bad count {s:?}"))?;
            if c.round_deadline == 0.0 {
                return Err("--fault-quorum has no effect without \
                            --round-deadline"
                    .into());
            }
        }
        if let Some(s) = args.get("faults") {
            match s {
                "off" => {
                    if c.enabled() {
                        return Err(
                            "--faults off contradicts the --fault-* / \
                             --round-deadline flags also given"
                                .into(),
                        );
                    }
                }
                "on" => {
                    if !c.enabled() {
                        return Err(
                            "--faults on needs at least one --fault-* rate \
                             or --round-deadline"
                                .into(),
                        );
                    }
                }
                other => {
                    return Err(format!("--faults {other:?}: expected off|on"))
                }
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("fault-crash", self.crash),
            ("fault-drop", self.drop),
            ("fault-corrupt", self.corrupt),
            ("fault-straggle", self.straggle),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("--{name} {p} outside [0, 1]"));
            }
        }
        if self.straggle_mult < 1.0 || !self.straggle_mult.is_finite() {
            return Err(format!(
                "--fault-straggle multiplier {} must be finite and >= 1",
                self.straggle_mult
            ));
        }
        if self.round_deadline < 0.0 || !self.round_deadline.is_finite() {
            return Err(format!(
                "--round-deadline {} must be finite and >= 0",
                self.round_deadline
            ));
        }
        if self.backoff_base <= 0.0 || !self.backoff_base.is_finite() {
            return Err(format!(
                "--fault-backoff {} must be finite and > 0",
                self.backoff_base
            ));
        }
        if self.max_retries > 16 {
            return Err(format!(
                "--fault-retries {} is absurd (max 16)",
                self.max_retries
            ));
        }
        if self.quorum == 0 {
            return Err("--fault-quorum must be >= 1".into());
        }
        Ok(())
    }
}

/// Cumulative fault/recovery counters — surfaced as trace counters, as
/// telemetry gauges in `health-report`, and in `RunMetrics` for the
/// chaos bench rows.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultCounters {
    /// client crashes injected (post-SGD, pre-upload)
    pub crashes: u64,
    /// clients permanently evicted after repeated crashes
    pub evictions: u64,
    /// lost uplink transmission attempts
    pub drops_up: u64,
    /// lost downlink transmission attempts
    pub drops_down: u64,
    /// checksum-detected corrupted uplink payloads (treated as drops)
    pub corruptions: u64,
    /// retransmission attempts made
    pub retries: u64,
    /// deliveries abandoned after exhausting the retry budget
    pub gave_up: u64,
    /// delivered updates discarded for missing the round deadline
    pub deadline_misses: u64,
    /// rounds where the server waited past the deadline to reach quorum
    pub quorum_waits: u64,
    /// rounds closed with fewer than quorum arrivals (degraded)
    pub degraded_rounds: u64,
    /// simulated seconds spent in retry backoff
    pub backoff_time: f64,
    /// simulated compute seconds whose results never reached the server
    pub wasted_compute_time: f64,
    /// payload bits of failed or discarded transmissions
    pub wasted_bits: u64,
}

/// One delivery attempt sequence's outcome ([`FaultEngine::deliver`]).
#[derive(Clone, Copy, Debug)]
pub struct Delivery {
    /// did any attempt get through intact?
    pub delivered: bool,
    /// total link + backoff time across every attempt
    pub time: f64,
    /// transmissions made (1 = first attempt succeeded)
    pub attempts: u32,
}

/// The seeded chaos engine: per-(round, client) fault draws from a
/// private RNG tree, straggler assignment, crash/eviction bookkeeping,
/// retry/backoff delivery, and the deadline/quorum round-close rule.
#[derive(Clone, Debug)]
pub struct FaultEngine {
    cfg: FaultConfig,
    seed: u64,
    straggler: Vec<bool>,
    crash_count: Vec<u32>,
    dead: Vec<bool>,
    pub counters: FaultCounters,
}

impl FaultEngine {
    pub fn new(cfg: &FaultConfig, master_seed: u64, n: usize) -> Self {
        let seed = derive_seed(master_seed, FAULT_STREAM);
        let mut rng = Rng::new(derive_seed(seed, SALT_STRAGGLER));
        let straggler =
            (0..n).map(|_| rng.bernoulli(cfg.straggle)).collect();
        FaultEngine {
            cfg: cfg.clone(),
            seed,
            straggler,
            crash_count: vec![0; n],
            dead: vec![false; n],
            counters: FaultCounters::default(),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// One private RNG leaf per (decision, round, client): algorithms may
    /// consume decisions in any order (worker fan-out, event pops)
    /// without perturbing each other's draws.
    fn leaf(&self, salt: u64, round: u64, client: usize) -> Rng {
        Rng::new(derive_seed(
            derive_seed(self.seed, salt),
            (round << 32) | client as u64,
        ))
    }

    pub fn is_straggler(&self, client: usize) -> bool {
        self.straggler[client]
    }

    /// Compute/link slowdown for this client (1.0 for non-stragglers).
    pub fn slow_mult(&self, client: usize) -> f64 {
        if self.straggler[client] {
            self.cfg.straggle_mult
        } else {
            1.0
        }
    }

    /// Does this client crash after local SGD this round? (Stateless
    /// draw; pair with [`Self::record_crash`] when it fires.)
    pub fn crashes(&self, round: u64, client: usize) -> bool {
        self.cfg.crash > 0.0
            && self.leaf(SALT_CRASH, round, client).bernoulli(self.cfg.crash)
    }

    /// Book a crash; returns true when it tips the client into permanent
    /// eviction (the caller must then also evict it from the
    /// availability index so it is never resampled).
    pub fn record_crash(&mut self, client: usize) -> bool {
        self.counters.crashes += 1;
        self.crash_count[client] += 1;
        if self.crash_count[client] >= CRASHES_TO_EVICT && !self.dead[client] {
            self.dead[client] = true;
            self.counters.evictions += 1;
            return true;
        }
        false
    }

    pub fn is_dead(&self, client: usize) -> bool {
        self.dead[client]
    }

    /// Price compute/bits that never became a server-visible update.
    pub fn waste(&mut self, compute_s: f64, bits: u64) {
        self.counters.wasted_compute_time += compute_s;
        self.counters.wasted_bits += bits;
    }

    /// Attempt a transmission with bounded retry + exponential backoff.
    ///
    /// `link_time` is one attempt's transport price (already
    /// straggle-multiplied by the caller); every attempt pays it again,
    /// plus `backoff_base * 2^i` between attempts. For uplink payloads
    /// pass the encoded bytes: the first attempt then runs the *real*
    /// frame check — checksum the payload, flip one seeded bit if the
    /// corrupt draw fires, verify server-side (FNV-1a detects every
    /// single-bit flip; see quant::frame_checksum tests). Retries model
    /// re-encoded transmissions with a bernoulli corrupt draw.
    ///
    /// The caller charges `attempts * bits` to the tally (retries cost
    /// real bits); failed attempts' bits are also booked here as waste.
    pub fn deliver(
        &mut self,
        round: u64,
        client: usize,
        dir: LinkDir,
        link_time: f64,
        bits: u64,
        payload: Option<&[u8]>,
    ) -> Delivery {
        let salt = match dir {
            LinkDir::Up => SALT_UP,
            LinkDir::Down => SALT_DOWN,
        };
        let mut rng = self.leaf(salt, round, client);
        let mut time = 0.0;
        for attempt in 0..=self.cfg.max_retries {
            time += link_time;
            let lost = rng.bernoulli(self.cfg.drop);
            let corrupted = if lost || dir == LinkDir::Down {
                false
            } else if attempt == 0 {
                self.frame_corrupted(&mut rng, payload)
            } else {
                rng.bernoulli(self.cfg.corrupt)
            };
            if !lost && !corrupted {
                return Delivery { delivered: true, time, attempts: attempt + 1 };
            }
            if lost {
                match dir {
                    LinkDir::Up => self.counters.drops_up += 1,
                    LinkDir::Down => self.counters.drops_down += 1,
                }
            } else {
                self.counters.corruptions += 1;
            }
            self.counters.wasted_bits += bits;
            if attempt < self.cfg.max_retries {
                let backoff =
                    self.cfg.backoff_base * f64::powi(2.0, attempt as i32);
                time += backoff;
                self.counters.retries += 1;
                self.counters.backoff_time += backoff;
            }
        }
        self.counters.gave_up += 1;
        Delivery {
            delivered: false,
            time,
            attempts: self.cfg.max_retries + 1,
        }
    }

    /// The wire-level corruption model for a framed uplink payload:
    /// checksum sender-side, flip one seeded bit when the corrupt draw
    /// fires, verify server-side. Returns true when the frame check
    /// fails (→ treated as a drop). Without the payload bytes (e.g.
    /// uncompressed fp32 messages never materialized as bytes) the draw
    /// alone decides.
    fn frame_corrupted(&self, rng: &mut Rng, payload: Option<&[u8]>) -> bool {
        if !rng.bernoulli(self.cfg.corrupt) {
            return false;
        }
        match payload {
            Some(bytes) if !bytes.is_empty() => {
                let sent = frame_checksum(bytes);
                let mut wire = bytes.to_vec();
                let bit = rng.gen_range(wire.len() * 8);
                wire[bit / 8] ^= 1 << (bit % 8);
                frame_checksum(&wire) != sent
            }
            _ => true,
        }
    }

    /// The deadline/quorum round-close rule over delivered arrival
    /// offsets (simulated seconds relative to round start). Returns the
    /// round's communication cutoff and an accept mask aligned with
    /// `arrivals`:
    ///
    /// - no deadline: accept everything, cutoff = max arrival;
    /// - all beat the deadline: accept everything, cutoff = max arrival
    ///   (the server closes as soon as the last update lands);
    /// - some miss but quorum beat it: accept the on-time ones, cutoff =
    ///   deadline (the server waited that long), misses counted;
    /// - fewer than quorum beat it: extend the cutoff to the quorum-th
    ///   fastest arrival (`quorum_waits`), accept those;
    /// - fewer than quorum delivered at all: degrade gracefully — accept
    ///   everything that arrived, cutoff = max(deadline, last arrival).
    pub fn quorum_cutoff(
        &mut self,
        arrivals: &[f64],
    ) -> (f64, Vec<bool>) {
        let max_arrival =
            arrivals.iter().cloned().fold(0.0f64, f64::max);
        if self.cfg.round_deadline == 0.0 {
            return (max_arrival, vec![true; arrivals.len()]);
        }
        let deadline = self.cfg.round_deadline;
        let quorum_short = arrivals.len() < self.cfg.quorum;
        if quorum_short {
            self.counters.degraded_rounds += 1;
        }
        let on_time = arrivals.iter().filter(|&&a| a <= deadline).count();
        if on_time == arrivals.len() {
            // Everything delivered beat the deadline. Below quorum the
            // server still waited the deadline out hoping for more.
            let cutoff = if quorum_short {
                deadline.max(max_arrival)
            } else {
                max_arrival
            };
            return (cutoff, vec![true; arrivals.len()]);
        }
        let quorum = self.cfg.quorum.min(arrivals.len());
        let cutoff = if on_time >= quorum {
            deadline
        } else {
            // Wait past the deadline for the quorum-th fastest arrival.
            let mut sorted = arrivals.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.counters.quorum_waits += 1;
            sorted[quorum.max(1) - 1]
        };
        let accept: Vec<bool> =
            arrivals.iter().map(|&a| a <= cutoff).collect();
        let misses = accept.iter().filter(|&&ok| !ok).count() as u64;
        self.counters.deadline_misses += misses;
        (cutoff, accept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn chaotic() -> FaultConfig {
        FaultConfig {
            crash: 0.3,
            drop: 0.4,
            corrupt: 0.2,
            straggle: 0.5,
            straggle_mult: 4.0,
            round_deadline: 20.0,
            quorum: 2,
            ..Default::default()
        }
    }

    #[test]
    fn default_is_disabled_and_labelled_off() {
        let c = FaultConfig::default();
        assert!(!c.enabled());
        assert!(c.validate().is_ok());
        assert_eq!(c.label(), "off");
        assert!(chaotic().enabled());
        assert!(chaotic().label().contains("crash=0.3"));
    }

    #[test]
    fn cli_full_surface_parses() {
        let a = cli::parse(&sv(&[
            "run",
            "--fault-crash",
            "0.1",
            "--fault-drop",
            "0.2",
            "--fault-corrupt",
            "0.05",
            "--fault-straggle",
            "0.25:4",
            "--round-deadline",
            "30",
            "--fault-retries",
            "3",
            "--fault-backoff",
            "0.25",
            "--fault-quorum",
            "2",
        ]));
        let c = FaultConfig::from_args(&a).unwrap();
        assert!(c.enabled());
        assert_eq!(c.crash, 0.1);
        assert_eq!(c.straggle, 0.25);
        assert_eq!(c.straggle_mult, 4.0);
        assert_eq!(c.max_retries, 3);
        assert_eq!(c.quorum, 2);
    }

    #[test]
    fn cli_rejects_inconsistent_combos() {
        // off + a rate is contradictory.
        let a = cli::parse(&sv(&["run", "--faults", "off", "--fault-drop", "0.1"]));
        assert!(FaultConfig::from_args(&a).is_err());
        // on with nothing armed is vacuous.
        let a = cli::parse(&sv(&["run", "--faults", "on"]));
        assert!(FaultConfig::from_args(&a).is_err());
        // retry/backoff/quorum knobs without the faults they tune.
        let a = cli::parse(&sv(&["run", "--fault-retries", "3"]));
        assert!(FaultConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--fault-backoff", "1.0"]));
        assert!(FaultConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--fault-quorum", "2"]));
        assert!(FaultConfig::from_args(&a).is_err());
        // Bare flags, bad grammar, out-of-range rates.
        let a = cli::parse(&sv(&["run", "--fault-crash"]));
        assert!(FaultConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--fault-crash", "1.5"]));
        assert!(FaultConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--fault-straggle", "0.5"]));
        assert!(FaultConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--fault-straggle", "0.5:0.5"]));
        assert!(FaultConfig::from_args(&a).is_err());
        let a = cli::parse(&sv(&["run", "--faults", "maybe"]));
        assert!(FaultConfig::from_args(&a).is_err());
    }

    #[test]
    fn decisions_are_deterministic_and_order_free() {
        let cfg = chaotic();
        let a = FaultEngine::new(&cfg, 7, 32);
        let b = FaultEngine::new(&cfg, 7, 32);
        // Same seed ⇒ identical straggler set and per-leaf draws, in any
        // query order.
        assert_eq!(a.straggler, b.straggler);
        for (round, client) in [(0u64, 3usize), (5, 0), (2, 31), (0, 3)] {
            assert_eq!(a.crashes(round, client), b.crashes(round, client));
        }
        // Different seeds diverge somewhere on a grid this size.
        let c = FaultEngine::new(&cfg, 8, 32);
        let mut differs = c.straggler != a.straggler;
        for round in 0..8u64 {
            for client in 0..32 {
                if a.crashes(round, client) != c.crashes(round, client) {
                    differs = true;
                }
            }
        }
        assert!(differs, "seed must matter");
    }

    #[test]
    fn deliver_prices_retries_and_gives_up() {
        // drop=1: every attempt lost, full retry budget spent.
        let cfg = FaultConfig {
            drop: 1.0,
            max_retries: 2,
            backoff_base: 0.5,
            ..Default::default()
        };
        let mut e = FaultEngine::new(&cfg, 1, 4);
        let d = e.deliver(0, 0, LinkDir::Up, 2.0, 100, None);
        assert!(!d.delivered);
        assert_eq!(d.attempts, 3);
        // 3 transmissions at 2.0 + backoffs 0.5 + 1.0.
        assert!((d.time - 7.5).abs() < 1e-12);
        assert_eq!(e.counters.drops_up, 3);
        assert_eq!(e.counters.retries, 2);
        assert_eq!(e.counters.gave_up, 1);
        assert_eq!(e.counters.wasted_bits, 300);
        assert!((e.counters.backoff_time - 1.5).abs() < 1e-12);
        // drop=0, corrupt=0: first attempt sails through at link price.
        let mut ok = FaultEngine::new(&FaultConfig::default(), 1, 4);
        let d = ok.deliver(0, 0, LinkDir::Down, 2.0, 100, None);
        assert!(d.delivered);
        assert_eq!(d.attempts, 1);
        assert_eq!(d.time.to_bits(), 2.0f64.to_bits());
        assert_eq!(ok.counters, FaultCounters::default());
    }

    #[test]
    fn corruption_is_detected_via_the_real_frame_check() {
        let cfg = FaultConfig { corrupt: 1.0, max_retries: 0, ..Default::default() };
        let mut e = FaultEngine::new(&cfg, 3, 4);
        let payload: Vec<u8> = (0..64).map(|i| (i * 37) as u8).collect();
        let d = e.deliver(0, 1, LinkDir::Up, 1.0, 512, Some(&payload));
        assert!(!d.delivered, "flipped bit must fail the frame check");
        assert_eq!(e.counters.corruptions, 1);
        // Downlink frames are not corrupted (corruption models the
        // quantized uplink encoding).
        let d = e.deliver(0, 1, LinkDir::Down, 1.0, 512, None);
        assert!(d.delivered);
    }

    #[test]
    fn crash_bookkeeping_evicts_after_threshold() {
        let mut e = FaultEngine::new(&chaotic(), 1, 8);
        assert!(!e.record_crash(5), "first crash reboots");
        assert!(!e.is_dead(5));
        assert!(e.record_crash(5), "second crash evicts");
        assert!(e.is_dead(5));
        assert!(!e.record_crash(5), "already dead: no double eviction");
        assert_eq!(e.counters.crashes, 3);
        assert_eq!(e.counters.evictions, 1);
    }

    #[test]
    fn quorum_cutoff_covers_every_regime() {
        let mk = |deadline: f64, quorum: usize| {
            FaultEngine::new(
                &FaultConfig {
                    drop: 0.1,
                    round_deadline: deadline,
                    quorum,
                    ..Default::default()
                },
                1,
                8,
            )
        };
        // No deadline: everything accepted, cutoff = slowest.
        let mut e = FaultEngine::new(
            &FaultConfig { drop: 0.1, ..Default::default() },
            1,
            8,
        );
        let (cut, acc) = e.quorum_cutoff(&[3.0, 1.0, 2.0]);
        assert_eq!(cut, 3.0);
        assert!(acc.iter().all(|&x| x));
        // All on time: closes at the last arrival, not the deadline.
        let mut e = mk(10.0, 2);
        let (cut, acc) = e.quorum_cutoff(&[3.0, 1.0]);
        assert_eq!(cut, 3.0);
        assert!(acc.iter().all(|&x| x));
        assert_eq!(e.counters.deadline_misses, 0);
        // Quorum met, one late: cutoff = deadline, the late one dropped.
        let mut e = mk(10.0, 2);
        let (cut, acc) = e.quorum_cutoff(&[3.0, 25.0, 7.0]);
        assert_eq!(cut, 10.0);
        assert_eq!(acc, vec![true, false, true]);
        assert_eq!(e.counters.deadline_misses, 1);
        // Quorum not met by the deadline: wait for the quorum-th fastest.
        let mut e = mk(10.0, 2);
        let (cut, acc) = e.quorum_cutoff(&[25.0, 12.0, 30.0]);
        assert_eq!(cut, 25.0);
        assert_eq!(acc, vec![true, true, false]);
        assert_eq!(e.counters.quorum_waits, 1);
        assert_eq!(e.counters.deadline_misses, 1);
        // Fewer deliveries than quorum: degrade, accept what arrived.
        let mut e = mk(10.0, 3);
        let (cut, acc) = e.quorum_cutoff(&[12.0]);
        assert_eq!(cut, 12.0);
        assert_eq!(acc, vec![true]);
        assert_eq!(e.counters.degraded_rounds, 1);
        // Nothing delivered at all: the server waited out the deadline.
        let mut e = mk(10.0, 2);
        let (cut, acc) = e.quorum_cutoff(&[]);
        assert!(acc.is_empty());
        assert_eq!(cut, 10.0);
        assert_eq!(e.counters.degraded_rounds, 1);
    }
}

//! Experiment coordinator: wires config × data × engine × quantizer ×
//! timing into a run context, dispatches to the selected algorithm, and
//! owns evaluation scheduling + bit accounting.
//!
//! This is the launcher layer a deployment would use: `run(cfg)` is the single
//! entry point behind both the CLI (`quafl run ...`) and the figure
//! harness.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::algorithms;
use crate::config::{Algorithm, ExperimentConfig, QuantizerKind};
use crate::data::{partition, Dataset, Shard, SynthSpec};
use crate::exec::{EngineFactory, EnginePool};
use crate::fault::FaultEngine;
use crate::fleet::ClientModelStore;
use crate::metrics::{CommTally, EvalPoint, RunMetrics};
use crate::model::ModelSpec;
use crate::net::{ClientAvailability, Transport};
use crate::quant::{
    lattice_gamma_for, IdentityQuantizer, LatticeQuantizer, QsgdQuantizer,
    Quantizer,
};
use crate::select::{ParticipationTracker, SelectionPolicy, SelectionView};
use crate::sim::{build_clocks, ClientClock};
use crate::trace::{JsonlSink, Tracer};
use crate::util::json::Json;
use crate::util::rng::{derive_seed, Rng};

/// Default location of the AOT artifacts relative to the workspace root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Everything an algorithm needs to execute a run.
pub struct FlRun {
    pub cfg: ExperimentConfig,
    /// model architecture (also available via `pool.spec()`; duplicated
    /// here so algorithms can read it while the pool is mutably borrowed)
    pub spec: ModelSpec,
    pub train: Dataset,
    pub val: Dataset,
    /// fixed subsample of the training set for train-loss curves
    pub train_probe: Dataset,
    pub shards: Vec<Shard>,
    pub clocks: Vec<ClientClock>,
    /// per-worker training engines + the deterministic fan-out primitive
    /// (engine 0 doubles as the serial/eval engine)
    pub pool: EnginePool,
    pub quantizer: Box<dyn Quantizer>,
    /// prices every server↔client exchange from its actual encoded bits
    /// ([`crate::net`]); the default `Ideal` profile prices exactly 0.0
    pub transport: Box<dyn Transport>,
    /// gates which clients are reachable at a given simulated time
    pub availability: ClientAvailability,
    /// server-side client-selection policy ([`crate::select`]); the
    /// default `Uniform` is a bit-exact wrapper over
    /// [`ClientAvailability::sample`]
    pub selector: Box<dyn SelectionPolicy>,
    /// per-client participation/staleness/loss history feeding the
    /// selection policy and the Gini/staleness metrics columns
    pub tracker: ParticipationTracker,
    /// server-side sampling randomness
    pub rng: Rng,
    /// expected steps per interaction per client (H_i) — analytic, used by
    /// the weighted variant's η_i = H_min / H_i
    pub expected_h: Vec<f64>,
    /// structured-event sink handle ([`crate::trace`]); `Tracer::off()`
    /// unless `--trace` names a JSONL file. Every hook is a near-no-op
    /// when off and never consumes RNG or perturbs the trajectory when
    /// on (rust/tests/trace_parity.rs).
    pub tracer: Tracer,
    /// seeded chaos engine ([`crate::fault`]) — `None` unless a fault
    /// flag armed it, so `--faults off` (the default) constructs nothing
    /// and stays bit-exact (rust/tests/fault_parity.rs)
    pub fault: Option<FaultEngine>,
}

impl FlRun {
    /// Materialize a run context from a validated config.
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        Self::with_artifacts(cfg, DEFAULT_ARTIFACTS_DIR)
    }

    pub fn with_artifacts(cfg: &ExperimentConfig, artifacts: &str) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let spec = ModelSpec::by_name(&cfg.model).map_err(anyhow::Error::msg)?;

        let synth = SynthSpec::family(
            cfg.family,
            cfg.train_samples,
            cfg.val_samples,
            derive_seed(cfg.seed, 0xDA7A),
        );
        let (train, val) = synth.generate();
        anyhow::ensure!(
            train.dim == spec.input_dim() && train.num_classes == spec.num_classes(),
            "dataset ({}, {}) does not match model {:?}",
            train.dim,
            train.num_classes,
            spec.name
        );

        // The partition is materialized once and shared: each Shard is a
        // view into the Arc (client id + RNG stream), so per-client index
        // vectors are never duplicated — the O(n) memory term the lazy
        // shard removes. The fork argument (the shard's length) matches
        // the old eager construction, keeping every batch stream bit-exact.
        let part = Arc::new(partition(
            &train,
            cfg.n,
            cfg.partition,
            derive_seed(cfg.seed, 0x9A47),
        ));
        let mut shard_rng = Rng::new(derive_seed(cfg.seed, 0x54A2D));
        let shards: Vec<Shard> = (0..cfg.n)
            .map(|i| {
                let len = part.shards[i].len() as u64;
                Shard::from_partition(part.clone(), i, shard_rng.fork(len))
            })
            .collect();

        let clocks = build_clocks(cfg.n, &cfg.timing, derive_seed(cfg.seed, 0xC10C));

        let factory = EngineFactory::new(
            &cfg.model,
            cfg.use_xla,
            artifacts,
            cfg.batch,
            cfg.engine_kernel,
        );
        let pool = EnginePool::new(factory, cfg.workers).context("building engine")?;
        anyhow::ensure!(
            pool.train_batch() == cfg.batch,
            "engine batch {} != config batch {} (XLA artifacts fix the batch; \
             set --batch accordingly)",
            pool.train_batch(),
            cfg.batch
        );

        // Fixed train-loss probe: first min(512, len) samples.
        let probe_n = train.len().min(512);
        let probe_idx: Vec<usize> = (0..probe_n).collect();
        let train_probe = subset(&train, &probe_idx);

        let expected_h = expected_steps_per_interaction(cfg, &clocks);
        let quantizer = build_quantizer(cfg, spec.num_params());
        // Neither build consumes shared RNG state, so the default Ideal
        // network leaves every downstream random stream untouched. The
        // clock rates feed the optional compute↔bandwidth copula
        // (`--net-compute-corr`; 0.0 keeps the legacy independent draws).
        let rates: Vec<f64> = clocks.iter().map(|c| c.rate()).collect();
        let transport =
            cfg.net.build_transport(cfg.n, derive_seed(cfg.seed, 0x4E70), &rates);
        let availability = cfg.net.build_availability(
            cfg.n,
            derive_seed(cfg.seed, 0x4E71),
            cfg.event_driven,
        );

        let tracer = match &cfg.trace {
            Some(path) => {
                let sink = JsonlSink::append(path)
                    .with_context(|| format!("opening trace file {path}"))?;
                Tracer::new(Arc::new(sink), cfg.trace_level)
            }
            None => Tracer::off(),
        };
        tracer.meta(vec![
            ("algorithm", Json::Str(format!("{:?}", cfg.algorithm))),
            ("n", Json::Num(cfg.n as f64)),
            ("s", Json::Num(cfg.s as f64)),
            ("k", Json::Num(cfg.k as f64)),
            ("seed", Json::Num(cfg.seed as f64)),
            ("workers", Json::Num(cfg.workers as f64)),
            ("event_driven", Json::Bool(cfg.event_driven)),
            ("engine_kernel", Json::Str(cfg.engine_kernel.name().to_string())),
            ("telemetry", Json::Bool(cfg.telemetry)),
            ("faults", Json::Str(cfg.fault.label())),
        ]);

        let fault = cfg
            .fault
            .enabled()
            .then(|| FaultEngine::new(&cfg.fault, cfg.seed, cfg.n));

        Ok(FlRun {
            cfg: cfg.clone(),
            spec,
            train,
            val,
            train_probe,
            shards,
            clocks,
            pool,
            quantizer,
            transport,
            availability,
            selector: cfg.select.build(cfg.s),
            tracker: ParticipationTracker::new(cfg.n),
            rng: Rng::new(derive_seed(cfg.seed, 0x5E1EC7)),
            expected_h,
            tracer,
            fault,
        })
    }

    /// Should the run's [`crate::telemetry::Telemetry`] registry arm?
    /// Telemetry rides the trace sink, so it needs one attached
    /// (`--trace`) and the `--telemetry` opt-out left at its default.
    pub fn telemetry_armed(&self) -> bool {
        self.tracer.enabled() && self.cfg.telemetry
    }

    /// Poll every passive per-layer counter and emit the round's gauge
    /// snapshot (cumulative values; `trace-report` shows last/max).
    /// `fleet` is `None` for algorithms without a per-client model store
    /// (the sequential baseline). One early-out branch when tracing is
    /// off — no counter is even read.
    pub fn emit_counters(
        &self,
        round: u64,
        now: f64,
        tally: &CommTally,
        fleet: Option<&ClientModelStore>,
    ) {
        if !self.tracer.enabled() {
            return;
        }
        let t = &self.tracer;
        t.counter("pool_busy_ns", round, self.pool.busy_ns() as f64, now);
        let (drained, depth, avail_ops) = self.availability.event_stats();
        t.counter("events_drained", round, drained as f64, now);
        t.counter("event_queue_depth", round, depth as f64, now);
        let fen = avail_ops + self.tracker.fenwick_ops();
        t.counter("fenwick_ops", round, fen as f64, now);
        if let Some(store) = fleet {
            t.counter(
                "cow_materializations",
                round,
                store.materializations() as f64,
                now,
            );
        }
        t.counter("bits_up", round, tally.bits_up as f64, now);
        t.counter("bits_down", round, tally.bits_down as f64, now);
        t.counter("steps_total", round, tally.total_steps as f64, now);
        let (kflops, kbytes) = self.pool.kernel_stats();
        t.counter("kernel_flops", round, kflops as f64, now);
        t.counter("kernel_bytes", round, kbytes as f64, now);
        if let Some(f) = &self.fault {
            let c = &f.counters;
            t.counter("fault_crashes", round, c.crashes as f64, now);
            t.counter("fault_evictions", round, c.evictions as f64, now);
            t.counter("fault_drops_up", round, c.drops_up as f64, now);
            t.counter("fault_drops_down", round, c.drops_down as f64, now);
            t.counter("fault_corruptions", round, c.corruptions as f64, now);
            t.counter("fault_retries", round, c.retries as f64, now);
            t.counter("fault_gave_up", round, c.gave_up as f64, now);
            t.counter(
                "fault_deadline_misses",
                round,
                c.deadline_misses as f64,
                now,
            );
            t.counter(
                "fault_degraded_rounds",
                round,
                c.degraded_rounds as f64,
                now,
            );
            t.counter("fault_wasted_bits", round, c.wasted_bits as f64, now);
            t.counter("fault_backoff_s", round, c.backoff_time, now);
        }
    }

    /// Sample this round's participants through the selection policy.
    /// Under the default `Uniform` policy this consumes exactly the RNG
    /// stream [`ClientAvailability::sample`] consumed before the
    /// subsystem existed, so default trajectories are bit-identical
    /// (rust/tests/select_parity.rs).
    pub fn select_clients(&mut self, now: f64) -> Vec<usize> {
        let mut view = SelectionView {
            now,
            n: self.cfg.n,
            availability: &mut self.availability,
            tracker: &self.tracker,
        };
        self.selector.select(&mut view, &mut self.rng, self.cfg.s)
    }

    /// Event-driven admission (FedBuff): should `client`'s arriving
    /// update enter the aggregation buffer? The default `Uniform` policy
    /// admits everything without consuming randomness.
    pub fn admit_update(&mut self, now: f64, client: usize) -> bool {
        let mut view = SelectionView {
            now,
            n: self.cfg.n,
            availability: &mut self.availability,
            tracker: &self.tracker,
        };
        self.selector.admit(&mut view, &mut self.rng, client)
    }

    /// Build the per-client model store for this run: copy-on-write by
    /// default (all clients share `base` until they diverge), or fully
    /// materialized when `dense_fleet` asks for the reference O(n·d)
    /// layout — rust/tests/fleet_parity.rs proves the two bit-identical.
    pub fn fleet_store(&self, base: Vec<f32>) -> ClientModelStore {
        ClientModelStore::with_mode(self.cfg.n, base, self.cfg.dense_fleet)
    }

    /// `--price-init-broadcast`: charge the t=0 broadcast of the
    /// full-precision init model to all n clients. Every client's
    /// downlink is accounted in the tally; the transfers overlap, so the
    /// returned elapsed cost is the slowest one. A client whose link
    /// prices the transfer at a positive time also restarts its
    /// local-step process at its own receive time; under the default
    /// `Ideal` transport every cost is exactly 0.0, the clocks are left
    /// untouched, and only the bit tally changes.
    pub fn price_init_broadcast(&mut self, tally: &mut CommTally) -> f64 {
        let bits = (self.spec.num_params() * 32) as u64;
        let mut slowest = 0f64;
        for i in 0..self.cfg.n {
            let t = self.transport.downlink_time(i, bits);
            tally.bits_down += bits;
            tally.comm_down_time += t;
            if t > 0.0 {
                self.clocks[i].restart(t);
            }
            slowest = slowest.max(t);
        }
        slowest
    }

    /// Evaluate server params (validation set sharded across the engine
    /// pool — bit-identical to a primary-only evaluation); push an
    /// EvalPoint carrying the run's cumulative [`CommTally`].
    pub fn eval_point(
        &mut self,
        metrics: &mut RunMetrics,
        round: usize,
        sim_time: f64,
        tally: &CommTally,
        params: &[f32],
    ) -> Result<()> {
        let t0 = self.tracer.start();
        let (val_loss, val_acc) = self.pool.evaluate_sharded(params, &self.val)?;
        let (train_loss, _) =
            self.pool.evaluate_sharded(params, &self.train_probe)?;
        self.tracer.span("eval", t0, round as u64, 0.0, sim_time);
        metrics.push(EvalPoint {
            round,
            sim_time,
            total_client_steps: tally.total_steps,
            bits_up: tally.bits_up,
            bits_down: tally.bits_down,
            comm_up_time: tally.comm_up_time,
            comm_down_time: tally.comm_down_time,
            peak_model_bytes: tally.peak_model_bytes,
            participation_gini: self.tracker.participation_gini(),
            staleness_max: self.tracker.max_staleness(),
            staleness_mean: self.tracker.mean_staleness(),
            val_loss,
            val_acc,
            train_loss,
            wasted_up_bits: tally.wasted_up_bits,
            wasted_compute_time: tally.wasted_compute_time,
        });
        Ok(())
    }
}

/// Extract a sub-dataset by indices (used for the train-loss probe).
pub fn subset(data: &Dataset, idx: &[usize]) -> Dataset {
    let mut features = Vec::with_capacity(idx.len() * data.dim);
    let mut labels = Vec::with_capacity(idx.len());
    for &i in idx {
        features.extend_from_slice(data.feature_row(i));
        labels.push(data.labels[i]);
    }
    Dataset { features, labels, dim: data.dim, num_classes: data.num_classes }
}

/// Analytic E[H_i]: a client is sampled every ~(swt+sit)·n/s time units in
/// expectation; it completes steps at rate λ_i, capped at K.
pub fn expected_steps_per_interaction(
    cfg: &ExperimentConfig,
    clocks: &[ClientClock],
) -> Vec<f64> {
    let interval =
        (cfg.timing.swt + cfg.timing.sit) * cfg.n as f64 / cfg.s as f64;
    clocks
        .iter()
        .map(|c| (c.rate() * interval).min(cfg.k as f64).max(1e-6))
        .collect()
}

/// Build the quantizer the config asks for. For the lattice scheme γ is
/// derived from an expected model-distance bound unless overridden:
/// distance between server and client models is O(η·K·‖grad‖) per the
/// potential argument; we use 2·η·K as a conservative default for the
/// O(1)-gradient synthetic tasks.
pub fn build_quantizer(cfg: &ExperimentConfig, dim: usize) -> Box<dyn Quantizer> {
    match cfg.quantizer {
        QuantizerKind::None => Box::new(IdentityQuantizer),
        QuantizerKind::Qsgd { bits } => Box::new(QsgdQuantizer::new(bits)),
        QuantizerKind::Lattice { bits } => {
            let gamma = cfg.lattice_gamma.unwrap_or_else(|| {
                // Server↔client model distance is O(η·K·‖grad‖); 4x covers
                // the non-i.i.d. drift (calibrated in EXPERIMENTS.md §Quant).
                let dist_bound = 4.0 * cfg.lr as f64 * cfg.k as f64;
                lattice_gamma_for(dist_bound, bits, dim)
            });
            Box::new(LatticeQuantizer::new(bits, gamma))
        }
    }
}

/// Run the configured experiment end to end.
pub fn run(cfg: &ExperimentConfig) -> Result<RunMetrics> {
    run_with_artifacts(cfg, DEFAULT_ARTIFACTS_DIR)
}

pub fn run_with_artifacts(cfg: &ExperimentConfig, artifacts: &str) -> Result<RunMetrics> {
    let mut ctx = FlRun::with_artifacts(cfg, artifacts)?;
    let mut result = match cfg.algorithm {
        Algorithm::QuAFL => algorithms::quafl::run(&mut ctx),
        Algorithm::FedAvg => algorithms::fedavg::run(&mut ctx),
        Algorithm::FedBuff => algorithms::fedbuff::run(&mut ctx),
        Algorithm::Baseline => algorithms::baseline::run(&mut ctx),
    };
    if let (Ok(metrics), Some(f)) = (&mut result, &ctx.fault) {
        metrics.fault = f.counters;
    }
    ctx.tracer.flush();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TimingConfig;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            n: 8,
            s: 3,
            k: 4,
            rounds: 4,
            train_samples: 256,
            val_samples: 64,
            eval_every: 2,
            batch: 16,
            ..Default::default()
        }
    }

    #[test]
    fn flrun_builds() {
        let ctx = FlRun::new(&small_cfg()).unwrap();
        assert_eq!(ctx.shards.len(), 8);
        assert_eq!(ctx.clocks.len(), 8);
        assert_eq!(ctx.expected_h.len(), 8);
        assert_eq!(ctx.train.len(), 256);
        assert!(ctx.train_probe.len() <= 512);
    }

    #[test]
    fn expected_h_respects_speed_and_cap() {
        let cfg = ExperimentConfig {
            n: 10,
            s: 5,
            k: 10,
            timing: TimingConfig { slow_fraction: 0.5, ..Default::default() },
            ..small_cfg()
        };
        let clocks = build_clocks(cfg.n, &cfg.timing, 1);
        let h = expected_steps_per_interaction(&cfg, &clocks);
        // interval = 11*10/5 = 22; fast rate .5 => 11 capped at 10;
        // slow rate .125 => 2.75.
        for (c, &hi) in clocks.iter().zip(&h) {
            if c.slow {
                assert!((hi - 2.75).abs() < 1e-9, "slow H={hi}");
            } else {
                assert_eq!(hi, 10.0, "fast capped at K");
            }
        }
    }

    #[test]
    fn quantizer_built_matches_kind() {
        let mut cfg = small_cfg();
        cfg.quantizer = QuantizerKind::Lattice { bits: 10 };
        assert_eq!(build_quantizer(&cfg, 1000).name(), "lattice");
        cfg.quantizer = QuantizerKind::Qsgd { bits: 8 };
        assert_eq!(build_quantizer(&cfg, 1000).name(), "qsgd");
        cfg.quantizer = QuantizerKind::None;
        assert_eq!(build_quantizer(&cfg, 1000).name(), "identity");
    }

    #[test]
    fn subset_extracts_rows() {
        let ctx = FlRun::new(&small_cfg()).unwrap();
        let sub = subset(&ctx.train, &[3, 5]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.feature_row(0), ctx.train.feature_row(3));
        assert_eq!(sub.labels[1], ctx.train.labels[5]);
    }
}

//! Client data partitioning — the data-heterogeneity axis of the paper.
//!
//! - `Iid`: fixed random split, each client gets a 1/n partition
//!   (the paper's MNIST/FMNIST/CIFAR setup, Appendix A.2).
//! - `ByClass`: samples sorted by class, carved into n contiguous shards —
//!   each client sees a non-overlapping subset of classes (the paper's
//!   "pure non-i.i.d." CelebA setup).
//! - `Dirichlet(α)`: standard intermediate-heterogeneity knob; per class,
//!   sample proportions over clients from Dir(α) (small α → concentrated).

use super::Dataset;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PartitionKind {
    Iid,
    ByClass,
    Dirichlet(f64),
}

impl PartitionKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "iid" => Ok(PartitionKind::Iid),
            "by-class" | "byclass" | "noniid" => Ok(PartitionKind::ByClass),
            other => {
                if let Some(rest) = other.strip_prefix("dirichlet:") {
                    rest.parse::<f64>()
                        .map(PartitionKind::Dirichlet)
                        .map_err(|_| format!("bad dirichlet alpha in {other:?}"))
                } else {
                    Err(format!(
                        "unknown partition {other:?} (iid | by-class | dirichlet:ALPHA)"
                    ))
                }
            }
        }
    }
}

/// Per-client index lists into the dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Vec<usize>>,
}

impl Partition {
    /// Fraction of label mass a client holds on its own classes vs the
    /// global distribution — a scalar heterogeneity diagnostic in [0, 1]:
    /// 0 for a perfectly i.i.d. split, →1 for fully class-disjoint shards.
    pub fn heterogeneity(&self, data: &Dataset) -> f64 {
        let global = data.class_counts();
        let total: usize = global.len();
        let mut acc = 0.0;
        for shard in &self.shards {
            let mut local = vec![0usize; total];
            for &i in shard {
                local[data.labels[i] as usize] += 1;
            }
            // total-variation distance between local and global label dist
            let gsum: f64 = global.iter().sum::<usize>() as f64;
            let lsum: f64 = local.iter().sum::<usize>() as f64;
            let tv: f64 = global
                .iter()
                .zip(&local)
                .map(|(&g, &l)| (g as f64 / gsum - l as f64 / lsum).abs())
                .sum::<f64>()
                / 2.0;
            acc += tv;
        }
        acc / self.shards.len() as f64
    }
}

/// Split `data` into `n` shards.
pub fn partition(data: &Dataset, n: usize, kind: PartitionKind, seed: u64) -> Partition {
    assert!(n >= 1 && data.len() >= n, "need at least one sample per client");
    let mut rng = Rng::new(seed);
    let shards = match kind {
        PartitionKind::Iid => {
            let mut idx: Vec<usize> = (0..data.len()).collect();
            rng.shuffle(&mut idx);
            chunk_even(&idx, n)
        }
        PartitionKind::ByClass => {
            // Stable sort by class, then contiguous equal chunks: clients
            // get non-overlapping class ranges.
            let mut idx: Vec<usize> = (0..data.len()).collect();
            rng.shuffle(&mut idx); // randomize within class
            idx.sort_by_key(|&i| data.labels[i]);
            chunk_even(&idx, n)
        }
        PartitionKind::Dirichlet(alpha) => {
            assert!(alpha > 0.0, "dirichlet alpha must be positive");
            let mut shards = vec![Vec::new(); n];
            // Per class, distribute its samples by Dir(alpha) proportions.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.num_classes];
            for i in 0..data.len() {
                by_class[data.labels[i] as usize].push(i);
            }
            for samples in by_class.iter_mut() {
                rng.shuffle(samples);
                let props = rng.dirichlet(alpha, n);
                // Cumulative assignment preserving counts.
                let mut start = 0usize;
                let total = samples.len();
                let mut acc = 0.0;
                for (c, &p) in props.iter().enumerate() {
                    acc += p;
                    let end = if c == n - 1 {
                        total
                    } else {
                        (acc * total as f64).round() as usize
                    }
                    .min(total);
                    shards[c].extend_from_slice(&samples[start..end]);
                    start = end;
                }
            }
            // Guarantee non-empty shards: steal one sample from the largest.
            for c in 0..n {
                if shards[c].is_empty() {
                    let donor = (0..n).max_by_key(|&j| shards[j].len()).unwrap();
                    let sample = shards[donor].pop().unwrap();
                    shards[c].push(sample);
                }
            }
            shards
        }
    };
    debug_assert_eq!(shards.iter().map(Vec::len).sum::<usize>(), data.len());
    Partition { shards }
}

fn chunk_even(idx: &[usize], n: usize) -> Vec<Vec<usize>> {
    let len = idx.len();
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for c in 0..n {
        let size = base + usize::from(c < extra);
        out.push(idx[start..start + size].to_vec());
        start += size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthFamily, SynthSpec};

    fn data() -> Dataset {
        SynthSpec::family(SynthFamily::Mnist, 400, 10, 1).generate().0
    }

    fn assert_is_partition(p: &Partition, len: usize) {
        let mut seen = vec![false; len];
        for shard in &p.shards {
            assert!(!shard.is_empty());
            for &i in shard {
                assert!(!seen[i], "sample {i} in two shards");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some samples unassigned");
    }

    #[test]
    fn iid_is_a_partition_with_even_sizes() {
        let d = data();
        let p = partition(&d, 7, PartitionKind::Iid, 3);
        assert_is_partition(&p, d.len());
        let sizes: Vec<usize> = p.shards.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn by_class_is_a_partition_with_few_classes_per_client() {
        let d = data();
        let n = 10;
        let p = partition(&d, n, PartitionKind::ByClass, 3);
        assert_is_partition(&p, d.len());
        for shard in &p.shards {
            let mut classes: Vec<u32> = shard.iter().map(|&i| d.labels[i]).collect();
            classes.sort_unstable();
            classes.dedup();
            assert!(classes.len() <= 3, "shard spans {} classes", classes.len());
        }
    }

    #[test]
    fn dirichlet_is_a_partition() {
        let d = data();
        for &alpha in &[0.1, 1.0, 100.0] {
            let p = partition(&d, 8, PartitionKind::Dirichlet(alpha), 5);
            assert_is_partition(&p, d.len());
        }
    }

    #[test]
    fn heterogeneity_ordering() {
        // by-class > dirichlet(0.1) > dirichlet(100) ≈ iid
        let d = data();
        let h_iid = partition(&d, 10, PartitionKind::Iid, 7).heterogeneity(&d);
        let h_dir01 =
            partition(&d, 10, PartitionKind::Dirichlet(0.1), 7).heterogeneity(&d);
        let h_class = partition(&d, 10, PartitionKind::ByClass, 7).heterogeneity(&d);
        assert!(h_class > h_dir01, "class={h_class} dir={h_dir01}");
        assert!(h_dir01 > h_iid, "dir={h_dir01} iid={h_iid}");
        assert!(h_class > 0.8, "by-class should be near 1, got {h_class}");
        assert!(h_iid < 0.35, "iid should be small, got {h_iid}");
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(PartitionKind::parse("iid").unwrap(), PartitionKind::Iid);
        assert_eq!(
            PartitionKind::parse("by-class").unwrap(),
            PartitionKind::ByClass
        );
        assert_eq!(
            PartitionKind::parse("dirichlet:0.5").unwrap(),
            PartitionKind::Dirichlet(0.5)
        );
        assert!(PartitionKind::parse("nope").is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let d = data();
        let a = partition(&d, 5, PartitionKind::Dirichlet(0.5), 11);
        let b = partition(&d, 5, PartitionKind::Dirichlet(0.5), 11);
        assert_eq!(a.shards, b.shards);
    }
}

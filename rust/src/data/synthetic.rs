//! Synthetic classification families standing in for the paper's datasets
//! (MNIST / Fashion-MNIST / CIFAR-10 / CelebA — DESIGN.md §3).
//!
//! Generative model: each class c gets a prototype μ_c ~ N(0, I_d) and a
//! low-rank "style" basis; a sample of class c is
//! `x = margin·μ_c + style·z + noise·ε` with `z, ε ~ N(0, I)`,
//! normalized to roughly unit-variance features like normalized image
//! pixels. `margin`/`noise`/`label_noise` tune difficulty so the families
//! mimic the relative hardness of the paper's tasks: `mnist`-like is
//! nearly linearly separable (MLP → high accuracy fast), `hard` (the
//! FMNIST/CIFAR stand-in) needs the nonlinearity and more steps, and the
//! non-i.i.d. experiments use by-class partitioning on top (partition.rs).

use super::Dataset;
use crate::util::rng::Rng;

/// Named difficulty presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthFamily {
    /// Large-margin, low-noise: the MNIST stand-in.
    Mnist,
    /// Smaller margin, structured style noise: FMNIST/CIFAR stand-in.
    Hard,
    /// Many-class, high style variance: CelebA stand-in (used with
    /// by-class partitioning for the pure non-i.i.d. experiments).
    Celeb,
    /// 16-dimensional Mnist-like miniature: not a paper task, but the
    /// only family whose dataset (`train_samples >= n` is enforced) fits
    /// in memory at n=10⁶–10⁷ for the fleet-scaling benchmarks.
    Tiny,
}

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub dim: usize,
    pub classes: usize,
    pub train: usize,
    pub val: usize,
    pub margin: f32,
    pub noise: f32,
    /// rank of the shared style subspace
    pub style_rank: usize,
    pub style_scale: f32,
    /// probability a training label is resampled uniformly
    pub label_noise: f64,
    pub seed: u64,
}

impl SynthSpec {
    pub fn family(f: SynthFamily, train: usize, val: usize, seed: u64) -> Self {
        match f {
            SynthFamily::Mnist => SynthSpec {
                dim: 784,
                classes: 10,
                train,
                val,
                margin: 1.0,
                noise: 1.0,
                style_rank: 4,
                style_scale: 0.3,
                label_noise: 0.0,
                seed,
            },
            SynthFamily::Hard => SynthSpec {
                dim: 784,
                classes: 10,
                train,
                val,
                margin: 0.32,
                noise: 1.5,
                style_rank: 16,
                style_scale: 1.2,
                label_noise: 0.04,
                seed,
            },
            SynthFamily::Celeb => SynthSpec {
                dim: 784,
                classes: 10,
                train,
                val,
                margin: 0.7,
                noise: 1.0,
                style_rank: 24,
                style_scale: 1.0,
                label_noise: 0.0,
                seed,
            },
            SynthFamily::Tiny => SynthSpec {
                dim: 16,
                classes: 10,
                train,
                val,
                margin: 1.0,
                noise: 1.0,
                style_rank: 4,
                style_scale: 0.3,
                label_noise: 0.0,
                seed,
            },
        }
    }

    /// Generate (train, val) with a shared generative model but disjoint
    /// sample draws.
    pub fn generate(&self) -> (Dataset, Dataset) {
        let mut rng = Rng::new(self.seed);
        // Class prototypes.
        let protos: Vec<Vec<f32>> = (0..self.classes)
            .map(|_| (0..self.dim).map(|_| rng.normal() as f32).collect())
            .collect();
        // Shared style basis (dim x rank).
        let style: Vec<Vec<f32>> = (0..self.style_rank)
            .map(|_| (0..self.dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let gen = |n: usize, rng: &mut Rng, label_noise: f64| -> Dataset {
            let mut features = Vec::with_capacity(n * self.dim);
            let mut labels = Vec::with_capacity(n);
            let inv_sqrt_dim = 1.0 / (self.dim as f32).sqrt();
            for _ in 0..n {
                let mut c = rng.gen_range(self.classes);
                let proto = &protos[c];
                if label_noise > 0.0 && rng.bernoulli(label_noise) {
                    c = rng.gen_range(self.classes);
                }
                let z: Vec<f32> = (0..self.style_rank)
                    .map(|_| rng.normal() as f32 * self.style_scale)
                    .collect();
                for j in 0..self.dim {
                    let mut style_j = 0.0f32;
                    for (r, zr) in z.iter().enumerate() {
                        style_j += style[r][j] * zr;
                    }
                    let v = self.margin * proto[j]
                        + style_j * inv_sqrt_dim.sqrt()
                        + self.noise * rng.normal() as f32;
                    // keep features O(1)
                    features.push(v * 0.5);
                }
                labels.push(c as u32);
            }
            Dataset { features, labels, dim: self.dim, num_classes: self.classes }
        };
        let mut train_rng = rng.fork(1);
        let mut val_rng = rng.fork(2);
        let train = gen(self.train, &mut train_rng, self.label_noise);
        let val = gen(self.val, &mut val_rng, 0.0);
        (train, val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let spec = SynthSpec::family(SynthFamily::Mnist, 50, 20, 9);
        let (a, _) = spec.generate();
        let (b, _) = spec.generate();
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seed_differs() {
        let s1 = SynthSpec::family(SynthFamily::Mnist, 50, 10, 1).generate().0;
        let s2 = SynthSpec::family(SynthFamily::Mnist, 50, 10, 2).generate().0;
        assert_ne!(s1.features, s2.features);
    }

    #[test]
    fn shapes_and_label_range() {
        let spec = SynthSpec::family(SynthFamily::Hard, 120, 40, 3);
        let (train, val) = spec.generate();
        assert_eq!(train.len(), 120);
        assert_eq!(val.len(), 40);
        assert_eq!(train.features.len(), 120 * 784);
        assert!(train.labels.iter().all(|&l| l < 10));
        assert!(val.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn tiny_family_is_small_dimensional() {
        let spec = SynthSpec::family(SynthFamily::Tiny, 64, 16, 8);
        assert_eq!(spec.dim, 16);
        let (train, val) = spec.generate();
        assert_eq!(train.features.len(), 64 * 16);
        assert_eq!(val.len(), 16);
        assert!(train.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn all_classes_present() {
        let spec = SynthSpec::family(SynthFamily::Celeb, 500, 100, 4);
        let (train, _) = spec.generate();
        let counts = train.class_counts();
        assert!(counts.iter().all(|&c| c > 10), "{counts:?}");
    }

    #[test]
    fn features_are_order_one() {
        let spec = SynthSpec::family(SynthFamily::Mnist, 50, 10, 5);
        let (train, _) = spec.generate();
        let mean: f64 = train.features.iter().map(|&v| v as f64).sum::<f64>()
            / train.features.len() as f64;
        let var: f64 = train
            .features
            .iter()
            .map(|&v| (v as f64 - mean).powi(2))
            .sum::<f64>()
            / train.features.len() as f64;
        assert!(mean.abs() < 0.5, "mean={mean}");
        assert!(var > 0.05 && var < 5.0, "var={var}");
    }

    #[test]
    fn mnist_family_is_linearly_separable_enough() {
        // Nearest-prototype classification on the generated data should be
        // much better than chance for the "easy" family.
        let spec = SynthSpec::family(SynthFamily::Mnist, 200, 200, 6);
        let (train, val) = spec.generate();
        // Estimate class means from train.
        let mut means = vec![vec![0f64; spec.dim]; spec.classes];
        let mut counts = vec![0usize; spec.classes];
        for i in 0..train.len() {
            let c = train.labels[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(train.feature_row(i)) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            if c > 0 {
                for v in m.iter_mut() {
                    *v /= c as f64;
                }
            }
        }
        let mut correct = 0;
        for i in 0..val.len() {
            let row = val.feature_row(i);
            let mut best = (f64::MAX, 0usize);
            for (c, m) in means.iter().enumerate() {
                let d: f64 = row
                    .iter()
                    .zip(m)
                    .map(|(&x, &mu)| (x as f64 - mu).powi(2))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 as u32 == val.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / val.len() as f64;
        assert!(acc > 0.5, "nearest-prototype acc={acc}");
    }
}

//! Datasets and heterogeneous partitioning — the LEAF-benchmark substitute
//! (DESIGN.md §3). Synthetic classification families with controllable
//! difficulty + the paper's partitioning modes: fixed random i.i.d. split
//! (MNIST/FMNIST/CIFAR experiments) and pure non-i.i.d. by-class split
//! (CelebA experiments), plus a Dirichlet(α) partitioner for ablations.

pub mod partition;
pub mod synthetic;

pub use partition::{partition, Partition, PartitionKind};
pub use synthetic::{SynthSpec, SynthFamily};

use std::sync::Arc;

use crate::util::rng::Rng;

/// A dense classification dataset. Features are row-major
/// `(num_samples, dim)`; labels are class ids `< num_classes`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
    pub dim: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn feature_row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Materialize a batch (x row-major, y one-hot) from sample indices.
    pub fn gather_batch(&self, idx: &[usize]) -> Batch {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = vec![0f32; idx.len() * self.num_classes];
        for (row, &i) in idx.iter().enumerate() {
            x.extend_from_slice(self.feature_row(i));
            y[row * self.num_classes + self.labels[i] as usize] = 1.0;
        }
        Batch { x, y, batch: idx.len(), dim: self.dim, classes: self.num_classes }
    }

    /// [`Dataset::gather_batch`] into a caller-owned scratch batch: the
    /// `x`/`y` vectors are truncated and refilled in place, so a scratch
    /// reused across calls with the same shape allocates nothing after
    /// the first fill. Produces bit-identical contents to
    /// [`Dataset::gather_batch`].
    pub fn gather_batch_into(&self, idx: &[usize], out: &mut Batch) {
        out.x.clear();
        out.x.reserve(idx.len() * self.dim);
        out.y.clear();
        out.y.resize(idx.len() * self.num_classes, 0f32);
        for (row, &i) in idx.iter().enumerate() {
            out.x.extend_from_slice(self.feature_row(i));
            out.y[row * self.num_classes + self.labels[i] as usize] = 1.0;
        }
        out.batch = idx.len();
        out.dim = self.dim;
        out.classes = self.num_classes;
    }

    /// Class histogram (used by partition tests and heterogeneity stats).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.num_classes];
        for &l in &self.labels {
            c[l as usize] += 1;
        }
        c
    }
}

/// A materialized minibatch in the layout the engines expect.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (batch, dim) row-major features
    pub x: Vec<f32>,
    /// (batch, classes) row-major one-hot labels
    pub y: Vec<f32>,
    pub batch: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Batch {
    /// Zero-sample placeholder — the initial state of scratch batches
    /// filled by [`Dataset::gather_batch_into`].
    pub fn empty() -> Self {
        Batch { x: Vec::new(), y: Vec::new(), batch: 0, dim: 0, classes: 0 }
    }
}

/// A client's view of the training set: indices into the shared dataset
/// plus an independent sampling stream (clients sample i.i.d. from their
/// local distribution, matching the paper's stochastic-gradient model).
///
/// The index list has two backings (ROADMAP "Lazy shards"): an owned
/// vector (the baseline node, tests) or a **shared view into the fleet's
/// one materialized [`Partition`]** — client `i`'s shard is just
/// `(Arc<Partition>, i)` plus its RNG, so building n shards allocates no
/// per-client index vectors at all. The pre-lazy construction cloned
/// every partition shard into its own `Vec<usize>`, an O(total-samples)
/// duplicate plus n allocations that `figures net_fleet`-scale sweeps
/// paid up front. Batch draws are bit-identical either way (same index
/// values, same RNG stream).
#[derive(Clone, Debug)]
pub struct Shard {
    backing: ShardBacking,
    rng: Rng,
}

#[derive(Clone, Debug)]
enum ShardBacking {
    /// the shard owns its index list
    Owned(Vec<usize>),
    /// a view into the fleet-shared partition: no per-client allocation
    Shared { part: Arc<Partition>, client: usize },
}

impl Shard {
    pub fn new(indices: Vec<usize>, rng: Rng) -> Self {
        assert!(!indices.is_empty(), "empty shard");
        Shard { backing: ShardBacking::Owned(indices), rng }
    }

    /// Client `client`'s view of the shared partition (see the type docs).
    pub fn from_partition(part: Arc<Partition>, client: usize, rng: Rng) -> Self {
        assert!(!part.shards[client].is_empty(), "empty shard");
        Shard { backing: ShardBacking::Shared { part, client }, rng }
    }

    /// The client's index list (borrowed from the shared partition when
    /// the shard is a view).
    pub fn indices(&self) -> &[usize] {
        match &self.backing {
            ShardBacking::Owned(v) => v,
            ShardBacking::Shared { part, client } => &part.shards[*client],
        }
    }

    pub fn len(&self) -> usize {
        self.indices().len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices().is_empty()
    }

    /// Draw a batch of local sample indices with replacement.
    pub fn sample_batch(&mut self, batch: usize) -> Vec<usize> {
        let Shard { backing, rng } = self;
        let indices: &[usize] = match backing {
            ShardBacking::Owned(v) => v,
            ShardBacking::Shared { part, client } => &part.shards[*client],
        };
        (0..batch)
            .map(|_| indices[rng.gen_range(indices.len())])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            features: (0..12).map(|v| v as f32).collect(),
            labels: vec![0, 1, 2, 0],
            dim: 3,
            num_classes: 3,
        }
    }

    #[test]
    fn gather_batch_layout() {
        let d = tiny();
        let b = d.gather_batch(&[1, 3]);
        assert_eq!(b.batch, 2);
        assert_eq!(b.x, vec![3.0, 4.0, 5.0, 9.0, 10.0, 11.0]);
        assert_eq!(b.y, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn gather_batch_into_matches_gather_batch() {
        let d = tiny();
        let mut scratch = Batch::empty();
        for idx in [vec![1usize, 3], vec![0], vec![2, 0, 1]] {
            d.gather_batch_into(&idx, &mut scratch);
            let fresh = d.gather_batch(&idx);
            assert_eq!(scratch.x, fresh.x);
            assert_eq!(scratch.y, fresh.y);
            assert_eq!(scratch.batch, fresh.batch);
            assert_eq!(scratch.dim, fresh.dim);
            assert_eq!(scratch.classes, fresh.classes);
        }
    }

    #[test]
    fn gather_batch_into_reuses_capacity() {
        let d = tiny();
        let mut scratch = Batch::empty();
        d.gather_batch_into(&[0, 1, 2], &mut scratch);
        let (cx, cy) = (scratch.x.capacity(), scratch.y.capacity());
        // Same or smaller shapes must not reallocate.
        d.gather_batch_into(&[3, 2, 1], &mut scratch);
        d.gather_batch_into(&[1], &mut scratch);
        assert_eq!(scratch.x.capacity(), cx);
        assert_eq!(scratch.y.capacity(), cy);
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny().class_counts(), vec![2, 1, 1]);
    }

    #[test]
    fn shard_sampling_stays_in_shard() {
        let mut s = Shard::new(vec![2, 5, 7], Rng::new(1));
        for _ in 0..50 {
            for i in s.sample_batch(4) {
                assert!([2, 5, 7].contains(&i));
            }
        }
    }

    #[test]
    fn shard_sampling_covers_all_indices() {
        let mut s = Shard::new(vec![1, 2, 3, 4], Rng::new(2));
        let mut seen = [false; 5];
        for _ in 0..100 {
            for i in s.sample_batch(2) {
                seen[i] = true;
            }
        }
        assert!(seen[1] && seen[2] && seen[3] && seen[4]);
    }

    #[test]
    fn shared_shard_matches_owned_bitwise() {
        // The lazy (shared-partition) backing must produce the exact
        // batch stream the owned backing produces from the same RNG.
        let part = Arc::new(Partition {
            shards: vec![vec![0, 3], vec![2, 5, 7, 9]],
        });
        let mut owned = Shard::new(part.shards[1].clone(), Rng::new(11));
        let mut shared = Shard::from_partition(part.clone(), 1, Rng::new(11));
        assert_eq!(owned.len(), shared.len());
        assert_eq!(owned.indices(), shared.indices());
        for _ in 0..50 {
            assert_eq!(owned.sample_batch(7), shared.sample_batch(7));
        }
    }

    #[test]
    fn shared_shard_allocates_no_index_copies() {
        // The view borrows the partition's own storage.
        let part = Arc::new(Partition { shards: vec![vec![4, 8, 15]] });
        let shard = Shard::from_partition(part.clone(), 0, Rng::new(1));
        assert!(std::ptr::eq(shard.indices(), part.shards[0].as_slice()));
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn shared_shard_rejects_empty_partition_entry() {
        let part = Arc::new(Partition { shards: vec![vec![]] });
        let _ = Shard::from_partition(part, 0, Rng::new(1));
    }
}

//! Bench-regression gate (`quafl bench-compare OLD.json NEW.json`): diff
//! two canonical `{bench, rows}` BENCH artifacts and flag wall-time
//! regressions beyond a percentage threshold.
//!
//! Rows are matched by the concatenation of their string-valued fields
//! (for the standard [`super::bench`] schema that is the row `name`;
//! richer artifacts like BENCH_fleet.json match on every string column),
//! so reordering rows between runs never misreports. Only the wall-time
//! keys in [`GATE_KEYS`] are gated; counts/throughput columns are
//! informational. Rows present on one side only are reported but
//! non-fatal — benchmarks legitimately grow new rows.

use crate::util::json::Json;

/// Wall-time row keys the gate inspects (a key participates only when
/// present and numeric on both sides).
pub const GATE_KEYS: &[&str] =
    &["mean_ns", "p50_ns", "p95_ns", "wall_ns_total", "wall_ns_mean"];

/// One gated key's old→new movement on one row.
#[derive(Clone, Debug)]
pub struct Delta {
    pub row: String,
    pub key: &'static str,
    pub old: f64,
    pub new: f64,
    /// (new − old) / old · 100
    pub pct: f64,
}

/// Everything `bench-compare` reports.
#[derive(Debug, Default)]
pub struct CompareOutcome {
    pub bench: String,
    /// rows matched on both sides
    pub compared: usize,
    /// gated keys that slowed down by more than the threshold (fatal)
    pub regressions: Vec<Delta>,
    /// gated keys that sped up by more than the threshold (informational)
    pub improvements: Vec<Delta>,
    /// row ids present in OLD only (warn)
    pub missing: Vec<String>,
    /// row ids present in NEW only (warn)
    pub added: Vec<String>,
}

impl CompareOutcome {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }

    /// Human-readable report, worst regression first.
    pub fn render(&self, max_regress_pct: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "bench-compare: {} — {} row(s) compared, {} regression(s), \
             {} improvement(s) (threshold {:.1}%)\n",
            self.bench,
            self.compared,
            self.regressions.len(),
            self.improvements.len(),
            max_regress_pct,
        ));
        let mut worst = self.regressions.clone();
        worst.sort_by(|a, b| b.pct.partial_cmp(&a.pct).unwrap());
        for d in &worst {
            out.push_str(&format!(
                "  REGRESSION {:+.1}%  {}  {}: {:.0} -> {:.0} ns\n",
                d.pct, d.row, d.key, d.old, d.new
            ));
        }
        for d in &self.improvements {
            out.push_str(&format!(
                "  improved   {:+.1}%  {}  {}: {:.0} -> {:.0} ns\n",
                d.pct, d.row, d.key, d.old, d.new
            ));
        }
        for id in &self.missing {
            out.push_str(&format!("  warning: row only in OLD: {id}\n"));
        }
        for id in &self.added {
            out.push_str(&format!("  warning: row only in NEW: {id}\n"));
        }
        if self.passed() {
            out.push_str("  PASS\n");
        } else {
            out.push_str("  FAIL\n");
        }
        out
    }
}

/// A row's identity: its string-valued fields as sorted `key=value`
/// pairs (the `Json::Obj` BTreeMap is already key-sorted). Rows with no
/// string field fall back to their array position.
fn row_id(row: &Json, index: usize) -> String {
    let mut parts = Vec::new();
    if let Json::Obj(m) = row {
        for (k, v) in m {
            if let Json::Str(s) = v {
                parts.push(format!("{k}={s}"));
            }
        }
    }
    if parts.is_empty() {
        format!("row#{index}")
    } else {
        parts.join("|")
    }
}

fn rows_by_id(doc: &Json) -> Result<Vec<(String, &Json)>, String> {
    let rows = doc
        .get("rows")
        .and_then(|r| match r {
            Json::Arr(a) => Some(a),
            _ => None,
        })
        .ok_or("artifact has no `rows` array (not a canonical BENCH file?)")?;
    Ok(rows
        .iter()
        .enumerate()
        .map(|(i, r)| (row_id(r, i), r))
        .collect())
}

/// Diff two canonical BENCH artifacts. `max_regress_pct` is the fatal
/// slowdown threshold on every [`GATE_KEYS`] column; errors are
/// malformed inputs, never regressions (the caller checks
/// [`CompareOutcome::passed`]).
pub fn compare(
    old: &Json,
    new: &Json,
    max_regress_pct: f64,
) -> Result<CompareOutcome, String> {
    let old_bench = old
        .get("bench")
        .and_then(Json::as_str)
        .ok_or("OLD artifact has no `bench` name")?;
    let new_bench = new
        .get("bench")
        .and_then(Json::as_str)
        .ok_or("NEW artifact has no `bench` name")?;
    if old_bench != new_bench {
        return Err(format!(
            "bench name mismatch: OLD is {old_bench:?}, NEW is {new_bench:?}"
        ));
    }
    let old_rows = rows_by_id(old)?;
    let new_rows = rows_by_id(new)?;

    let mut out = CompareOutcome {
        bench: old_bench.to_string(),
        ..Default::default()
    };
    for (id, old_row) in &old_rows {
        let Some((_, new_row)) = new_rows.iter().find(|(nid, _)| nid == id)
        else {
            out.missing.push(id.clone());
            continue;
        };
        out.compared += 1;
        for &key in GATE_KEYS {
            let (Some(o), Some(n)) = (
                old_row.get(key).and_then(Json::as_f64),
                new_row.get(key).and_then(Json::as_f64),
            ) else {
                continue;
            };
            if o.is_nan() || o <= 0.0 {
                continue;
            }
            let pct = (n - o) / o * 100.0;
            let d = Delta { row: id.clone(), key, old: o, new: n, pct };
            if pct > max_regress_pct {
                out.regressions.push(d);
            } else if pct < -max_regress_pct {
                out.improvements.push(d);
            }
        }
    }
    for (id, _) in &new_rows {
        if !old_rows.iter().any(|(oid, _)| oid == id) {
            out.added.push(id.clone());
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn bench_doc(rows: &[(&str, f64)]) -> Json {
        let rows_json: Vec<String> = rows
            .iter()
            .map(|(name, mean)| {
                format!(
                    "{{\"name\":\"{name}\",\"mean_ns\":{mean},\"iters\":3}}"
                )
            })
            .collect();
        parse(&format!(
            "{{\"bench\":\"engine_step\",\"rows\":[{}]}}",
            rows_json.join(",")
        ))
        .unwrap()
    }

    #[test]
    fn identical_artifacts_pass() {
        let doc = bench_doc(&[("a", 100.0), ("b", 200.0)]);
        let out = compare(&doc, &doc, 10.0).unwrap();
        assert!(out.passed());
        assert_eq!(out.compared, 2);
        assert!(out.regressions.is_empty() && out.improvements.is_empty());
        assert!(out.missing.is_empty() && out.added.is_empty());
    }

    #[test]
    fn slowdown_beyond_threshold_fails() {
        let old = bench_doc(&[("a", 100.0), ("b", 200.0)]);
        let new = bench_doc(&[("a", 125.0), ("b", 205.0)]);
        let out = compare(&old, &new, 10.0).unwrap();
        assert!(!out.passed());
        assert_eq!(out.regressions.len(), 1);
        assert_eq!(out.regressions[0].row, "name=a");
        assert_eq!(out.regressions[0].key, "mean_ns");
        assert!((out.regressions[0].pct - 25.0).abs() < 1e-9);
        assert!(out.render(10.0).contains("REGRESSION"));
    }

    #[test]
    fn speedup_is_informational_not_fatal() {
        let old = bench_doc(&[("a", 100.0)]);
        let new = bench_doc(&[("a", 50.0)]);
        let out = compare(&old, &new, 10.0).unwrap();
        assert!(out.passed());
        assert_eq!(out.improvements.len(), 1);
    }

    #[test]
    fn missing_and_added_rows_warn_but_pass() {
        let old = bench_doc(&[("a", 100.0), ("gone", 1.0)]);
        let new = bench_doc(&[("a", 100.0), ("fresh", 1.0)]);
        let out = compare(&old, &new, 10.0).unwrap();
        assert!(out.passed());
        assert_eq!(out.missing, vec!["name=gone".to_string()]);
        assert_eq!(out.added, vec!["name=fresh".to_string()]);
        let r = out.render(10.0);
        assert!(r.contains("only in OLD") && r.contains("only in NEW"));
    }

    #[test]
    fn multi_string_fields_compose_the_identity() {
        let doc = parse(
            "{\"bench\":\"engine_step\",\"rows\":[{\"name\":\"a\",\
             \"kind\":\"blocked\",\"mean_ns\":5}]}",
        )
        .unwrap();
        let out = compare(&doc, &doc, 10.0).unwrap();
        assert_eq!(out.compared, 1);
        // BTreeMap ordering: kind before name.
        let rows = rows_by_id(&doc).unwrap();
        assert_eq!(rows[0].0, "kind=blocked|name=a");
    }

    #[test]
    fn mismatched_bench_names_error() {
        let old = bench_doc(&[("a", 100.0)]);
        let new = parse("{\"bench\":\"other\",\"rows\":[]}").unwrap();
        assert!(compare(&old, &new, 10.0).is_err());
        assert!(compare(&parse("{}").unwrap(), &old, 10.0).is_err());
        let no_rows = parse("{\"bench\":\"engine_step\"}").unwrap();
        assert!(compare(&no_rows, &no_rows, 10.0).is_err());
    }

    #[test]
    fn zero_or_missing_gate_keys_are_skipped() {
        let old = parse(
            "{\"bench\":\"b\",\"rows\":[{\"name\":\"a\",\"mean_ns\":0,\
             \"count\":10}]}",
        )
        .unwrap();
        let new = parse(
            "{\"bench\":\"b\",\"rows\":[{\"name\":\"a\",\"mean_ns\":999,\
             \"count\":99999}]}",
        )
        .unwrap();
        // mean_ns old == 0 → no ratio; `count` is not a gate key.
        let out = compare(&old, &new, 10.0).unwrap();
        assert!(out.passed());
    }
}

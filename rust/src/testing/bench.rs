//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Wall-clock timing with warmup, fixed-duration sampling, and
//! criterion-style reporting (mean ± std, p50/p95, throughput). Bench
//! binaries (`cargo bench`) build on this; results for EXPERIMENTS.md
//! §Perf are copied from its output. [`write_bench_json`] serializes a
//! result set as a canonical `{bench, rows}` artifact (`BENCH_engine.json`
//! / `BENCH_round.json`, same shape as `BENCH_fleet.json` and
//! `BENCH_phase.json`) when a bench binary is given `--out-dir`.

use std::time::{Duration, Instant};

use crate::util::stats::{percentile, Welford};

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
    /// optional units-per-iteration for throughput reporting
    pub units: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<40} {:>12} ± {:>10}  p50 {:>12} p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.iters
        );
        if let Some((units, label)) = self.units {
            let per_sec = units / (self.mean_ns / 1e9);
            s.push_str(&format!("  {} {label}/s", fmt_count(per_sec)));
        }
        s
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

pub fn fmt_count(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}K", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

/// Time `f` repeatedly: `warmup` then sample for ~`sample_secs` wall
/// seconds (at least 5 iterations). Returns stats over per-iter times.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 3, 1.0, None, &mut f)
}

/// Benchmark with declared per-iteration units (bytes, steps, rounds...).
pub fn bench_units<F: FnMut()>(
    name: &str,
    units: f64,
    label: &'static str,
    mut f: F,
) -> BenchResult {
    bench_cfg(name, 3, 1.0, Some((units, label)), &mut f)
}

pub fn bench_cfg(
    name: &str,
    warmup: usize,
    sample_secs: f64,
    units: Option<(f64, &'static str)>,
    f: &mut dyn FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    let mut samples = Vec::new();
    let deadline = Instant::now() + Duration::from_secs_f64(sample_secs);
    while Instant::now() < deadline || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        w.push(ns);
        samples.push(ns);
        if samples.len() >= 100_000 {
            break;
        }
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: w.mean(),
        std_ns: w.std(),
        p50_ns: percentile(&samples, 50.0),
        p95_ns: percentile(&samples, 95.0),
        iters: w.count(),
        units,
    };
    println!("{}", r.report());
    r
}

/// Serialize bench results as the canonical `{bench, rows}` JSON document
/// the CI perf artifacts use. Numbers format through the shared
/// [`crate::util::json`] writer, so the file round-trips bit-exactly
/// through [`crate::util::json::parse`].
pub fn write_bench_json(
    path: &str,
    bench_name: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    use crate::util::json::{self, Json};
    use std::collections::BTreeMap;

    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::Str(r.name.clone()));
            o.insert("mean_ns".into(), Json::Num(r.mean_ns));
            o.insert("std_ns".into(), Json::Num(r.std_ns));
            o.insert("p50_ns".into(), Json::Num(r.p50_ns));
            o.insert("p95_ns".into(), Json::Num(r.p95_ns));
            o.insert("iters".into(), Json::Num(r.iters as f64));
            if let Some((units, label)) = r.units {
                o.insert("units_per_iter".into(), Json::Num(units));
                o.insert("unit".into(), Json::Str(label.to_string()));
            }
            Json::Obj(o)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str(bench_name.to_string()));
    doc.insert("rows".into(), Json::Arr(rows));
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, json::to_string(&Json::Obj(doc)) + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0u64;
        let r = bench_cfg("noop", 1, 0.01, None, &mut || {
            count += 1;
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(count >= r.iters);
    }

    #[test]
    fn bench_json_round_trips() {
        let r = BenchResult {
            name: "row".into(),
            mean_ns: 1234.5,
            std_ns: 10.0,
            p50_ns: 1200.0,
            p95_ns: 1400.0,
            iters: 17,
            units: Some((32.0, "samples")),
        };
        let dir = std::env::temp_dir().join("quafl_bench_json_test");
        let path = dir.join("BENCH_test.json");
        let path = path.to_str().unwrap().to_string();
        write_bench_json(&path, "test_bench", &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("test_bench"));
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("mean_ns").unwrap().as_f64(), Some(1234.5));
        assert_eq!(rows[0].get("unit").unwrap().as_str(), Some("samples"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500.0ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.500ms");
        assert_eq!(fmt_count(2.5e6), "2.50M");
    }
}

//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` randomized inputs drawn from a
//! generator; on failure it retries the failing seed with a binary-search
//! style "shrink by regeneration at smaller size" pass and reports the
//! smallest reproduction seed + size it found. Deterministic given the
//! base seed, so failures are reproducible from the log line.

pub mod bench;
pub mod compare;

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// maximum "size" hint passed to the generator (e.g. vector length)
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE, max_size: 1024 }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` cases with sizes ramping from 1 to
/// `cfg.max_size`. The property returns `Err(msg)` to signal failure.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Ramp sizes so early failures are small; always include max_size.
        let size = if cfg.cases <= 1 {
            cfg.max_size
        } else {
            1 + case * (cfg.max_size - 1) / (cfg.cases - 1)
        };
        let case_seed = crate::util::rng::derive_seed(cfg.seed, case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: try smaller sizes with the same seed.
            let mut best = (size, msg.clone());
            let mut lo = 1usize;
            let mut hi = size;
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                let mut r2 = Rng::new(case_seed);
                match prop(&mut r2, mid) {
                    Err(m) => {
                        best = (mid, m);
                        hi = mid;
                    }
                    Ok(()) => {
                        lo = mid + 1;
                    }
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 smallest failing size {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float comparison for properties.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always_ok", PropConfig { cases: 10, ..Default::default() }, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "smallest failing size")]
    fn failing_property_shrinks() {
        check(
            "fails_above_16",
            PropConfig { cases: 8, max_size: 100, ..Default::default() },
            |_, size| {
                if size > 16 {
                    Err(format!("size {size} too big"))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn sizes_ramp_to_max() {
        let mut max_seen = 0;
        check(
            "ramp",
            PropConfig { cases: 5, max_size: 50, ..Default::default() },
            |_, size| {
                max_seen = max_seen.max(size);
                Ok(())
            },
        );
        assert_eq!(max_seen, 50);
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6));
        assert!(!close(1.0, 2.0, 1e-6));
    }
}

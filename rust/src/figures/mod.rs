//! Figure harness: regenerates every figure in the paper's evaluation
//! (body Figures 1–6, appendix Figures 7–16) as CSV series with the same
//! axes the paper plots. DESIGN.md §5 is the authoritative index.
//!
//! Datasets are the synthetic LEAF substitutes (DESIGN.md §3); the claim
//! being reproduced is the *shape* of each comparison (orderings,
//! crossovers, robustness), not absolute accuracies.
//!
//! Default scale is reduced so `quafl figures` completes on a laptop core
//! in minutes; `--paper-scale` restores the paper's n/s/rounds.

use anyhow::{Context, Result};

use crate::config::{
    Algorithm, AveragingMode, ExperimentConfig, QuantizerKind,
};
use crate::coordinator;
use crate::data::{PartitionKind, SynthFamily};
use crate::metrics::RunMetrics;
use crate::util::csv::CsvWriter;

/// One experimental arm of a figure.
pub struct Arm {
    pub label: String,
    pub cfg: ExperimentConfig,
}

pub fn list() -> Vec<&'static str> {
    vec![
        "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
        "fig9", "fig10", "fig11", "fig13", "fig15", "fig16",
    ]
}

/// Run a figure by id, writing one CSV per arm plus a summary row file.
pub fn run_figure(id: &str, out_dir: &str, paper_scale: bool) -> Result<()> {
    let arms = arms_for(id, paper_scale)
        .with_context(|| format!("unknown figure {id:?} (known: {:?})", list()))?;
    std::fs::create_dir_all(out_dir)?;
    let mut summary = CsvWriter::create(
        format!("{out_dir}/{id}_summary.csv"),
        &[
            "arm", "final_acc", "final_val_loss", "final_train_loss",
            "sim_time", "total_bits", "p_zero_progress", "mean_h",
            "time_to_acc50",
        ],
    )?;
    for arm in arms {
        let t0 = std::time::Instant::now();
        let metrics = coordinator::run(&arm.cfg)
            .with_context(|| format!("{id} arm {}", arm.label))?;
        let path = format!("{out_dir}/{id}_{}.csv", arm.label);
        metrics.write_csv(&path)?;
        summary.row_strs(&[
            arm.label.clone(),
            format!("{:.4}", metrics.final_acc()),
            format!("{:.4}", metrics.final_loss()),
            format!(
                "{:.4}",
                metrics.points.last().map(|p| p.train_loss).unwrap_or(f64::NAN)
            ),
            format!(
                "{:.1}",
                metrics.points.last().map(|p| p.sim_time).unwrap_or(0.0)
            ),
            format!("{}", metrics.total_bits()),
            format!("{:.3}", metrics.zero_progress_fraction()),
            format!("{:.2}", metrics.mean_observed_steps()),
            metrics
                .time_to_accuracy(0.5)
                .map(|t| format!("{t:.1}"))
                .unwrap_or_else(|| "never".into()),
        ])?;
        eprintln!(
            "[figures] {id}/{}: acc={:.3} ({}s)",
            arm.label,
            metrics.final_acc(),
            t0.elapsed().as_secs()
        );
    }
    summary.flush()?;
    Ok(())
}

/// Convenience for tests and the summary table in EXPERIMENTS.md.
pub fn run_arms(arms: Vec<Arm>) -> Result<Vec<(String, RunMetrics)>> {
    arms.into_iter()
        .map(|a| coordinator::run(&a.cfg).map(|m| (a.label, m)))
        .collect()
}

fn scale(paper: bool, small: usize, full: usize) -> usize {
    if paper {
        full
    } else {
        small
    }
}

/// Base config shared by the figure experiments.
fn base(paper: bool) -> ExperimentConfig {
    ExperimentConfig {
        rounds: scale(paper, 60, 300),
        train_samples: scale(paper, 4000, 20_000),
        val_samples: 1024,
        eval_every: scale(paper, 10, 20),
        ..Default::default()
    }
}

pub fn arms_for(id: &str, paper: bool) -> Option<Vec<Arm>> {
    let b = base(paper);
    let arms = match id {
        // Fig 1: peers s ∈ {10,20,30,40}, n=100, 14-bit, non-iid, 30% slow.
        "fig1" => {
            let n = scale(paper, 40, 100);
            [1usize, 2, 3, 4]
                .iter()
                .map(|&m| {
                    let s = scale(paper, 4, 10) * m;
                    Arm {
                        label: format!("s{s}"),
                        cfg: ExperimentConfig {
                            algorithm: Algorithm::QuAFL,
                            n,
                            s,
                            family: SynthFamily::Celeb,
                            partition: PartitionKind::ByClass,
                            quantizer: QuantizerKind::Lattice { bits: 14 },
                            timing: crate::config::TimingConfig {
                                slow_fraction: 0.3,
                                ..Default::default()
                            },
                            // non-iid needs a longer horizon for the s
                            // ordering to separate from noise
                            rounds: b.rounds * 3,
                            eval_every: b.eval_every * 3,
                            ..b.clone()
                        },
                    }
                })
                .collect()
        }
        // Fig 2: bits b ∈ {8,10,12,32}, n=40, s=5, iid mnist.
        "fig2" => [8u8, 10, 12, 32]
            .iter()
            .map(|&bits| Arm {
                label: format!("b{bits}"),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::QuAFL,
                    n: scale(paper, 20, 40),
                    s: 5,
                    quantizer: if bits == 32 {
                        QuantizerKind::None
                    } else {
                        QuantizerKind::Lattice { bits }
                    },
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 3: QuAFL (weighted + unweighted) vs FedAvg vs baseline, in
        // simulated time, hard family, 25% slow.
        "fig3" => {
            let mk = |label: &str, algo: Algorithm, weighted: bool| Arm {
                label: label.into(),
                cfg: ExperimentConfig {
                    algorithm: algo,
                    weighted,
                    family: SynthFamily::Hard,
                    n: 20,
                    s: 5,
                    quantizer: QuantizerKind::Lattice { bits: 12 },
                    ..b.clone()
                },
            };
            vec![
                mk("quafl_weighted", Algorithm::QuAFL, true),
                mk("quafl", Algorithm::QuAFL, false),
                Arm {
                    label: "fedavg".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::FedAvg,
                        family: SynthFamily::Hard,
                        n: 20,
                        s: 5,
                        quantizer: QuantizerKind::None,
                        ..b.clone()
                    },
                },
                Arm {
                    label: "baseline".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::Baseline,
                        family: SynthFamily::Hard,
                        n: 20,
                        s: 5,
                        rounds: b.rounds * 10,
                        eval_every: b.eval_every * 10,
                        ..b.clone()
                    },
                },
            ]
        }
        // Fig 4: averaging variants on non-iid celeb.
        "fig4" => [
            ("both", AveragingMode::Both),
            ("server_only", AveragingMode::ServerOnly),
            ("client_only", AveragingMode::ClientOnly),
        ]
        .iter()
        .map(|(label, mode)| Arm {
            label: label.to_string(),
            cfg: ExperimentConfig {
                algorithm: Algorithm::QuAFL,
                averaging: *mode,
                n: scale(paper, 40, 100),
                s: scale(paper, 8, 10),
                family: SynthFamily::Celeb,
                partition: PartitionKind::ByClass,
                quantizer: QuantizerKind::Lattice { bits: 14 },
                ..b.clone()
            },
        })
        .collect(),
        // Fig 5: lattice vs QSGD inside QuAFL, mnist.
        "fig5" => vec![
            Arm {
                label: "lattice".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Lattice { bits: 10 },
                    ..b.clone()
                },
            },
            Arm {
                label: "qsgd".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Qsgd { bits: 10 },
                    // QSGD on raw models needs a gentler lr to stay stable
                    // (the paper: "we had to perform careful tuning").
                    lr: 0.05,
                    ..b.clone()
                },
            },
        ],
        // Fig 6: QuAFL ± quantization vs FedBuff ± QSGD, sim time.
        "fig6" => vec![
            Arm {
                label: "quafl_lattice14".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Lattice { bits: 14 },
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "quafl_fp32".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::None,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedbuff_fp32".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedBuff,
                    quantizer: QuantizerKind::None,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedbuff_qsgd14".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedBuff,
                    quantizer: QuantizerKind::Qsgd { bits: 14 },
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
        ],
        // Fig 7: K ∈ {5,10,20} (paper: FMNIST → hard family).
        "fig7" => [5usize, 10, 20]
            .iter()
            .map(|&k| Arm {
                label: format!("K{k}"),
                cfg: ExperimentConfig {
                    k,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 8: s ∈ {4,8,16}.
        "fig8" => [4usize, 8, 16]
            .iter()
            .map(|&s| Arm {
                label: format!("s{s}"),
                cfg: ExperimentConfig {
                    s,
                    n: 20.max(s),
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 9: server waiting time sweep.
        "fig9" => [2.0f64, 10.0, 30.0]
            .iter()
            .map(|&swt| Arm {
                label: format!("swt{}", swt as i64),
                cfg: ExperimentConfig {
                    timing: crate::config::TimingConfig {
                        swt,
                        ..Default::default()
                    },
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            })
            .collect(),
        // Fig 10: rounds-axis comparison baseline vs FedAvg vs QuAFL.
        "fig10" => vec![
            Arm {
                label: "baseline".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::Baseline,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedavg".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedAvg,
                    quantizer: QuantizerKind::None,
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
            Arm {
                label: "quafl".into(),
                cfg: ExperimentConfig {
                    family: SynthFamily::Hard,
                    ..b.clone()
                },
            },
        ],
        // Fig 11/12: time vs acc & loss across algorithm variants (the CSV
        // carries both columns, so one run covers both panels).
        "fig11" | "fig12" => vec![
            Arm {
                label: "quafl_lattice".into(),
                cfg: ExperimentConfig {
                    family: SynthFamily::Hard,
                    quantizer: QuantizerKind::Lattice { bits: 10 },
                    ..b.clone()
                },
            },
            Arm {
                label: "quafl_fp32".into(),
                cfg: ExperimentConfig {
                    family: SynthFamily::Hard,
                    quantizer: QuantizerKind::None,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedavg".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedAvg,
                    family: SynthFamily::Hard,
                    quantizer: QuantizerKind::None,
                    ..b.clone()
                },
            },
            Arm {
                label: "baseline".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::Baseline,
                    family: SynthFamily::Hard,
                    rounds: b.rounds * 10,
                    eval_every: b.eval_every * 10,
                    ..b.clone()
                },
            },
        ],
        // Fig 13/14: large fleet (paper n=300, s=30).
        "fig13" | "fig14" => vec![Arm {
            label: "n300".into(),
            cfg: ExperimentConfig {
                n: scale(paper, 60, 300),
                s: scale(paper, 6, 30),
                family: SynthFamily::Hard,
                train_samples: scale(paper, 6000, 30_000),
                quantizer: QuantizerKind::Lattice { bits: 10 },
                ..b.clone()
            },
        }],
        // Fig 15: full convergence, n=20, s=5 — all methods to plateau.
        "fig15" => {
            let rounds = scale(paper, 150, 1000);
            vec![
                Arm {
                    label: "quafl".into(),
                    cfg: ExperimentConfig { rounds, ..b.clone() },
                },
                Arm {
                    label: "fedavg".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::FedAvg,
                        quantizer: QuantizerKind::None,
                        rounds,
                        ..b.clone()
                    },
                },
                Arm {
                    label: "baseline".into(),
                    cfg: ExperimentConfig {
                        algorithm: Algorithm::Baseline,
                        rounds: rounds * 10,
                        eval_every: b.eval_every * 10,
                        ..b.clone()
                    },
                },
            ]
        }
        // Fig 16: FedBuff+QSGD vs QuAFL+lattice at equal bit width.
        "fig16" => vec![
            Arm {
                label: "quafl_lattice10".into(),
                cfg: ExperimentConfig {
                    quantizer: QuantizerKind::Lattice { bits: 10 },
                    partition: PartitionKind::ByClass,
                    family: SynthFamily::Celeb,
                    ..b.clone()
                },
            },
            Arm {
                label: "fedbuff_qsgd10".into(),
                cfg: ExperimentConfig {
                    algorithm: Algorithm::FedBuff,
                    quantizer: QuantizerKind::Qsgd { bits: 10 },
                    partition: PartitionKind::ByClass,
                    family: SynthFamily::Celeb,
                    ..b.clone()
                },
            },
        ],
        _ => return None,
    };
    Some(arms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_figure_has_arms_and_valid_configs() {
        for id in list() {
            for paper in [false, true] {
                let arms = arms_for(id, paper).unwrap_or_else(|| {
                    panic!("figure {id} has no arms");
                });
                assert!(!arms.is_empty());
                for arm in arms {
                    arm.cfg
                        .validate()
                        .unwrap_or_else(|e| panic!("{id}/{}: {e}", arm.label));
                }
            }
        }
    }

    #[test]
    fn unknown_figure_is_none() {
        assert!(arms_for("fig99", false).is_none());
    }

    #[test]
    fn fig1_sweeps_s_with_fixed_n() {
        let arms = arms_for("fig1", false).unwrap();
        let ss: Vec<usize> = arms.iter().map(|a| a.cfg.s).collect();
        assert_eq!(ss, vec![4, 8, 12, 16]);
        assert!(arms.iter().all(|a| a.cfg.partition == PartitionKind::ByClass));
    }

    #[test]
    fn fig2_includes_fp32_arm() {
        let arms = arms_for("fig2", false).unwrap();
        assert!(arms.iter().any(|a| a.cfg.quantizer == QuantizerKind::None));
    }

    #[test]
    fn fig16_same_bit_width_across_algorithms() {
        let arms = arms_for("fig16", false).unwrap();
        assert_eq!(arms[0].cfg.quantizer.bits(), arms[1].cfg.quantizer.bits());
        assert_eq!(arms[1].cfg.algorithm, Algorithm::FedBuff);
    }
}
